"""Out-of-sample projection, incremental graph maintenance, and the
continuous-batching projection server."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import LargeVis, LargeVisConfig
from repro.core import knn as knn_lib
from repro.core import transform as tr
from repro.core.neighbor_explore import neighbor_explore
from repro.data.synthetic import mnist_like
from repro.kernels import ops, ref

KEY = jax.random.key(0)

N_CORPUS, N_QUERY = 400, 120
# samples_per_node high enough that the corpus layout actually converges:
# an under-converged embedding fragments class clusters, and then the
# weighted-mean init (correctly) lands between fragments — the quality
# margin below is about the PROJECTION, so give it a converged corpus.
CFG = LargeVisConfig(n_neighbors=12, n_trees=4, samples_per_node=2000,
                     batch_size=128, perplexity=10.0, transform_steps=48)


@pytest.fixture(scope="module")
def data():
    x, labels = mnist_like(KEY, N_CORPUS + N_QUERY, 16, 5)
    return x, np.asarray(labels)


@pytest.fixture(scope="module")
def fitted(data):
    x, _ = data
    return LargeVis(cfg=CFG).fit(x[:N_CORPUS], jax.random.key(1))


def _knn_accuracy(y_corpus, labels_corpus, y_query, labels_query, k=5):
    """Classify each query by majority label of its k nearest corpus
    points in the 2-D embedding."""
    d = np.sum((y_query[:, None, :] - y_corpus[None, :, :]) ** 2, axis=-1)
    nn = np.argsort(d, axis=1)[:, :k]
    votes = labels_corpus[nn]
    pred = np.array([np.bincount(v).argmax() for v in votes])
    return float(np.mean(pred == labels_query))


# ---------------------------------------------------------------------------
# Frozen-rows kernel mode
# ---------------------------------------------------------------------------

def test_frozen_rows_bitwise_kernel_vs_ref():
    """n_frozen mode: kernel == jitted oracle bitwise, frozen rows
    bit-identical to their inputs — for scalar AND per-edge lr."""
    k = jax.random.key(3)
    n, s, b, m, nf = 64, 2, 40, 5, 48
    y = jax.random.normal(jax.random.fold_in(k, 0), (n, s), jnp.float32)
    i = jax.random.randint(jax.random.fold_in(k, 1), (b,), 0, n)
    j = jax.random.randint(jax.random.fold_in(k, 2), (b,), 0, n)
    negs = jax.random.randint(jax.random.fold_in(k, 3), (b, m), 0, n)
    mask = (negs != i[:, None]).astype(jnp.float32)
    for lr in (0.5, jax.random.uniform(jax.random.fold_in(k, 4), (b,))):
        got = ops.largevis_edge_step(y, i, j, negs, mask, lr, n_frozen=nf)
        oracle = jax.jit(functools.partial(
            ref.fused_edge_step_ref, n_frozen=nf))(y, i, j, negs, mask, lr)
        assert np.array_equal(
            np.asarray(got).view(np.uint32),
            np.asarray(oracle).view(np.uint32))
        assert np.array_equal(
            np.asarray(got[:nf]).view(np.uint32),
            np.asarray(y[:nf]).view(np.uint32))


def test_per_edge_lr_scalar_broadcast_bitwise():
    """A broadcast (B,) lr vector reproduces the scalar-lr path bitwise."""
    k = jax.random.key(5)
    n, s, b, m = 50, 2, 32, 4
    y = jax.random.normal(jax.random.fold_in(k, 0), (n, s), jnp.float32)
    i = jax.random.randint(jax.random.fold_in(k, 1), (b,), 0, n)
    j = jax.random.randint(jax.random.fold_in(k, 2), (b,), 0, n)
    negs = jax.random.randint(jax.random.fold_in(k, 3), (b, m), 0, n)
    mask = (negs != i[:, None]).astype(jnp.float32)
    a = ops.largevis_edge_step(y, i, j, negs, mask, 0.7)
    v = ops.largevis_edge_step(y, i, j, negs, mask, jnp.full((b,), 0.7))
    assert np.array_equal(np.asarray(a).view(np.uint32),
                          np.asarray(v).view(np.uint32))


# ---------------------------------------------------------------------------
# Out-of-sample projection
# ---------------------------------------------------------------------------

def test_transform_freezes_corpus_bitwise(data, fitted):
    """The projection's concat embedding keeps every corpus row
    bit-identical (the kernel's -0.0 masking), and the fitted carrier is
    not mutated."""
    x, _ = data
    r = fitted.result_
    y_before = np.asarray(r.y, np.float32).copy()
    nn_idx, nn_dist = tr.query_neighbors(x[N_CORPUS:], r.x, CFG.n_neighbors)
    from repro.core.perplexity import calibrate_p
    p = calibrate_p(nn_dist, float(CFG.n_neighbors),
                    iters=CFG.perplexity_iters)
    y0 = tr._weighted_mean_init(p, nn_idx, r.y)
    y_full = jnp.concatenate([jnp.asarray(r.y, jnp.float32),
                              y0.astype(jnp.float32)])
    out = tr._project_scan(
        y_full, jax.random.key(9), jnp.log(p), nn_idx, r.neg_sampler,
        n_negatives=CFG.n_negatives, steps=int(CFG.transform_steps),
        rho0=float(CFG.rho0), prob_fn=CFG.prob_fn, a=CFG.prob_a,
        gamma=CFG.gamma, clip=CFG.grad_clip,
        fused_step=bool(CFG.fused_step))
    assert np.array_equal(
        np.asarray(out[:N_CORPUS]).view(np.uint32),
        y_before.view(np.uint32))
    # the public path leaves the carrier untouched
    fitted.transform(x[N_CORPUS:])
    assert np.array_equal(np.asarray(r.y, np.float32).view(np.uint32),
                          y_before.view(np.uint32))


def test_project_scan_donates_embedding(fitted):
    """The scan is compiled with the (N+Q, s) buffer donated (aliased
    input->output), so projection adds no second embedding-sized buffer."""
    r = fitted.result_
    q, k = 8, CFG.n_neighbors
    y_full = jnp.zeros((N_CORPUS + q, 2), jnp.float32)
    kwargs = dict(n_negatives=CFG.n_negatives, steps=4, rho0=1.0,
                  prob_fn="inv_quadratic", a=1.0, gamma=7.0, clip=5.0,
                  fused_step=True)
    compiled = tr._project_scan.lower(
        y_full, jax.random.key(0), jnp.zeros((q, k)),
        jnp.zeros((q, k), jnp.int32), r.neg_sampler, **kwargs).compile()
    assert "input_output_alias" in compiled.as_text()


def test_transform_quality_within_refit_margin(data, fitted):
    """Acceptance: projecting held-out queries lands them well enough that
    a KNN classifier in embedding space is within 0.05 of refitting the
    whole dataset from scratch."""
    x, labels = data
    y_corpus = np.asarray(fitted.embedding_)
    y_query = np.asarray(fitted.transform(x[N_CORPUS:]))
    assert np.isfinite(y_query).all()
    acc_transform = _knn_accuracy(y_corpus, labels[:N_CORPUS],
                                  y_query, labels[N_CORPUS:])

    refit = LargeVis(cfg=CFG).fit(x, jax.random.key(1))
    y_all = np.asarray(refit.embedding_)
    acc_refit = _knn_accuracy(y_all[:N_CORPUS], labels[:N_CORPUS],
                              y_all[N_CORPUS:], labels[N_CORPUS:])
    assert acc_transform >= acc_refit - 0.05, (acc_transform, acc_refit)


# ---------------------------------------------------------------------------
# Incremental graph maintenance
# ---------------------------------------------------------------------------

def test_knn_insert_recall_vs_fresh_build(data, fitted):
    """Insert-maintained graph recall tracks a fresh brute-force build."""
    x, _ = data
    r = fitted.result_
    x_all, idx_all, dist_all = tr.knn_insert(
        r.x, r.knn_idx, r.knn_dist, x[N_CORPUS:], key=jax.random.key(7),
        cfg=CFG)
    assert idx_all.shape == (N_CORPUS + N_QUERY, CFG.n_neighbors)
    fresh_idx, _ = knn_lib.brute_force_knn(x, k=CFG.n_neighbors)
    hit = (np.asarray(idx_all)[:, :, None]
           == np.asarray(fresh_idx)[:, None, :]).any(axis=1)
    recall = float(hit.mean())
    assert recall > 0.9, recall
    # distances stay consistent with the ids they claim
    x_np = np.asarray(x_all)
    row = x_np[10] - x_np[np.asarray(idx_all)[10]]
    np.testing.assert_allclose(np.sum(row * row, axis=1),
                               np.asarray(dist_all)[10], rtol=1e-4,
                               atol=1e-4)


def test_neighbor_explore_rows_subset(data):
    """rows= explores only the given rows: untouched rows bit-identical,
    explored rows never get worse."""
    x, _ = data
    x = x[:200]
    idx, dist = knn_lib.brute_force_knn(x, k=8)
    # corrupt some rows to give exploring work to do
    bad = jnp.arange(0, 200, 7, dtype=jnp.int32)
    idx = idx.at[bad].set(jnp.broadcast_to(
        jnp.arange(8, dtype=jnp.int32), (bad.shape[0], 8)))
    xb = np.asarray(x)
    corrupted = xb[np.asarray(bad)][:, None, :] - xb[None, :8, :]
    dist = dist.at[bad].set(jnp.asarray(
        np.sum(corrupted * corrupted, axis=-1), jnp.float32))
    idx2, dist2 = neighbor_explore(x, idx, dist, iters=2,
                                   key=jax.random.key(3), rows=bad)
    untouched = np.setdiff1d(np.arange(200), np.asarray(bad))
    assert np.array_equal(np.asarray(idx2)[untouched],
                          np.asarray(idx)[untouched])
    assert float(jnp.mean(dist2[bad])) <= float(jnp.mean(dist[bad]))


def test_estimator_insert_grows_model(data, fitted):
    """insert() returns coords for the new points, grows every carrier
    field consistently, and never moves existing embedding rows."""
    x, _ = data
    import pickle
    m = pickle.loads(pickle.dumps(fitted))     # work on a copy
    y_before = np.asarray(m.embedding_).copy()
    y_new = m.insert(x[N_CORPUS:])
    assert y_new.shape == (N_QUERY, 2)
    r = m.result_
    n_all = N_CORPUS + N_QUERY
    assert r.x.shape[0] == n_all
    assert r.y.shape[0] == n_all
    assert r.knn_idx.shape == (n_all, CFG.n_neighbors)
    assert r.weights.shape == (n_all, CFG.n_neighbors)
    assert r.neg_sampler.n_nodes == n_all
    assert np.array_equal(np.asarray(r.y[:N_CORPUS]), y_before)
    # the grown model serves transforms
    yq = m.transform(x[:3])
    assert np.isfinite(np.asarray(yq)).all()


# ---------------------------------------------------------------------------
# Continuous-batching projection server
# ---------------------------------------------------------------------------

def test_projection_engine_round_trip(data, fitted):
    """More requests than slots: everything retires with finite coords,
    latencies are recorded, and the corpus stays bit-frozen through all
    the traffic."""
    from repro.launch.serve_projection import ProjectionEngine, ProjectRequest
    x, _ = data
    y_ref = np.asarray(fitted.embedding_, np.float32).copy()
    eng = ProjectionEngine(fitted.result_, slots=16, seed=2)
    reqs = [ProjectRequest(i, np.asarray(x[N_CORPUS + i % N_QUERY]))
            for i in range(50)]
    for r in reqs:
        eng.submit(r)
    n_steps = eng.run()
    assert all(r.done for r in reqs)
    ys = np.stack([r.y for r in reqs])
    assert np.isfinite(ys).all()
    assert all(r.latency >= 0 for r in reqs)
    assert n_steps >= int(CFG.transform_steps)
    assert np.array_equal(
        np.asarray(eng.y_full[:N_CORPUS]).view(np.uint32),
        y_ref.view(np.uint32))


def test_projection_engine_deterministic(data, fitted):
    """Same seed + same submission order -> bitwise-identical results."""
    from repro.launch.serve_projection import ProjectionEngine, ProjectRequest
    x, _ = data

    def serve():
        eng = ProjectionEngine(fitted.result_, slots=8, seed=4)
        reqs = [ProjectRequest(i, np.asarray(x[N_CORPUS + i]))
                for i in range(12)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return np.stack([r.y for r in reqs])

    a, b = serve(), serve()
    assert np.array_equal(a.view(np.uint32), b.view(np.uint32))
