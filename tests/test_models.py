"""Per-architecture smoke tests (reduced configs) + consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import make_model

KEY = jax.random.key(0)


def _batch(cfg, B, S, with_labels=True):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks}
    if with_labels:
        b["labels"] = toks
    if cfg.is_encoder_decoder:
        b["encoder_frames"] = jax.random.normal(
            KEY, (B, cfg.enc_positions, cfg.d_model), cfg.dtype)
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke(name):
    """Reduced config: one forward/train step, shapes + no NaNs."""
    cfg = get_config(name).reduced()
    model = make_model(cfg)
    params = model["init"](KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    loss = jax.jit(model["loss"])(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{name}: loss={loss}"
    logits, cache = jax.jit(model["prefill"])(
        params, {k: v for k, v in batch.items() if k != "labels"})
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    dec = {"tokens": batch["tokens"][:, :1], "cache": cache,
           "position": jnp.full((B,), S - 1, jnp.int32)}
    dl, new_cache = jax.jit(model["decode"])(params, dec)
    assert dl.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(dl).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_grads_finite(name):
    cfg = get_config(name).reduced()
    model = make_model(cfg)
    params = model["init"](KEY)
    batch = _batch(cfg, 2, 16)
    grads = jax.jit(jax.grad(model["loss"]))(params, batch)
    leaves = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in leaves), name
    # at least one non-zero grad per top-level group
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_prefill(name):
    """prefill(S)+decode(token S) == prefill(S+1) last logits.

    MoE archs use total routing (topk=E): top-k *membership* at random init
    flips under f32 reduction-order noise (router margins ~1e-5), which is a
    property of untrained routers, not of the cache machinery under test.
    """
    cfg = get_config(name).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, topk_experts=cfg.n_experts)
    model = make_model(cfg)
    params = model["init"](KEY)
    B, S = 2, 31
    full = _batch(cfg, B, S + 1, with_labels=False)
    pre = dict(full, tokens=full["tokens"][:, :S])
    ref_logits, _ = jax.jit(model["prefill"])(params, full)
    _, cache = jax.jit(model["prefill"])(params, pre)

    def pad(x):  # grow stacked attention caches (n_per, B, S, KVH, hd) by 1
        if x.ndim == 5 and x.shape[2] == S:
            return jnp.pad(x, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        return x

    cache = jax.tree.map(pad, cache)
    dec = {"tokens": full["tokens"][:, S:S + 1], "cache": cache,
           "position": jnp.full((B,), S, jnp.int32)}
    dl, _ = jax.jit(model["decode"])(params, dec)
    rel = float(jnp.max(jnp.abs(dl - ref_logits))) / \
        float(jnp.max(jnp.abs(ref_logits)))
    assert rel < 2e-3, f"{name}: rel={rel}"


def test_chunked_attention_matches_full():
    from repro.models.attention import mha_chunked, mha_full
    B, S, H, KVH, hd = 2, 256, 8, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)
    pos = jnp.arange(S)
    for window in (0, 64):
        a = mha_full(q, k, v, pos, pos, causal=True, window=window)
        b = mha_chunked(q, k, v, pos, pos, causal=True, window=window,
                        q_block=64, kv_block=32)
        np.testing.assert_allclose(a, b, atol=3e-5)


def test_sliding_window_mask_semantics():
    """Token at position p must not attend beyond p-window."""
    from repro.models.attention import _mask_bias
    pos = jnp.arange(16)
    bias = _mask_bias(pos, pos, causal=True, window=4)
    m = np.asarray(bias)
    assert m[10, 10] == 0 and m[10, 7] == 0        # within window
    assert m[10, 6] < -1e29 and m[10, 11] < -1e29  # outside / future


@pytest.mark.parametrize("name", ["llama3-8b", "gemma3-12b"])
def test_decode_with_int8_kv_cache(name):
    """Quantized-cache decode matches prefill within int8 error bounds."""
    from repro.models.factory import make_model as mk
    cfg = get_config(name).reduced()
    model_q = mk(cfg, kv_quant=True)
    model = mk(cfg)
    params = model["init"](KEY)
    B, S = 2, 31
    full = _batch(cfg, B, S + 1, with_labels=False)
    pre = dict(full, tokens=full["tokens"][:, :S])
    ref_logits, _ = jax.jit(model["prefill"])(params, full)
    _, cache = jax.jit(model_q["prefill"])(params, pre)

    def pad(x):
        if x.ndim == 5 and x.shape[2] == S:
            pads = [(0, 0), (0, 0), (0, 1)] + [(0, 0)] * (x.ndim - 3)
            return jnp.pad(x, pads)
        return x

    cache = jax.tree.map(pad, cache)
    dec = {"tokens": full["tokens"][:, S:S + 1], "cache": cache,
           "position": jnp.full((B,), S, jnp.int32)}
    dl, new_cache = jax.jit(model["decode"])(params, dec)
    # int8 cache: tolerance governed by quantization (~1/127 per element)
    rel = float(jnp.max(jnp.abs(dl - ref_logits))) / \
        float(jnp.max(jnp.abs(ref_logits)))
    assert rel < 0.15, f"{name}: rel={rel}"
    # cache stayed quantized after the decode step
    kinds = {l.dtype for l in jax.tree.leaves(new_cache)}
    assert np.dtype("int8") in kinds
