"""Kernel autotuner (runtime/autotune.py) + embedding-tiled fused step.

Covers: versioned cache round-trip and wholesale version rejection, mode
resolution (``off`` returns the legacy default verbatim and ignores every
cache; ``cache`` consults user cache then the committed table, with
``default`` acting as a key whitelist), pow2 shape bucketing, the sweep's
paired adopt rule (beat the incumbent by > 3 % or keep the default), the
``sample > 0`` key-stream gate on neighbor_explore, bitwise equality of
the embedding-tiled fused step against the untiled kernel and the ref
oracle (multi-tile, odd N, duplicate-dense batches, frozen rows, per-edge
lr), an HLO check that the tiled lowering holds no second full-embedding
temporary beyond the aliased in/out, and the lifted size bound on
``ops.fused_step_supported``.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import hlo_checks

from repro.kernels import ops, ref
from repro.kernels.largevis_step import fused_edge_step
from repro.runtime import autotune

BACKEND = jax.default_backend()
GAMMA, A, CLIP = 7.0, 1.0, 5.0

_ref_step = jax.jit(ref.fused_edge_step_ref,
                    static_argnames=("gamma", "a", "clip", "eps", "n_frozen"))


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    """Isolated cache dir, no committed table, guaranteed mode restore."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    monkeypatch.setattr(autotune, "_defaults_path",
                        lambda: tmp_path / "no_committed_table.json")
    autotune._mem.clear()
    yield tmp_path
    autotune.set_mode(None)
    autotune._mem.clear()


# ---------------------------------------------------------------------------
# cache plumbing + mode resolution
# ---------------------------------------------------------------------------

def test_cache_roundtrip_and_key_whitelist(tuner):
    """A written entry is served back — but only through the default's
    keys, so a cached config can never leak an unknown kwarg into a call
    site with a different signature."""
    autotune.set_mode("cache")
    shape = dict(n=8000, k=20)
    key = autotune.bucket_key("symmetrize", shape)
    autotune._write_entry(BACKEND, key,
                          {"config": dict(tile=512, rogue_kw=7)})
    autotune._mem.clear()
    got = autotune.get("symmetrize", shape, dict(tile=4096))
    assert got == dict(tile=512)          # tuned value in, rogue key out


def test_version_mismatch_rejected_wholesale(tuner):
    autotune.set_mode("cache")
    shape = dict(n=8000, k=20)
    key = autotune.bucket_key("symmetrize", shape)
    path = autotune._cache_path(BACKEND)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({
        "version": autotune.AUTOTUNE_VERSION + 1,
        "entries": {key: {"config": dict(tile=512)}}}))
    assert autotune._read_entries(path) == {}
    assert autotune.get("symmetrize", shape, dict(tile=4096)) == \
        dict(tile=4096)
    # corrupt file: same answer, no crash
    path.write_text("{not json")
    autotune._mem.clear()
    assert autotune.get("symmetrize", shape, dict(tile=4096)) == \
        dict(tile=4096)


def test_off_mode_returns_default_verbatim(tuner):
    """``off`` is the bitwise CI anchor: a poisoned cache entry must not
    reach the call site."""
    shape = dict(n=8000, k=20)
    key = autotune.bucket_key("symmetrize", shape)
    autotune._write_entry(BACKEND, key, {"config": dict(tile=13)})
    autotune.set_mode("off")
    assert autotune.get("symmetrize", shape, dict(tile=4096)) == \
        dict(tile=4096)
    autotune.set_mode("cache")
    assert autotune.get("symmetrize", shape, dict(tile=4096)) == \
        dict(tile=13)


def test_user_cache_wins_over_committed_table(tuner, monkeypatch):
    autotune.set_mode("cache")
    shape = dict(n=8000, k=20)
    key = autotune.bucket_key("symmetrize", shape)
    table = tuner / "table.json"
    table.write_text(json.dumps({
        "version": autotune.AUTOTUNE_VERSION,
        "entries": {key: {"config": dict(tile=256)}}}))
    monkeypatch.setattr(autotune, "_defaults_path", lambda: table)
    assert autotune.get("symmetrize", shape, dict(tile=4096)) == \
        dict(tile=256)                     # committed table on user miss
    autotune._write_entry(BACKEND, key, {"config": dict(tile=512)})
    autotune._mem.clear()
    assert autotune.get("symmetrize", shape, dict(tile=512)) == \
        dict(tile=512)                     # user cache wins


def test_shape_bucketing_pow2():
    assert autotune.bucket_shape(dict(n=1000, k=20)) == dict(n=1024, k=32)
    k_a = autotune.bucket_key("k", dict(n=1000), backend="cpu")
    k_b = autotune.bucket_key("k", dict(n=1024), backend="cpu")
    k_c = autotune.bucket_key("k", dict(n=1025), backend="cpu")
    assert k_a == k_b != k_c
    assert k_a.startswith("cpu/k/")


def test_legacy_default_registry():
    assert autotune.legacy_default("largevis_edge_step") == \
        dict(tile=1024, gather="take", y_tile=0)
    assert autotune.legacy_default("topk_sqdist", backend="tpu") == \
        dict(bm=256, bn=512, lane=128)
    with pytest.raises(KeyError):
        autotune.legacy_default("no_such_kernel")


# ---------------------------------------------------------------------------
# sweep decision rule (timing faked — the adopt logic, not the clock)
# ---------------------------------------------------------------------------

def _fake_builder(shape, backend):
    cands = [dict(tile=2), dict(tile=3)]
    return cands, lambda cfg: (lambda: cfg["tile"])


def _fake_timer(times_paired):
    """best_of_interleaved stub: shortlist pass ranks candidate tile=3
    fastest; the paired pass returns ``times_paired``."""
    def fake(fns, repeats):
        if len(fns) == 2:
            return None, list(times_paired)
        return None, [1.0, 0.9, 0.5][:len(fns)]
    return fake


def test_sweep_adopts_clear_winner(tuner, monkeypatch):
    from repro.runtime import timing
    monkeypatch.setitem(autotune._SWEEPS, "fake_kernel", _fake_builder)
    monkeypatch.setattr(timing, "best_of_interleaved",
                        _fake_timer((1.0, 0.5)))
    chosen = autotune.sweep("fake_kernel", dict(n=100), dict(tile=1))
    assert chosen == dict(tile=3)
    # persisted: a fresh cache-mode lookup serves it
    autotune._mem.clear()
    autotune.set_mode("cache")
    assert autotune.get("fake_kernel", dict(n=100), dict(tile=1)) == \
        dict(tile=3)


def test_sweep_keeps_default_on_noise_margin(tuner, monkeypatch):
    """A paired win inside ADOPT_MARGIN is indistinguishable from load
    noise on a single-core box — ties keep the legacy default."""
    from repro.runtime import timing
    monkeypatch.setitem(autotune._SWEEPS, "fake_kernel", _fake_builder)
    monkeypatch.setattr(timing, "best_of_interleaved",
                        _fake_timer((1.0, 0.99)))
    assert autotune.sweep("fake_kernel", dict(n=100), dict(tile=1)) == \
        dict(tile=1)


def test_sweep_mode_sweeps_on_miss(tuner, monkeypatch):
    from repro.runtime import timing
    monkeypatch.setitem(autotune._SWEEPS, "fake_kernel", _fake_builder)
    monkeypatch.setattr(timing, "best_of_interleaved",
                        _fake_timer((1.0, 0.5)))
    autotune.set_mode("sweep")
    assert autotune.get("fake_kernel", dict(n=100), dict(tile=1)) == \
        dict(tile=3)


def test_unknown_kernel_sweep_is_identity(tuner):
    assert autotune.sweep("no_such_kernel", dict(n=4), dict(tile=9)) == \
        dict(tile=9)


# ---------------------------------------------------------------------------
# call-site contracts
# ---------------------------------------------------------------------------

def test_off_mode_topk_bitwise_vs_explicit_legacy(tuner):
    """AUTOTUNE=off through the ops layer == the legacy config passed
    explicitly, bitwise — the pre-autotuner repo is reproducible."""
    autotune.set_mode("off")
    ka, kb = jax.random.split(jax.random.key(7))
    a = jax.random.normal(ka, (300, 16), jnp.float32)
    b = jax.random.normal(kb, (500, 16), jnp.float32)
    d_off, i_off = ops.topk_sqdist(a, b, 10)
    legacy = autotune.legacy_default("topk_sqdist")
    autotune.set_mode("cache")
    d_leg, i_leg = ops.topk_sqdist(a, b, 10, **legacy)
    assert np.array_equal(np.asarray(d_off), np.asarray(d_leg))
    assert np.array_equal(np.asarray(i_off), np.asarray(i_leg))


def test_explore_sample_gate_never_consults_tuner(tuner, monkeypatch):
    """``neighbor_explore`` with ``sample > 0`` folds the tile index into
    its key stream — tuning the tile would change which candidates are
    drawn.  The call site must not consult the tuner there (and must
    consult it for the deterministic ``sample == 0`` path)."""
    from repro.core import knn, neighbor_explore as ne
    x = jax.random.normal(jax.random.key(3), (200, 8), jnp.float32)
    idx, dist = knn.brute_force_knn(x, 5)
    calls = []
    real_get = autotune.get

    def spy(kernel, shape, default):
        calls.append(kernel)
        return real_get(kernel, shape, default)

    monkeypatch.setattr(autotune, "get", spy)
    ne.neighbor_explore(x, idx, dist, iters=1, sample=16,
                        key=jax.random.key(4))
    assert "neighbor_explore" not in calls
    ne.neighbor_explore(x, idx, dist, iters=1, sample=0)
    assert "neighbor_explore" in calls


def test_routing_config_sets_mode(tuner):
    from repro.configs.largevis_default import LargeVisConfig, RoutingConfig
    from repro.core.largevis import _apply_autotune_mode
    _apply_autotune_mode(LargeVisConfig(
        routing=RoutingConfig(autotune="off")))
    assert autotune.mode() == "off"
    _apply_autotune_mode(LargeVisConfig())     # auto -> env default
    assert autotune.mode() == "cache"


# ---------------------------------------------------------------------------
# embedding-tiled fused step: bitwise contract + VMEM residency
# ---------------------------------------------------------------------------

def _batch(N, B, M, s=2, seed=0, lo=0):
    ks = jax.random.split(jax.random.fold_in(jax.random.key(11), seed), 5)
    y = jax.random.normal(ks[0], (N, s), jnp.float32)
    i = jax.random.randint(ks[1], (B,), lo, N)
    j = jax.random.randint(ks[2], (B,), lo, N)
    negs = jax.random.randint(ks[3], (B, M), lo, N)
    mask = ((negs != i[:, None]) & (negs != j[:, None])).astype(jnp.float32)
    return y, i, j, negs, mask


@pytest.mark.parametrize("y_tile", [5, 8, 16, 36, 50])
def test_tiled_matches_untiled_and_ref_bitwise(y_tile):
    """Odd N=37 against tiles that divide unevenly (padded slab), exceed N
    (clamped), and everything between — all bitwise equal to the untiled
    kernel and the compiled oracle."""
    y, i, j, negs, mask = _batch(37, 29, 4, s=3, seed=1)
    kw = dict(gamma=GAMMA, a=A, clip=CLIP, interpret=True)
    tiled = fused_edge_step(y, i, j, negs, mask, 0.37, y_tile=y_tile, **kw)
    flat = fused_edge_step(y, i, j, negs, mask, 0.37, **kw)
    want = _ref_step(y, i, j, negs, mask, 0.37, gamma=GAMMA, a=A, clip=CLIP)
    assert np.array_equal(np.asarray(tiled), np.asarray(flat))
    assert np.array_equal(np.asarray(tiled), np.asarray(want))


@pytest.mark.parametrize("y_tile", [4, 7, 32])
def test_tiled_duplicate_dense_frozen_per_edge_lr(y_tile):
    """Every row drawn many times per batch (N=6), half the rows frozen,
    per-edge learning rates: the tiled accumulation order and the frozen
    -0.0 no-op writes must survive tiling bitwise."""
    N, B, M, s = 6, 64, 3, 2
    y, i, j, negs, mask = _batch(N, B, M, s=s, seed=2)
    lr = jax.random.uniform(jax.random.key(9), (B,), jnp.float32, 0.1, 0.9)
    kw = dict(gamma=GAMMA, a=A, clip=CLIP, n_frozen=3, interpret=True)
    tiled = fused_edge_step(y, i, j, negs, mask, lr, y_tile=y_tile, **kw)
    flat = fused_edge_step(y, i, j, negs, mask, lr, **kw)
    want = _ref_step(y, i, j, negs, mask, lr, gamma=GAMMA, a=A, clip=CLIP,
                     n_frozen=3)
    assert np.array_equal(np.asarray(tiled), np.asarray(flat))
    assert np.array_equal(np.asarray(tiled), np.asarray(want))
    assert np.array_equal(np.asarray(tiled[:3]), np.asarray(y[:3]))


def test_ops_route_applies_y_tile_bitwise(tuner):
    """A cached y_tile flows through ops.largevis_edge_step and changes
    nothing but the tiling."""
    autotune.set_mode("cache")
    y, i, j, negs, mask = _batch(123, 40, 5, seed=3)
    base = ops.largevis_edge_step(y, i, j, negs, mask, 0.5, gamma=GAMMA,
                                  a=A, clip=CLIP)
    key = autotune.bucket_key("largevis_edge_step",
                              dict(n=123, b=40, m=5, s=2))
    autotune._write_entry(BACKEND, key, {"config": dict(y_tile=48)})
    autotune._mem.clear()
    jax.clear_caches()                    # tiles are static jit args
    tuned = ops.largevis_edge_step(y, i, j, negs, mask, 0.5, gamma=GAMMA,
                                   a=A, clip=CLIP)
    assert np.array_equal(np.asarray(base), np.asarray(tuned))


def test_tiled_hlo_no_second_full_embedding():
    """Per grid step the tiled lowering holds an (R, s) slab plus the two
    (B, (2+M)s) scratches — every buffer other than the whole-embedding
    in/out (and its padded alias) must fit in one slab/scratch."""
    N, s, B, M, R = 1000, 2, 64, 3, 384        # pads to Np = 1152
    y, i, j, negs, mask = _batch(N, B, M, s=s, seed=4)

    def f(y_, i_, j_, negs_, mask_):
        return fused_edge_step(y_, i_, j_, negs_, mask_, 0.5, gamma=GAMMA,
                               a=A, clip=CLIP, y_tile=R, interpret=True)

    txt = jax.jit(f).lower(y, i, j, negs, mask).as_text()
    n_pad = -(-N // R) * R
    whole = {(N, s), (n_pad, s)}
    limit = 4 * max(R * s, B * (2 + M) * s)
    offenders = sorted({
        (nb, dt, shape) for dt, shape, nb in hlo_checks.iter_buffers(txt)
        if shape not in whole and nb > limit}, reverse=True)
    assert not offenders, offenders[:8]
    # sanity: the slab and the padded alias really are in the lowering
    assert hlo_checks.has_buffer(txt, (R, s), "f32")
    assert hlo_checks.has_buffer(txt, (n_pad, s), "f32")


def test_fused_step_supported_lifts_size_bound():
    """The 8 MiB VMEM ceiling is a tiling decision now, not a routing
    rejection: any N is supported, with a tile chosen past the budget."""
    assert ops.fused_step_supported(10_000_000, 2)
    assert ops._fused_y_tile(100, 2) == 0          # fits: stay untiled
    big_tile = ops._fused_y_tile(10_000_000, 2)
    assert 0 < big_tile < 10_000_000
    assert 4 * 2 * big_tile <= ops._FUSED_MAX_Y_BYTES
