"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.knn_topk import pairwise_sqdist
from repro.kernels.largevis_grad import largevis_grads

KEY = jax.random.key(7)


@pytest.mark.parametrize("m,n,d", [(64, 64, 32), (100, 80, 100),
                                   (256, 128, 128), (33, 17, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist(m, n, d, dtype):
    ka, kb = jax.random.split(KEY)
    a = jax.random.normal(ka, (m, d), dtype)
    b = jax.random.normal(kb, (n, d), dtype)
    got = pairwise_sqdist(a, b, bm=64, bn=64, bk=32, interpret=True)
    want = ref.pairwise_sqdist_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


@pytest.mark.parametrize("b,m,s", [(128, 5, 2), (256, 7, 3), (64, 1, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_largevis_grads(b, m, s, dtype):
    ks = jax.random.split(KEY, 4)
    yi = jax.random.normal(ks[0], (b, s), dtype)
    yj = jax.random.normal(ks[1], (b, s), dtype)
    yn = jax.random.normal(ks[2], (b, m, s), dtype)
    mask = (jax.random.uniform(ks[3], (b, m)) > 0.1).astype(jnp.float32)
    got = largevis_grads(yi, yj, yn, mask, tile=64, interpret=True)
    want = ref.largevis_grads_ref(yi, yj, yn, neg_mask=mask)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-5, rtol=1e-5)


def test_largevis_grads_match_autodiff():
    """Hand-derived forces == jax.grad of the Eqn (6) objective.

    The reference impl's eps lives only in the force denominator (numerical
    guard, not part of the objective), so the exact-gradient comparison uses
    eps=0 on points bounded away from collision.
    """
    ks = jax.random.split(KEY, 3)
    B, M, s = 32, 5, 2
    yi = jax.random.normal(ks[0], (B, s))
    yj = jax.random.normal(ks[1], (B, s)) * 2.0
    yn = jax.random.normal(ks[2], (B, M, s)) * 2.0 + 4.0  # away from yi
    gamma, a = 7.0, 1.0

    def neg_loglik(yi, yj, yn):
        d2 = jnp.sum((yi - yj) ** 2, -1)
        pos = -jnp.log(1.0 / (1.0 + a * d2))               # -w log f
        dn2 = jnp.sum((yi[:, None] - yn) ** 2, -1)
        # -gamma log(1 - f) with 1-f = a dn2/(1+a dn2)
        neg = -gamma * (jnp.log(a * dn2) - jnp.log1p(a * dn2))
        return jnp.sum(pos) + jnp.sum(neg)

    auto = jax.grad(neg_loglik, argnums=(0, 1, 2))(yi, yj, yn)
    mask = jnp.ones((B, M))
    got = ref.largevis_grads_ref(yi, yj, yn, gamma=gamma, a=a, clip=1e9,
                                 eps=0.0, neg_mask=mask)
    for g, w in zip(got, auto):
        np.testing.assert_allclose(g, w, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("b,s,t,h,hd", [(1, 128, 128, 2, 64),
                                        (2, 64, 64, 4, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, t, h, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, t, h, hd), dtype)
    v = jax.random.normal(ks[2], (b, t, h, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, q_block=32, kv_block=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_attention():
    """Pallas flash == models.attention mha_full (heads pre-broadcast)."""
    from repro.models.attention import mha_full
    B, S, H, hd = 2, 128, 4, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.arange(S)
    want = mha_full(q, k, v, pos, pos, causal=True)
    got = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32,
                          interpret=True)
    np.testing.assert_allclose(got, want, atol=3e-5)
