"""Crash-safe resume (PR 8): kill the pipeline at every stage boundary
and mid-layout, restart the SAME call, and require the final embedding
to be **bitwise-equal** to an uninterrupted run.

Why bitwise is attainable: every stage is a pure function of
``(x, key, cfg)``, layout steps derive their randomness from
``fold_in(kr, step_id)``, the lr positions are host-side ``t/steps``
fractions, and checkpoints round-trip f32 exactly — so resuming at a
chunk boundary replays the identical trajectory (DESIGN.md; the scan
engine's resume hook has been bitwise-pinned since PR 4).

Three rings of coverage:

* in-process matrix (tier-1) — ``InjectedFault("exception")`` at every
  site, resume in the same process; fast because compiled fns are hot.
* one REAL ``SIGKILL`` subprocess round trip (tier-1) — no atexit, no
  flushing, mid-layout; the genuinely-crashed case.
* the full subprocess kill matrix, incl. ``distributed=True`` on a
  forced 4-device host mesh (``slow`` + ``chaos`` markers, nightly).
"""
import dataclasses
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.configs.largevis_default import (CheckpointConfig, HealthConfig,
                                            LargeVisConfig)
from repro.core.largevis import largevis
from repro.runtime.fault_tolerance import FaultInjector, InjectedFault

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N, D = 384, 16
CFG = LargeVisConfig(n_neighbors=8, n_trees=2, n_explore_iters=1, window=16,
                     perplexity=6.0, samples_per_node=120, batch_size=64,
                     steps_per_dispatch=10)


def _x():
    return np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)


def _ckpt_cfg(tmp_path, base=CFG, **kw):
    return dataclasses.replace(
        base, checkpoint=CheckpointConfig(directory=str(tmp_path / "ckpt"),
                                          every_chunks=1, **kw))


@pytest.fixture(scope="module")
def baseline():
    """Uninterrupted fit (no checkpointing) — the bitwise oracle."""
    return np.asarray(largevis(_x(), jax.random.key(7), cfg=CFG).y)


# ---------------------------------------------------------------------------
# in-process crash matrix (exception faults; fast)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site,hit", [
    ("stage:graph", 0),
    ("stage:weights", 0),
    ("stage:samplers", 0),
    ("layout_saved", 0),         # after the first layout chunk committed
    ("layout_saved", 2),         # mid-layout
])
def test_resume_bitwise_after_crash(tmp_path, baseline, site, hit):
    cfg = _ckpt_cfg(tmp_path)
    with pytest.raises(InjectedFault):
        largevis(_x(), jax.random.key(7), cfg=cfg,
                 fault=FaultInjector({site: {hit: "exception"}}))
    r = largevis(_x(), jax.random.key(7), cfg=cfg)
    assert np.array_equal(np.asarray(r.y), baseline)


def test_resume_skips_completed_stages(tmp_path, baseline, monkeypatch):
    """The restarted run RESTORES prior stages instead of recomputing:
    after a crash past the samplers boundary, the rerun must finish even
    with the graph/weights/sampler builders ripped out."""
    import sys
    lv = sys.modules["repro.core.largevis"]   # the module, not the function
    cfg = _ckpt_cfg(tmp_path)
    with pytest.raises(InjectedFault):
        largevis(_x(), jax.random.key(7), cfg=cfg,
                 fault=FaultInjector({"stage:samplers": {0: "exception"}}))

    def boom(*a, **kw):
        raise AssertionError("stage recomputed despite a valid checkpoint")

    monkeypatch.setattr(lv.knn_lib, "build_knn_graph", boom)
    monkeypatch.setattr(lv.perp_lib, "edge_weights", boom)
    monkeypatch.setattr(lv.sampler_lib, "build_edge_sampler", boom)
    r = largevis(_x(), jax.random.key(7), cfg=cfg)
    assert np.array_equal(np.asarray(r.y), baseline)


def test_fingerprint_rejects_foreign_checkpoint(tmp_path, baseline):
    """A checkpoint directory written by a DIFFERENT run (other data/key)
    is refused with a warning and every stage recomputes — resuming it
    would silently mix two runs' states."""
    cfg = _ckpt_cfg(tmp_path)
    other_x = np.random.default_rng(9).normal(size=(N, D)).astype(np.float32)
    largevis(other_x, jax.random.key(7), cfg=cfg)          # fills the dir
    with pytest.warns(RuntimeWarning, match="different run"):
        r = largevis(_x(), jax.random.key(7), cfg=cfg)
    assert np.array_equal(np.asarray(r.y), baseline)


def test_resume_false_ignores_checkpoints(tmp_path, baseline):
    cfg = _ckpt_cfg(tmp_path)
    largevis(_x(), jax.random.key(7), cfg=cfg)
    cfg_no = _ckpt_cfg(tmp_path, resume=False)
    r = largevis(_x(), jax.random.key(7), cfg=cfg_no)      # full recompute
    assert np.array_equal(np.asarray(r.y), baseline)


def test_completed_run_resumes_to_same_result(tmp_path, baseline):
    """Rerunning after a SUCCESSFUL checkpointed fit just reloads the
    final layout — same bits, near-zero layout time."""
    cfg = _ckpt_cfg(tmp_path)
    largevis(_x(), jax.random.key(7), cfg=cfg)
    r = largevis(_x(), jax.random.key(7), cfg=cfg)
    assert np.array_equal(np.asarray(r.y), baseline)


# ---------------------------------------------------------------------------
# real SIGKILL in a subprocess (no cleanup, no flushing)
# ---------------------------------------------------------------------------

_WORKER = r"""
import os, sys
if os.environ.get("RESUME_DIST") == "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, SRC)
import dataclasses
import numpy as np, jax
from repro.configs.largevis_default import LargeVisConfig, CheckpointConfig
from repro.core.largevis import largevis
from repro.runtime.fault_tolerance import FaultInjector

dist = os.environ.get("RESUME_DIST") == "1"
extra = dict(distributed=True, data_shards=4, sync_every=8) if dist else {}
cfg = LargeVisConfig(n_neighbors=8, n_trees=2, n_explore_iters=1, window=16,
                     perplexity=6.0, samples_per_node=120, batch_size=64,
                     steps_per_dispatch=10, **extra)
if os.environ.get("RESUME_CKPT"):
    cfg = dataclasses.replace(cfg, checkpoint=CheckpointConfig(
        directory=os.environ["RESUME_CKPT"], every_chunks=1))
x = np.random.default_rng(0).normal(size=(384, 16)).astype(np.float32)
site = os.environ.get("RESUME_SITE")
fault = (FaultInjector({site: {int(os.environ["RESUME_HIT"]): "kill"}})
         if site else None)
res = largevis(x, jax.random.key(7), cfg=cfg, fault=fault)
np.save(os.environ["RESUME_OUT"], np.asarray(res.y))
print("WORKER_DONE")
"""


def _run_worker(tmp_path, out_name, *, site=None, hit=0, ckpt=None,
                dist=False):
    env = dict(os.environ,
               RESUME_OUT=str(tmp_path / out_name),
               RESUME_SITE=site or "", RESUME_HIT=str(hit),
               RESUME_CKPT=str(ckpt) if ckpt else "",
               RESUME_DIST="1" if dist else "")
    env.pop("XLA_FLAGS", None)
    script = _WORKER.replace("SRC", repr(os.path.join(REPO, "src")))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


def _kill_resume_roundtrip(tmp_path, *, site, hit, dist=False):
    ckpt = tmp_path / "ckpt"
    killed = _run_worker(tmp_path, "na.npy", site=site, hit=hit, ckpt=ckpt,
                         dist=dist)
    assert killed.returncode == -9, (killed.returncode, killed.stderr[-2000:])
    resumed = _run_worker(tmp_path, "resumed.npy", ckpt=ckpt, dist=dist)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    clean = _run_worker(tmp_path, "clean.npy", dist=dist)
    assert clean.returncode == 0, clean.stderr[-2000:]
    y_resumed = np.load(tmp_path / "resumed.npy")
    y_clean = np.load(tmp_path / "clean.npy")
    assert np.array_equal(y_resumed, y_clean)


def test_sigkill_mid_layout_resume_bitwise(tmp_path):
    """Tier-1 representative: a REAL SIGKILL two committed layout chunks
    in, restart, bitwise-equal to an uninterrupted subprocess run."""
    _kill_resume_roundtrip(tmp_path, site="layout_saved", hit=2)


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("site,hit", [
    ("stage:graph", 0), ("stage:weights", 0), ("stage:samplers", 0),
    ("layout_saved", 0),
])
def test_sigkill_matrix_single_device(tmp_path, site, hit):
    _kill_resume_roundtrip(tmp_path, site=site, hit=hit)


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("site,hit", [
    ("stage:graph", 0), ("stage:weights", 0),
    ("layout_round", 1), ("layout_saved", 1),
])
def test_sigkill_matrix_distributed(tmp_path, site, hit):
    """Kill/resume on the 4-device local-SGD path: the layout checkpoint
    is cut at a sync boundary where every replica is bitwise-identical,
    so the re-broadcast resume continues the exact distributed run."""
    _kill_resume_roundtrip(tmp_path, site=site, hit=hit, dist=True)


# ---------------------------------------------------------------------------
# mid-save crash: a torn checkpoint write is invisible
# ---------------------------------------------------------------------------

def test_torn_layout_checkpoint_is_ignored(tmp_path, baseline):
    """Simulate a crash inside a checkpoint write (tmp dir present, no
    _COMMITTED rename): the resume must fall back to the previous
    committed chunk and still land bitwise on the oracle."""
    cfg = _ckpt_cfg(tmp_path, keep=3)
    with pytest.raises(InjectedFault):
        largevis(_x(), jax.random.key(7), cfg=cfg,
                 fault=FaultInjector({"layout_saved": {2: "exception"}}))
    layout_dir = tmp_path / "ckpt" / "layout"
    steps = sorted(int(p.name.split("_")[1])
                   for p in layout_dir.glob("step_*"))
    # tear the newest committed save: as if the rename never happened
    newest = layout_dir / f"step_{steps[-1]}"
    (newest / "_COMMITTED").unlink()
    r = largevis(_x(), jax.random.key(7), cfg=cfg)
    assert np.array_equal(np.asarray(r.y), baseline)
