"""Sharded KNN-graph construction pipeline (core/knn_sharded.py).

The multi-device assertions run in a subprocess with 8 host CPU devices
(``--xla_force_host_platform_device_count=8``) so the main pytest process
keeps its single-device jax config.  Covered:

  * recall >= 0.95 vs the `brute_force_knn` oracle on ~2k-point Gaussian
    clusters, and within 1% of the single-device `build_knn_graph` recall
  * an N-not-divisible-by-shard-count case (N=2003 over 8 shards)
  * exact mode (n_trees=0): the ring pass is distributed brute force —
    recall 1.0 and oracle-identical distances
  * peak-buffer shape check: every fused `topk_sqdist` fold traced by
    the sharded pipeline operates on at most (ceil(N/P), d) slabs — no
    (N, N) distance matrix — and the lowered per-device HLO contains no
    N x N or N x (K^2+K) f32 buffer (no all-gathered candidates)
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, SRC)
sys.path.insert(0, TESTS)
import math
import jax, jax.numpy as jnp, numpy as np

import hlo_checks

from repro.configs.largevis_default import LargeVisConfig
from repro.core import knn as knn_lib
from repro.core import knn_sharded
from repro.core.knn_sharded import build_knn_graph_sharded
from repro.data.synthetic import gaussian_mixture
from repro.kernels import ops
from repro.launch.mesh import make_data_mesh

assert len(jax.devices()) == 8, jax.devices()
KEY = jax.random.key(0)

# ---- record every fused topk_sqdist operand shape the pipeline traces ----
TILE_SHAPES = []
_real_topk = ops.topk_sqdist
def _recording_topk(a, b, k, **kw):
    TILE_SHAPES.append((tuple(a.shape), tuple(b.shape)))
    return _real_topk(a, b, k, **kw)
ops.topk_sqdist = _recording_topk

# ---- 1) 8-way shard vs oracle and vs single-device -----------------------
N, P = 2000, 8
x, _ = gaussian_mixture(KEY, N, 32, 8)
true_idx, true_d = knn_lib.brute_force_knn(x, 15)
cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                     window=32, distributed=True)
TILE_SHAPES.clear()
idx_s, dist_s = build_knn_graph_sharded(x, KEY, cfg)
r_sharded = knn_lib.knn_recall(idx_s, true_idx)
assert r_sharded >= 0.95, f"sharded recall vs oracle too low: {r_sharded}"

# no operand as large as the full point set: every fused fold is bounded
# by the per-shard slab (ring-carried streaming top-k, not an (N, N)
# matrix)
n_loc = math.ceil(N / P)
assert TILE_SHAPES, "sharded pipeline did not route through kernels.ops"
for sa, sb in TILE_SHAPES:
    assert sa[0] <= n_loc and sb[0] <= n_loc, (sa, sb)

# lowered per-device HLO holds no (N, N) f32 and no all-gathered candidate
# buffer (N, K*K + K)
fn = knn_sharded._make_sharded_fn(
    make_data_mesh(0), "data", n_shards=P, n_real=N, k=15, n_trees=4,
    depth=5, iters=2, sample=0)
hlo = fn.lower(x, jnp.arange(N, dtype=jnp.int32),
               jnp.zeros((32, 20), jnp.float32),
               jnp.zeros((1,), jnp.int32)).as_text()
# per-shard tiles are present; the full matrices are not
assert hlo_checks.has_buffer(hlo, (n_loc, n_loc), "f32"), (
    "expected per-shard distance tiles")
hlo_checks.assert_no_buffer(hlo, (N, N),
                            what="full NxN distance matrix materialized")
C = 15 * 15 + 15
hlo_checks.assert_no_buffer(hlo, (N, C),
                            what="candidate buffer all-gathered")

idx_1, _ = knn_lib.build_knn_graph(
    x, KEY, LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                           window=32))
r_single = knn_lib.knn_recall(idx_1, true_idx)
assert r_sharded >= r_single - 0.01, (r_sharded, r_single)
print("SHARDED_RECALL_OK", round(r_sharded, 4), round(r_single, 4))

# ---- 2) N not divisible by the shard count -------------------------------
x2, _ = gaussian_mixture(jax.random.key(1), 2003, 32, 8)
true2, _ = knn_lib.brute_force_knn(x2, 15)
idx2, dist2 = build_knn_graph_sharded(x2, KEY, cfg)
assert idx2.shape == (2003, 15) and dist2.shape == (2003, 15)
idx2_n = np.asarray(idx2)
assert ((idx2_n >= 0) & (idx2_n < 2003)).all(), "padded ids leaked"
assert (idx2_n != np.arange(2003)[:, None]).all(), "self edges"
r2 = knn_lib.knn_recall(idx2, true2)
assert r2 >= 0.95, f"indivisible-N recall too low: {r2}"
print("INDIVISIBLE_OK", round(r2, 4))

# ---- 3) exact mode == distributed brute force ----------------------------
cfg0 = LargeVisConfig(n_neighbors=15, n_trees=0, n_explore_iters=0,
                      distributed=True)
idx_e, dist_e = build_knn_graph_sharded(x2, KEY, cfg0)
assert knn_lib.knn_recall(idx_e, true2) == 1.0
_, td = knn_lib.brute_force_knn(x2, 15)
np.testing.assert_allclose(np.sort(np.asarray(dist_e)),
                           np.sort(np.asarray(td)), atol=1e-3)
print("EXACT_MODE_OK")
"""


@pytest.mark.slow
def test_sharded_knn_multi_device():
    script = (_SCRIPT
              .replace("SRC", repr(os.path.join(REPO, "src")))
              .replace("TESTS", repr(os.path.join(REPO, "tests"))))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED_RECALL_OK" in proc.stdout
    assert "INDIVISIBLE_OK" in proc.stdout
    assert "EXACT_MODE_OK" in proc.stdout


def test_sharded_knn_single_device_plumbing():
    """Tier-1 smoke: the sharded pipeline on a 1-device mesh agrees with
    the oracle (the ring degenerates to one local tile)."""
    from repro.configs.largevis_default import LargeVisConfig
    from repro.core import knn as knn_lib
    from repro.core.knn_sharded import build_knn_graph_sharded
    from repro.data.synthetic import gaussian_mixture

    x, _ = gaussian_mixture(jax.random.key(2), 403, 16, 4)
    true_idx, _ = knn_lib.brute_force_knn(x, 10)
    cfg = LargeVisConfig(n_neighbors=10, n_trees=4, n_explore_iters=1,
                         distributed=True)
    idx, dist = build_knn_graph_sharded(x, jax.random.key(3), cfg)
    assert idx.shape == (403, 10)
    idx_n = np.asarray(idx)
    assert (idx_n != np.arange(403)[:, None]).all()
    r = knn_lib.knn_recall(idx, true_idx)
    assert r >= 0.95, r
    # exact mode is the oracle itself
    cfg0 = LargeVisConfig(n_neighbors=10, n_trees=0, n_explore_iters=0,
                          distributed=True)
    idx_e, _ = build_knn_graph_sharded(x, jax.random.key(3), cfg0)
    assert knn_lib.knn_recall(idx_e, true_idx) == 1.0
