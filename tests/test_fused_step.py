"""Fully-fused edge-step kernel (kernels/largevis_step.py) + its routing.

Covers: bit-reproducibility against the pure-jnp oracle (including batches
dense with duplicate i/j/neg indices, and a numpy sequential loop that pins
the canonical per-edge update order), gather-mode equivalence, tile padding
for odd (collision-capped) batches and multi-tile batches, collision-masked
negatives leaving their target rows bitwise untouched, trajectory parity
fused-vs-split through all three drivers (scan engine, per-step loop,
shard_map local-SGD), and HLO checks that the fused path materializes no
gather/concat intermediate buffers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import hlo_checks

from repro.configs.largevis_default import LargeVisConfig
from repro.core import layout as layout_lib
from repro.core import sampler as sampler_lib
from repro.kernels import ops, ref
from repro.kernels.largevis_step import fused_edge_step
from repro.runtime.compat import make_mesh

KEY = jax.random.key(11)
GAMMA, A, CLIP = 7.0, 1.0, 5.0

# the bitwise contract is against the *compiled* oracle: eager op-by-op
# execution skips the multiply-add fusion XLA applies inside any jit
# (including the kernel's), which shifts values by ~1 ulp
_ref_step = jax.jit(ref.fused_edge_step_ref,
                    static_argnames=("gamma", "a", "clip", "eps"))


def _rand_batch(N, B, M, s=2, seed=0):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 5)
    y = jax.random.normal(ks[0], (N, s), jnp.float32)
    i = jax.random.randint(ks[1], (B,), 0, N)
    j = jax.random.randint(ks[2], (B,), 0, N)
    negs = jax.random.randint(ks[3], (B, M), 0, N)
    mask = ((negs != i[:, None]) & (negs != j[:, None])).astype(jnp.float32)
    return y, i, j, negs, mask


@pytest.mark.parametrize("N,B,tile", [
    (300, 64, 64),       # exact tile fit
    (300, 37, 16),       # odd batch -> padded remainder tile
    (500, 1500, 512),    # multi-tile grid + padding (T=3)
])
def test_kernel_matches_ref_oracle_bitwise(N, B, tile):
    y, i, j, negs, mask = _rand_batch(N, B, 5)
    got = fused_edge_step(y, i, j, negs, mask, 0.37, gamma=GAMMA, a=A,
                          clip=CLIP, tile=tile, interpret=True)
    want = _ref_step(y, i, j, negs, mask, 0.37, gamma=GAMMA, a=A, clip=CLIP)
    assert np.array_equal(np.asarray(got), np.asarray(want)), float(
        np.abs(np.asarray(got) - np.asarray(want)).max())


def test_duplicate_indices_accumulate_in_canonical_order():
    """A tiny embedding makes every batch dense with duplicates (the same
    row drawn as i, j and negative, many times over).  The kernel, the ref
    oracle and a numpy sequential loop in the canonical per-edge order
    [i_e, j_e, negs_e,0..M-1] must all agree bitwise — accumulation, not
    last-write-wins, and one ordering contract everywhere."""
    N, B, M, s = 8, 128, 5, 2
    y, i, j, negs, mask = _rand_batch(N, B, M, s, seed=3)
    lr = 0.21
    got = fused_edge_step(y, i, j, negs, mask, lr, gamma=GAMMA, a=A,
                          clip=CLIP, tile=32, interpret=True)
    want = _ref_step(y, i, j, negs, mask, lr, gamma=GAMMA, a=A, clip=CLIP)
    assert np.array_equal(np.asarray(got), np.asarray(want))

    # numpy sequential loop in the canonical order: pins accumulate-not-
    # overwrite semantics (allclose, not bitwise — numpy does not fuse
    # multiply-adds the way the compiled grads do)
    gi, gj, gneg = ref.largevis_grads_ref(y[i], y[j], y[negs], gamma=GAMMA,
                                          a=A, clip=CLIP, neg_mask=mask)
    yn = np.asarray(y).copy()
    ui = np.asarray(-jnp.float32(lr) * gi)
    uj = np.asarray(-jnp.float32(lr) * gj)
    un = np.asarray(-jnp.float32(lr) * gneg)
    i_n, j_n, n_n = np.asarray(i), np.asarray(j), np.asarray(negs)
    for e in range(B):
        yn[i_n[e]] += ui[e]
        yn[j_n[e]] += uj[e]
        for m in range(M):
            yn[n_n[e, m]] += un[e, m]
    np.testing.assert_allclose(np.asarray(got), yn, atol=1e-4, rtol=1e-4)


def test_gather_modes_bitwise_identical():
    """gather="take" (vectorized) and gather="loop" (per-row dynamic
    slices, the conservative TPU path) are the same kernel."""
    y, i, j, negs, mask = _rand_batch(400, 200, 5, seed=5)
    a = fused_edge_step(y, i, j, negs, mask, 0.5, gamma=GAMMA, a=A,
                        clip=CLIP, tile=64, interpret=True, gather="take")
    b = fused_edge_step(y, i, j, negs, mask, 0.5, gamma=GAMMA, a=A,
                        clip=CLIP, tile=64, interpret=True, gather="loop")
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_masked_negatives_leave_rows_untouched():
    """A collision-masked negative contributes exactly zero: rows that are
    only ever referenced through masked negatives keep their bits."""
    N, B, M = 50, 16, 5
    ks = jax.random.split(KEY, 4)
    y = jax.random.normal(ks[0], (N, 2), jnp.float32)
    # edges live entirely in rows [0, 40); negatives all point at row 47,
    # every one masked out
    i = jax.random.randint(ks[1], (B,), 0, 40)
    j = jax.random.randint(ks[2], (B,), 0, 40)
    negs = jnp.full((B, M), 47, jnp.int32)
    mask = jnp.zeros((B, M), jnp.float32)
    out = fused_edge_step(y, i, j, negs, mask, 0.8, gamma=GAMMA, a=A,
                          clip=CLIP, interpret=True)
    assert np.array_equal(np.asarray(out[47]), np.asarray(y[47]))
    # the positive-pair updates still landed
    assert not np.array_equal(np.asarray(out[:40]), np.asarray(y[:40]))
    # and rows nobody references at all keep their bits too
    assert np.array_equal(np.asarray(out[40:47]), np.asarray(y[40:47]))
    assert np.array_equal(np.asarray(out[48:]), np.asarray(y[48:]))


def test_padding_rows_are_noops():
    """Tile padding points padded edges at row 0 with zero gradients; a
    batch whose real edges avoid row 0 must leave row 0 bitwise intact."""
    N, B, M = 64, 13, 5          # 13 pads up to 16 with tile=16
    ks = jax.random.split(KEY, 4)
    y = jax.random.normal(ks[0], (N, 2), jnp.float32)
    i = jax.random.randint(ks[1], (B,), 1, N)
    j = jax.random.randint(ks[2], (B,), 1, N)
    negs = jax.random.randint(ks[3], (B, M), 1, N)
    mask = ((negs != i[:, None]) & (negs != j[:, None])).astype(jnp.float32)
    out = fused_edge_step(y, i, j, negs, mask, 0.9, gamma=GAMMA, a=A,
                          clip=CLIP, tile=16, interpret=True)
    assert np.array_equal(np.asarray(out[0]), np.asarray(y[0]))


def test_ops_impl_routes():
    """ops.largevis_edge_step: "fused"/"pallas"/"auto" hit the kernel,
    "ref" hits the oracle, and all agree bitwise (compiled, as the step
    bodies use them — eager execution skips XLA's multiply-add fusion)."""
    y, i, j, negs, mask = _rand_batch(200, 96, 5, seed=7)
    outs = [np.asarray(jax.jit(
        lambda *args: ops.largevis_edge_step(
            *args, gamma=GAMMA, a=A, clip=CLIP, impl=impl)
    )(y, i, j, negs, mask, 0.3)) for impl in ("fused", "pallas", "ref",
                                              "auto")]
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)


def test_fused_step_supported_on_cpu():
    # interpret mode has no VMEM residency bound
    assert ops.fused_step_supported(10_000_000, 2)


# ---------------------------------------------------------------------------
# driver-level trajectory parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def odd_graph():
    """603 nodes -> collision-capped batch 301 (odd): every dispatch runs
    the kernel's padded-tile path."""
    rng = np.random.default_rng(9)
    n, k = 603, 8
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (n, k)).astype(np.float32)
    es = sampler_lib.build_edge_sampler(idx, w)
    ns = sampler_lib.build_negative_sampler(idx, w)
    return n, es, ns


def _run(n, es, ns, **over):
    over = {"samples_per_node": 80, "batch_size": 4096, **over}
    return layout_lib.run_layout(KEY, es, ns, n, LargeVisConfig(**over))


def test_scan_driver_parity_fused_vs_split(odd_graph):
    n, es, ns = odd_graph
    assert layout_lib._collision_capped_batch(4096, n) % 2 == 1
    r_fused = _run(n, es, ns, fused_step=True)
    r_split = _run(n, es, ns, fused_step=False)
    assert r_fused.steps == r_split.steps
    a, b = np.asarray(r_fused.y), np.asarray(r_split.y)
    assert np.array_equal(a, b), float(np.abs(a - b).max())


def test_loop_driver_parity_fused_vs_split(odd_graph):
    n, es, ns = odd_graph
    r_fused = _run(n, es, ns, fused_step=True, steps_per_dispatch=1,
                   samples_per_node=20)
    r_split = _run(n, es, ns, fused_step=False, steps_per_dispatch=1,
                   samples_per_node=20)
    assert np.array_equal(np.asarray(r_fused.y), np.asarray(r_split.y))


def test_local_sgd_driver_parity_fused_vs_split(odd_graph):
    n, es, ns = odd_graph
    mesh = make_mesh((1,), ("data",))
    cfg_f = LargeVisConfig(sync_every=4, samples_per_node=32, batch_size=256,
                           fused_step=True)
    cfg_s = dataclasses.replace(cfg_f, fused_step=False)
    r_f = layout_lib.run_layout_local_sgd(KEY, es, ns, n, cfg_f, mesh)
    r_s = layout_lib.run_layout_local_sgd(KEY, es, ns, n, cfg_s, mesh)
    assert np.array_equal(np.asarray(r_f.y), np.asarray(r_s.y))


# ---------------------------------------------------------------------------
# HLO: the fused path materializes no gather/concat intermediates
# ---------------------------------------------------------------------------

def test_fused_hlo_emits_no_split_buffers():
    """The split step materializes a (B*(2+M), s) concatenated update
    buffer (and flattened (B, M*s) kernel operands on the Pallas-grads
    path); the fused lowering must contain neither."""
    n, B, M, s = 2000, 256, 5, 2
    rng = np.random.default_rng(1)
    idx = rng.integers(0, n, (n, 8)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (n, 8)).astype(np.float32)
    es = sampler_lib.build_edge_sampler(idx, w)
    ns = sampler_lib.build_negative_sampler(idx, w)
    cfg = LargeVisConfig(n_negatives=M, batch_size=B)
    kwargs = layout_lib._step_kwargs(es, ns, n, cfg, B)
    y0 = jax.random.normal(KEY, (n, s), jnp.float32)

    def lower(fused):
        kw = dict(kwargs, fused_step=fused)
        return layout_lib.layout_step.lower(
            y0, KEY, jnp.float32(0.1), **kw).as_text()

    concat_buf = ((2 + M) * B, s)
    flat_neg = (B, M * s)
    hlo_fused = lower(True)
    hlo_checks.assert_no_buffer(hlo_fused, concat_buf, "f32",
                                what="concatenated update buffer")
    hlo_checks.assert_no_buffer(hlo_fused, flat_neg, "f32",
                                what="flattened negative operand")
    # contrast: the split path really does build the concat update buffer
    hlo_split = lower(False)
    assert hlo_checks.has_buffer(hlo_split, concat_buf, "f32")
