"""Streaming fused distance->top-k: kernel vs oracle, HLO, consumers.

The contract (kernels/knn_topk.py::topk_sqdist vs ref.topk_sqdist_ref):
bitwise-identical (ids, dists) at equal (bm, bn) tiles — the kernel's
max-extraction merge reproduces lax.top_k's earliest-index tie order
exactly — plus structural HLO assertions that the fused consumers
(`brute_force_knn`, `forest_knn` window candidates, the sharded ring
step) materialize no (M, N) distance buffer and no post-kernel
sort/top_k, and that `forest_knn` compiles one scan body regardless of
n_trees.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import hlo_checks

from repro.configs.largevis_default import LargeVisConfig
from repro.core import knn as knn_lib
from repro.data.synthetic import gaussian_mixture
from repro.kernels import ops, ref
from repro.kernels.knn_topk import topk_sqdist

KEY = jax.random.key(3)


def _pair(m, n, d, seed=0):
    ka, kb = jax.random.split(jax.random.fold_in(KEY, seed))
    return (jax.random.normal(ka, (m, d), jnp.float32),
            jax.random.normal(kb, (n, d), jnp.float32))


# ---------------------------------------------------------------------------
# kernel == oracle, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lane", [128, 1])
@pytest.mark.parametrize("merge", ["concat", "tile"])
@pytest.mark.parametrize("m,n,d,k,bm,bn", [
    (64, 64, 32, 5, 32, 32),        # even multi-tile
    (100, 80, 7, 5, 32, 16),        # odd M, N, d
    (33, 17, 3, 20, 16, 8),         # k > bn AND k > N (invalid tail)
    (256, 512, 100, 20, 64, 128),   # larger sweep
    (130, 1, 5, 1, 64, 8),          # single column
])
def test_kernel_matches_oracle_bitwise(m, n, d, k, bm, bn, merge, lane):
    """Kernel == oracle bitwise at equal (bm, bn, lane), for BOTH oracle
    merge formulations (concat vs tile-shortlist — themselves required
    to be bit-identical to each other)."""
    a, b = _pair(m, n, d, seed=m + n)
    ri, rd = ref.topk_sqdist_ref(a, b, k, bm=bm, bn=bn, lane=lane,
                                 merge=merge)
    ki, kd = topk_sqdist(a, b, k, bm=bm, bn=bn, lane=lane, interpret=True)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(rd), np.asarray(kd))
    # and the answer is actually the k nearest
    dn = ((np.asarray(a, np.float64)[:, None]
           - np.asarray(b, np.float64)[None]) ** 2).sum(-1)
    kk = min(k, n)
    np.testing.assert_allclose(
        np.sort(np.asarray(rd, np.float64), 1)[:, :kk],
        np.sort(dn, 1)[:, :kk], atol=1e-3, rtol=1e-4)


def test_kernel_matches_oracle_self_edges_and_state():
    """Self-exclusion, running-state seeding and dedup agree bitwise; a
    second fold of the same candidates with dedup is a no-op."""
    x, _ = gaussian_mixture(KEY, 200, 16, 4)
    ids = jnp.arange(200, dtype=jnp.int32)
    kw = dict(a_ids=ids, b_ids=ids, bm=64, bn=64, lane=1)
    ri, rd = ref.topk_sqdist_ref(x, x, 8, **kw)
    ki, kd = topk_sqdist(x, x, 8, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(rd), np.asarray(kd))
    assert (np.asarray(ri) != np.arange(200)[:, None]).all(), "self edges"
    r2 = ref.topk_sqdist_ref(x, x, 8, init_ids=ri, init_dists=rd,
                             dedup=True, **kw)
    k2 = topk_sqdist(x, x, 8, init_ids=ki, init_dists=kd, dedup=True,
                     interpret=True, **kw)
    for got, want in zip(k2, r2):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # dedup re-fold of the same candidates must be a no-op
    np.testing.assert_array_equal(np.asarray(r2[0]), np.asarray(ri))


def test_kernel_matches_oracle_duplicate_ids():
    """Duplicate ids across column tiles (same id, different rows of b)
    with dedup: the first-seen copy wins in BOTH impls, bitwise."""
    a, b = _pair(48, 64, 8, seed=7)
    b_ids = (jnp.arange(64) % 29).astype(jnp.int32)   # dups across tiles
    kw = dict(b_ids=b_ids, dedup=True, bm=16, bn=16, lane=1)
    ri, rd = ref.topk_sqdist_ref(a, b, 6, **kw)
    ki, kd = topk_sqdist(a, b, 6, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(rd), np.asarray(kd))
    for row in np.asarray(ri):
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real), "dup id survived"


def test_kernel_matches_oracle_codes():
    """Bucket-code masking (the sharded ring's forest mask) agrees."""
    x, _ = gaussian_mixture(KEY, 160, 12, 4)
    ids = jnp.arange(160, dtype=jnp.int32)
    codes = (jax.random.uniform(KEY, (160, 3)) * 4).astype(jnp.int32)
    kw = dict(a_ids=ids, b_ids=ids, codes_a=codes, codes_b=codes,
              bm=32, bn=64, lane=1)
    ri, rd = ref.topk_sqdist_ref(x, x, 8, **kw)
    ki, kd = topk_sqdist(x, x, 8, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(rd), np.asarray(kd))
    # every surviving neighbor shares a bucket in at least one tree
    cn = np.asarray(codes)
    for i, row in enumerate(np.asarray(ri)):
        for g in row[row >= 0]:
            assert (cn[i] == cn[g]).any(), (i, g)


def test_oracle_results_sorted_and_exact():
    """Streaming fold == materialize-then-top_k on the same inputs
    (identical neighbor sets; ascending distances)."""
    x, _ = gaussian_mixture(KEY, 500, 24, 4)
    ids = jnp.arange(500, dtype=jnp.int32)
    ri, rd = ref.topk_sqdist_ref(x, x, 10, a_ids=ids, b_ids=ids,
                                 bm=128, bn=128)
    rd_n = np.asarray(rd)
    assert (np.diff(rd_n, axis=1) >= 0).all(), "distances not ascending"
    dd = np.asarray(ref.pairwise_sqdist_ref(x, x), np.float64)
    np.fill_diagonal(dd, np.inf)
    want = np.sort(dd, 1)[:, :10]
    np.testing.assert_allclose(np.sort(rd_n, 1), want, atol=1e-3)
    want_ids = np.argsort(dd, 1, kind="stable")[:, :10]
    assert (np.sort(np.asarray(ri), 1) == np.sort(want_ids, 1)).mean() > 0.999


# ---------------------------------------------------------------------------
# HLO: fused consumers hold no (M, N) buffer, no post-kernel sort/top_k
# ---------------------------------------------------------------------------

def test_hlo_brute_force_no_distance_matrix():
    x = jnp.zeros((8192, 32), jnp.float32)
    # fused path: no (M, N) buffer, no (tile, N) buffer, no sort, no top_k
    hlo = knn_lib.brute_force_knn.lower(x, 10, tile=512,
                                        impl="fused").as_text()
    hlo_checks.assert_no_buffer(hlo, (8192, 8192),
                                what="full NxN distance matrix")
    hlo_checks.assert_no_buffer(hlo, (512, 8192),
                                what="materialized (tile, N) row-tile")
    hlo_checks.assert_no_op(hlo, "sort", "top_k",
                            what="post-kernel sort/top_k on the fused path")
    # the streaming oracle path holds no (M, N)/(tile, N) buffer either
    hlo_ref = knn_lib.brute_force_knn.lower(x, 10, tile=2048,
                                            impl="ref").as_text()
    hlo_checks.assert_no_buffer(hlo_ref, (8192, 8192))
    hlo_checks.assert_no_buffer(hlo_ref, (2048, 8192),
                                what="(tile, N) buffer on the ref path")


def test_hlo_forest_window_fused_no_sort_topk():
    x = jnp.zeros((2048, 16), jnp.float32)
    hlo = knn_lib.forest_knn.lower(x, KEY, n_trees=4, depth=5, k=10,
                                   window=32, impl="fused").as_text()
    hlo_checks.assert_no_op(hlo, "top_k",
                            what="post-kernel top_k on the fused window path")
    # the only sorts are the per-tree argsort of bucket codes (one scan
    # body) — the merge itself is sort-free
    assert hlo_checks.count_op(hlo, "sort") == hlo_checks.count_op(
        knn_lib.forest_knn.lower(x, KEY, n_trees=8, depth=5, k=10,
                                 window=32, impl="fused").as_text(),
        "sort"), (
        "sort count grows with n_trees — tree body unrolled or the "
        "fused merge sorts")


def test_hlo_sharded_ring_fused_no_buffers():
    from repro.core import knn_sharded
    from repro.launch.mesh import make_data_mesh
    N, k = 1024, 10
    fn = knn_sharded._make_sharded_fn(
        make_data_mesh(1), "data", n_shards=1, n_real=N, k=k, n_trees=4,
        depth=5, iters=0, sample=0, impl="fused")
    hlo = fn.lower(jnp.zeros((N, 16), jnp.float32),
                   jnp.arange(N, dtype=jnp.int32),
                   jnp.zeros((16, 20), jnp.float32),
                   jnp.zeros((1,), jnp.int32)).as_text()
    hlo_checks.assert_no_op(hlo, "sort", "top_k",
                            what="post-kernel sort/top_k in the fused ring")
    hlo_checks.assert_no_buffer(hlo, (N, N),
                                what="(n_loc, n_loc) distance buffer")


# ---------------------------------------------------------------------------
# forest scan vs the PR-3 per-tree loop (materialize + merge_candidates)
# ---------------------------------------------------------------------------

def _pr3_window_candidates(x, code, k, window):
    """The PR-3 formulation: materialized (W, 3W) pairwise tiles + top_k
    + argsort-based merge_candidates (kept here as the semantic
    reference for the fused fold)."""
    N, d = x.shape
    W = window
    order = jnp.argsort(code)
    Np = int(np.ceil(N / W)) * W
    pad = Np - N
    order_p = jnp.concatenate(
        [order, jnp.full((pad,), N, jnp.int32)]) if pad else order
    xs = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])[order_p]
    nb = Np // W
    blocks = xs.reshape(nb, W, d)
    ids = order_p.reshape(nb, W)

    def block_dists(j):
        a = blocks[j]
        lo = jnp.clip(j - 1, 0, nb - 1)
        hi = jnp.clip(j + 1, 0, nb - 1)
        b = jnp.concatenate([blocks[lo], blocks[j], blocks[hi]])
        bid = jnp.concatenate([ids[lo], ids[j], ids[hi]])
        dd = ops.pairwise_sqdist(a, b)
        dd = jnp.where(bid[None, :] == N, knn_lib.INF, dd)
        kk = min(k + 1, 3 * W)
        nd, ni = jax.lax.top_k(-dd, kk)
        return bid[ni], -nd

    cid, cd = jax.lax.map(block_dists, jnp.arange(nb))
    kk = cid.shape[-1]
    flat_ids = cid.reshape(Np, kk)[:N]
    flat_d = cd.reshape(Np, kk)[:N]
    inv = jnp.zeros((N,), jnp.int32).at[order].set(
        jnp.arange(N, dtype=jnp.int32))
    return flat_ids[inv], flat_d[inv]


def test_forest_fused_matches_pr3_loop():
    """The fused per-tree fold selects the same neighbor sets as the old
    materialize-then-merge loop (distances agree to f32 tolerance; the
    two formulations differ only in summation order, so a vanishing
    fraction of exact-boundary ties may swap)."""
    x, _ = gaussian_mixture(KEY, 1000, 32, 8)
    N, k, n_trees, window = 1000, 10, 3, 32
    depth = knn_lib._auto_depth(N, 64)
    got_i, got_d = knn_lib.forest_knn(x, KEY, n_trees=n_trees, depth=depth,
                                      k=k, window=window)
    codes = knn_lib.hash_codes(x, KEY, n_trees, depth)
    run = None
    self_idx = jnp.arange(N)
    for t in range(n_trees):
        cid, cd = _pr3_window_candidates(x, codes[:, t], k, window)
        if run is not None:
            cid = jnp.concatenate([run[0], cid], axis=1)
            cd = jnp.concatenate([run[1], cd], axis=1)
        run = knn_lib.merge_candidates(cid, cd, k, self_idx=self_idx)
    want_i, want_d = run
    same = (np.sort(np.asarray(got_i), 1)
            == np.sort(np.asarray(want_i), 1)).all(1)
    assert same.mean() >= 0.995, f"neighbor sets diverge: {same.mean()}"
    np.testing.assert_allclose(
        np.sort(np.asarray(got_d), 1)[same],
        np.sort(np.asarray(want_d), 1)[same], atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end engine path
# ---------------------------------------------------------------------------

def test_engine_path_recall():
    """build_knn_graph through the fused stage-1 pipeline reaches >= 0.95
    recall vs the (itself fused) brute-force oracle."""
    x, _ = gaussian_mixture(KEY, 2000, 32, 8)
    true_idx, _ = knn_lib.brute_force_knn(x, 15)
    cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=3,
                         window=32)
    idx, dist = knn_lib.build_knn_graph(x, KEY, cfg)
    r = knn_lib.knn_recall(idx, true_idx)
    assert r >= 0.95, r
    assert (np.asarray(idx) != np.arange(2000)[:, None]).all()
    assert (np.diff(np.asarray(dist), axis=1) >= 0).all()


def test_knn_recall_tiled_matches_untiled():
    """The tiled recall equals the one-shot (N, K, K) formulation and
    never materializes the full match tensor."""
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 999, (999, 7)), jnp.int32)
    true = jnp.asarray(rng.integers(0, 999, (999, 7)), jnp.int32)
    got = knn_lib.knn_recall(idx, true, tile=128)       # odd: 999 % 128 != 0
    want = float(jnp.mean(
        (idx[:, :, None] == true[:, None, :]).any(-1).astype(jnp.float32)))
    assert abs(got - want) < 1e-6
    hlo = knn_lib._recall_hits.lower(
        jnp.zeros((1024, 7), jnp.int32), jnp.zeros((1024, 7), jnp.int32),
        128).as_text()
    hlo_checks.assert_no_buffer(hlo, (1024, 7, 7),
                                what="full (N, K, K) match tensor")
