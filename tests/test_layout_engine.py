"""Scan-fused layout engine (core/layout_engine.py) vs the per-step loop.

Covers: trajectory equivalence (the scanned driver must reproduce the
per-step Python loop bitwise at a fixed seed, including remainder chunks),
buffer donation (the chunk must alias y in -> y out, no doubled peak
buffer), the tile-padded kernel entry, and end-to-end layout quality
through the default engine path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.largevis_default import LargeVisConfig
from repro.core import layout as layout_lib
from repro.core import layout_engine
from repro.core import metrics
from repro.core import sampler as sampler_lib
from repro.core.largevis import largevis
from repro.data.synthetic import gaussian_mixture
from repro.runtime.compat import make_mesh

KEY = jax.random.key(0)


@pytest.fixture(scope="module")
def small_graph():
    """Synthetic 600-node directed KNN graph + samplers (stepping fixture)."""
    rng = np.random.default_rng(3)
    n, k = 600, 8
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (n, k)).astype(np.float32)
    es = sampler_lib.build_edge_sampler(idx, w)
    ns = sampler_lib.build_negative_sampler(idx, w)
    return n, es, ns


def _run(n, es, ns, *, steps_per_dispatch, spn=120):
    cfg = LargeVisConfig(samples_per_node=spn, batch_size=4096,
                         steps_per_dispatch=steps_per_dispatch)
    return layout_lib.run_layout(KEY, es, ns, n, cfg)


def test_scan_matches_loop_bitwise(small_graph):
    """Same seed -> the scanned engine reproduces the per-step Python loop
    exactly: same keys, same t/T schedule, same step body."""
    n, es, ns = small_graph
    r_loop = _run(n, es, ns, steps_per_dispatch=1)      # per-step driver
    r_scan = _run(n, es, ns, steps_per_dispatch=64)
    assert r_loop.steps == r_scan.steps
    assert r_loop.edge_samples == r_scan.edge_samples
    a, b = np.asarray(r_loop.y), np.asarray(r_scan.y)
    assert np.array_equal(a, b), float(np.abs(a - b).max())


def test_scan_remainder_chunks_match(small_graph):
    """A chunk size that does not divide the step count (prime H) exercises
    the remainder dispatch and must not change the trajectory."""
    n, es, ns = small_graph
    r_a = _run(n, es, ns, steps_per_dispatch=64)
    r_b = _run(n, es, ns, steps_per_dispatch=37)
    assert np.array_equal(np.asarray(r_a.y), np.asarray(r_b.y))


def test_chunk_donates_y_buffer(small_graph):
    """Donation must survive into the compiled executable: y aliases in->out
    (no doubled peak layout buffer) and the donated input is invalidated."""
    n, es, ns = small_graph
    cfg = LargeVisConfig()
    kwargs = layout_lib._step_kwargs(es, ns, n, cfg, 300)
    y0 = jax.random.normal(KEY, (n, 2), jnp.float32)
    step_ids = jnp.arange(8, dtype=jnp.int32)
    t_fracs = jnp.linspace(0.0, 0.1, 8).astype(jnp.float32)
    lowered = layout_engine.layout_chunk.lower(
        y0, KEY, step_ids, t_fracs, **kwargs)
    compiled = lowered.compile()
    assert "input_output_alias" in compiled.as_text()
    ma = compiled.memory_analysis()
    assert ma.alias_size_in_bytes >= y0.nbytes, ma.alias_size_in_bytes
    y1 = layout_engine.layout_chunk(y0, KEY, step_ids, t_fracs, **kwargs)
    assert y0.is_deleted()          # the buffer really was donated
    assert jnp.isfinite(y1).all()


def test_chunked_kernel_pads_odd_batches():
    """largevis_grads_chunked == strict kernel semantics at B % tile != 0
    (the collision cap produces arbitrary odd batches inside the scan)."""
    from repro.kernels import ref
    from repro.kernels.largevis_grad import largevis_grads_chunked
    k1, k2, k3 = jax.random.split(KEY, 3)
    b, m, s = 37, 5, 2
    yi = jax.random.normal(k1, (b, s), jnp.float32)
    yj = jax.random.normal(k2, (b, s), jnp.float32)
    yn = jax.random.normal(k3, (b, m, s), jnp.float32)
    mask = (jax.random.uniform(k1, (b, m)) > 0.2).astype(jnp.float32)
    got = largevis_grads_chunked(yi, yj, yn, mask, tile=16, interpret=True)
    want = ref.largevis_grads_ref(yi, yj, yn, neg_mask=mask)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


def test_local_sgd_scan_body_runs(small_graph):
    """make_local_sgd_fns now scans the shared step body; a single-device
    mesh round trip must run and keep the layout finite."""
    n, es, ns = small_graph
    mesh = make_mesh((1,), ("data",))
    cfg = LargeVisConfig(sync_every=4, samples_per_node=32, batch_size=256)
    res = layout_lib.run_layout_local_sgd(KEY, es, ns, n, cfg, mesh)
    assert jnp.isfinite(res.y).all()
    assert res.steps >= cfg.sync_every


def test_engine_layout_quality():
    """Paper C4 via the engine path: KNN-classifier accuracy on the
    2000-point fixture stays >= 0.95 (PR-1 recorded 0.96 on this cfg)."""
    x, labels = gaussian_mixture(KEY, 2000, 32, 8)
    cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                         window=32, perplexity=10.0, samples_per_node=2000,
                         batch_size=4096)
    assert cfg.steps_per_dispatch > 1   # default path = scan engine
    res = largevis(x, KEY, cfg=cfg)
    acc = metrics.knn_classifier_accuracy(res.y, labels, k=5)
    assert acc >= 0.95, acc
    assert jnp.isfinite(res.y).all()
