"""Elastic sharded pipeline (PR 10): topology-portable checkpoints,
mesh-level fault injection, and shard-failure recovery.

Contract under test (README "Robustness", elastic resume):

* stage checkpoints store GLOBAL arrays + a topology tag; the run
  fingerprint excludes the mesh shape, so a checkpoint written on P
  shards restores onto any P' (``StageCheckpointer.restore`` re-shards);
* graph-prep stages are bitwise P-invariant, so a P=4 run SIGKILLed at a
  stage boundary and resumed on 2 or 1 shards produces the **bitwise**
  KNN graph / weights — and sampler marginals — of an uninterrupted
  single-shard run;
* a layout checkpoint resumed on a different shard count continues from
  the last committed round boundary with exactly one
  ``TopologyChangeWarning`` (local-SGD trajectories are P-dependent);
* an injected per-shard fault (``ShardFailedError``) degrades the mesh
  ``P -> P/2`` with exactly one ``DegradedModeWarning`` and the fit
  completes; at P=1 the failure propagates;
* SIGTERM/SIGINT with checkpointing on commits a resumable layout save
  before the process exits by the signal (``PreemptionGuard``).

Tier-1 tests here are single-device-safe; the ``chaos``-marked tests
need a forced multi-device host (the CI mesh-chaos job runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) or spawn
subprocesses that force it themselves.
"""
import dataclasses
import json
import os
import signal
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck
from repro.checkpoint.largevis_state import (StageCheckpointer,
                                             run_fingerprint, topology_tag)
from repro.configs.largevis_default import CheckpointConfig, LargeVisConfig
from repro.runtime.fault_tolerance import (FAULT_SITES, SHARDED_FAULT_SITES,
                                           DegradedModeWarning,
                                           FaultInjector, PreemptionGuard,
                                           ShardFailedError,
                                           TopologyChangeWarning,
                                           fire_per_shard)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


def _x(n=384, d=16, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _cfg(shards, ckdir=None, **kw):
    base = dict(n_neighbors=8, n_trees=2, n_explore_iters=1, window=16,
                perplexity=6.0, samples_per_node=120, batch_size=64,
                distributed=True, data_shards=shards, sync_every=8)
    base.update(kw)
    cfg = LargeVisConfig(**base)
    if ckdir is not None:
        cfg = dataclasses.replace(cfg, checkpoint=CheckpointConfig(
            directory=str(ckdir), every_chunks=1))
    return cfg


# ---------------------------------------------------------------------------
# fault-plan validation + site registry (tier-1)
# ---------------------------------------------------------------------------

def test_fault_plan_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site.*bogus"):
        FaultInjector({"bogus": {0: "exception"}})


def test_fault_plan_rejects_malformed_shard_site():
    # a sharded base name needs a ':<digit>' shard suffix
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector({"knn_ring_step": {0: "exception"}})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector({"calibrate_shard:x": {0: "exception"}})


def test_fault_plan_accepts_registered_sites():
    plan = {s: {0: "exception"} for s in FAULT_SITES}
    plan.update({f"{s}:3": {0: "exception"} for s in SHARDED_FAULT_SITES})
    FaultInjector(plan)            # must not raise


def test_registry_covers_pipeline_sites():
    """Every site the source actually fires is registered — a renamed
    site would otherwise make existing chaos plans silently inert."""
    for s in ("stage:graph", "stage:weights", "stage:samplers",
              "layout_chunk", "layout_saved", "layout_round"):
        assert s in FAULT_SITES
    for s in ("knn_ring_step", "calibrate_shard", "symmetrize_exchange",
              "local_sgd_round"):
        assert s in SHARDED_FAULT_SITES


def test_fire_per_shard_wraps_shard_fault():
    fault = FaultInjector({"calibrate_shard:2": {0: "exception"}})
    with pytest.raises(ShardFailedError) as exc:
        fire_per_shard(fault, "calibrate_shard", 4, stage="calibrate")
    assert exc.value.shard == 2 and exc.value.stage == "calibrate"


def test_fire_per_shard_callable_transforms_payload():
    fault = FaultInjector({"local_sgd_round:1": {0: lambda dt: dt * 10}})
    out = fire_per_shard(fault, "local_sgd_round", 3, stage="layout",
                         payloads=[1.0, 1.0, 1.0])
    assert out == [1.0, 10.0, 1.0]


# ---------------------------------------------------------------------------
# topology-invariant fingerprints + topology tags (tier-1)
# ---------------------------------------------------------------------------

def test_fingerprint_excludes_topology():
    x, key = _x(64, 4), jax.random.key(3)
    fps = {run_fingerprint(x, key, LargeVisConfig(
        distributed=d, data_shards=p)) for d, p in
        [(False, 0), (True, 1), (True, 4), (True, 8)]}
    assert len(fps) == 1, "mesh shape leaked into the run fingerprint"


def test_fingerprint_still_binds_algorithm_and_data():
    x, key = _x(64, 4), jax.random.key(3)
    fp = run_fingerprint(x, key, LargeVisConfig())
    assert fp != run_fingerprint(x, key, LargeVisConfig(perplexity=9.0))
    assert fp != run_fingerprint(x, jax.random.key(4), LargeVisConfig())
    assert fp != run_fingerprint(_x(64, 4, seed=1), key, LargeVisConfig())


def test_topology_tag_resolves_shards():
    tag = topology_tag(LargeVisConfig(), 100)
    assert tag == {"distributed": False, "data_shards": 1, "n_rows": 100}
    tag = topology_tag(LargeVisConfig(distributed=True, data_shards=1), 7)
    assert tag["data_shards"] == 1 and tag["n_rows"] == 7


# ---------------------------------------------------------------------------
# fallback walk skips topology-incompatible checkpoints (tier-1)
# ---------------------------------------------------------------------------

def _stage_dir_with_tags(tmp_path, tags):
    """One stage dir with a checkpoint per (step, topology-tag)."""
    d = tmp_path / "stage"
    for step, tag in tags:
        ck.save(d, step, {"y": np.arange(8.0, dtype=np.float32)},
                keep=len(tags),
                extra_meta={"topology": tag} if tag is not None else None)
    return d


def test_walk_skips_degenerate_topology_checkpoint(tmp_path):
    """A newest checkpoint whose tag names more shards than rows (a
    mesh-shrink artifact at tiny N) is skipped like corruption and the
    older compatible one wins."""
    from repro.checkpoint.largevis_state import _topology_compatible
    d = _stage_dir_with_tags(tmp_path, [
        (1, {"distributed": True, "data_shards": 2, "n_rows": 8}),
        (2, {"distributed": True, "data_shards": 16, "n_rows": 8}),
    ])
    with pytest.warns(RuntimeWarning, match="incompatible checkpoint"):
        tree, step = ck.restore(d, validate=_topology_compatible)
    assert step == 1

    # explicit step: no fallback, hard error
    with pytest.raises(ck.CheckpointIncompatibleError):
        ck.restore(d, step=2, validate=_topology_compatible)


def test_walk_accepts_pre_elastic_checkpoints(tmp_path):
    """Checkpoints without a topology tag (pre-PR-10) restore silently."""
    from repro.checkpoint.largevis_state import _topology_compatible
    d = _stage_dir_with_tags(tmp_path, [(1, None)])
    tree, step = ck.restore(d, validate=_topology_compatible)
    assert step == 1


def test_stage_restore_passthrough_without_mesh(tmp_path):
    """restore(mesh=None) behaves exactly like load."""
    sc = StageCheckpointer(CheckpointConfig(directory=str(tmp_path)), "fp")
    sc.save("graph", {"idx": np.arange(12).reshape(6, 2)},
            extra={"topology": {"distributed": True, "data_shards": 3,
                                "n_rows": 6}})
    tree, step, extra = sc.restore("graph", mesh=None)
    assert np.array_equal(np.asarray(tree["idx"]),
                          np.arange(12).reshape(6, 2))
    assert extra["topology"]["data_shards"] == 3


# ---------------------------------------------------------------------------
# re-shard placement + in-process elastic resume (chaos: forced 4-dev mesh)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@multi_device
def test_stage_restore_reshards_onto_mesh(tmp_path):
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_data_mesh
    mesh = make_data_mesh(4)
    sc = StageCheckpointer(CheckpointConfig(directory=str(tmp_path)), "fp")
    rows = np.arange(32.0, dtype=np.float32).reshape(8, 4)
    sc.save("graph", {"w": rows, "scalar": np.float32(2.0)},
            extra={"topology": {"distributed": True, "data_shards": 2,
                                "n_rows": 8}})
    tree, step, extra = sc.restore("graph", mesh=mesh)
    w = tree["w"]
    assert np.array_equal(np.asarray(w), rows)          # values untouched
    assert w.sharding.spec == P("data", None)           # rows placed on mesh
    shard_rows = {s.data.shape[0] for s in w.addressable_shards}
    assert shard_rows == {2}                            # 8 rows over 4 shards


@pytest.mark.chaos
@multi_device
def test_elastic_resume_p4_to_smaller_mesh(tmp_path):
    """Full P=4 checkpointed run restored on P in {2, 1}: graph prep is
    bitwise, sampler marginals match the target-mesh rebuild bitwise
    (cross-mesh to ~f32 table rounding), the completed layout reloads
    as-is, and the topology change announces itself exactly once."""
    from repro.core.largevis import largevis
    from repro.core.sampler import build_samplers_sharded, edge_marginals
    from repro.launch.mesh import make_data_mesh
    x, key = _x(), jax.random.key(7)
    r4 = largevis(x, key, cfg=_cfg(4, tmp_path / "ck"))
    base = largevis(x, key, cfg=_cfg(1, tmp_path / "base"))
    m_base = edge_marginals(build_samplers_sharded(
        np.asarray(base.knn_idx), np.asarray(base.weights),
        mesh=make_data_mesh(1))[0])
    for new_p in (2, 1):
        with warnings.catch_warnings(record=True) as wlist:
            warnings.simplefilter("always")
            r = largevis(x, key, cfg=_cfg(new_p, tmp_path / "ck"))
        topo = [w for w in wlist
                if isinstance(w.message, TopologyChangeWarning)]
        assert len(topo) == 1, [str(w.message) for w in wlist]
        assert topo[0].message.saved_shards == 4
        assert topo[0].message.new_shards == new_p
        assert np.array_equal(np.asarray(r.knn_idx), np.asarray(base.knn_idx))
        assert np.array_equal(np.asarray(r.weights), np.asarray(base.weights))
        # layout was complete at the kill... i.e. at save: reload verbatim
        assert np.array_equal(np.asarray(r.y), np.asarray(r4.y))
        m = edge_marginals(build_samplers_sharded(
            np.asarray(r.knn_idx), np.asarray(r.weights),
            mesh=make_data_mesh(new_p))[0])
        if new_p == 1:
            assert np.array_equal(m, m_base)            # same-mesh: bitwise
        else:
            np.testing.assert_allclose(m, m_base, rtol=1e-6)


@pytest.mark.chaos
@multi_device
def test_shard_fault_degrades_mesh_and_completes(tmp_path):
    """One injected shard fault -> exactly one DegradedModeWarning, the
    fit completes on the halved mesh."""
    from repro.core.largevis import largevis
    for site, stage in [("knn_ring_step:1", "knn"),
                        ("calibrate_shard:2", "calibrate"),
                        ("symmetrize_exchange:0", "symmetrize"),
                        ("local_sgd_round:3", "layout")]:
        fault = FaultInjector({site: {0: "exception"}})
        with warnings.catch_warnings(record=True) as wlist:
            warnings.simplefilter("always")
            r = largevis(_x(), jax.random.key(7), cfg=_cfg(4), fault=fault)
        deg = [w for w in wlist
               if isinstance(w.message, DegradedModeWarning)]
        assert len(deg) == 1, (site, [str(w.message) for w in wlist])
        assert deg[0].message.stage == stage
        assert deg[0].message.from_impl == "mesh[4]"
        assert deg[0].message.to_impl == "mesh[2]"
        assert r.cfg.data_shards == 2
        assert np.all(np.isfinite(np.asarray(r.y)))


@pytest.mark.chaos
@multi_device
def test_shard_fault_at_one_shard_propagates():
    """With nothing left to shed the failure is real: re-raised."""
    from repro.core.largevis import largevis
    fault = FaultInjector({"calibrate_shard:0":
                           {h: "exception" for h in range(3)}})
    with pytest.raises(ShardFailedError), warnings.catch_warnings():
        warnings.simplefilter("ignore")
        largevis(_x(), jax.random.key(7), cfg=_cfg(4), fault=fault)


@pytest.mark.chaos
@multi_device
def test_straggling_shard_flagged_by_index():
    """A callable per-shard fault inflates one shard's observed round
    time; the per-shard watchdogs name that shard in the warning and in
    ``result.stragglers``."""
    from repro.core.largevis import build_graph, layout_graph
    cfg = _cfg(4, samples_per_node=400, batch_size=16)
    idx, dist, w, _ = build_graph(_x(256, 8), jax.random.key(5), cfg=cfg)
    slow = {h: (lambda dt: dt * 50 + 1.0) for h in range(12, 16)}
    fault = FaultInjector({"local_sgd_round:1": slow})
    with pytest.warns(RuntimeWarning, match="shard 1 straggling"):
        res, _ = layout_graph(idx, w, jax.random.key(6), cfg=cfg,
                              fault=fault)
    assert res.stragglers and all(s[0] == 1 for s in res.stragglers)


# ---------------------------------------------------------------------------
# subprocess matrix: SIGKILL on P=4, resume on P' in {2, 1} (slow/chaos)
# ---------------------------------------------------------------------------

_WORKER = r"""
import os, sys, warnings
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, SRC)
import dataclasses, json
import numpy as np, jax
from repro.configs.largevis_default import LargeVisConfig, CheckpointConfig
from repro.core.largevis import largevis
from repro.core.sampler import build_samplers_sharded, edge_marginals
from repro.launch.mesh import make_data_mesh
from repro.runtime.fault_tolerance import (FaultInjector,
                                           TopologyChangeWarning)

shards = int(os.environ["ELASTIC_SHARDS"])
cfg = LargeVisConfig(n_neighbors=8, n_trees=2, n_explore_iters=1, window=16,
                     perplexity=6.0, samples_per_node=120, batch_size=64,
                     distributed=True, data_shards=shards, sync_every=8,
                     checkpoint=CheckpointConfig(
                         directory=os.environ["ELASTIC_CKPT"],
                         every_chunks=1))
x = np.random.default_rng(0).normal(size=(384, 16)).astype(np.float32)
site = os.environ.get("ELASTIC_SITE")
fault = None
if site == "sigterm":
    # self-preempt two committed rounds into the layout: the guard must
    # save synchronously, then the process dies BY the signal
    cfg = dataclasses.replace(cfg, checkpoint=dataclasses.replace(
        cfg.checkpoint, every_chunks=1000))      # guard save, not cadence
    import signal
    fault = FaultInjector({"layout_round": {
        2: (lambda y: os.kill(os.getpid(), signal.SIGTERM) or y)}})
elif site:
    fault = FaultInjector({site: {int(os.environ["ELASTIC_HIT"]): "kill"}})
with warnings.catch_warnings(record=True) as wlist:
    warnings.simplefilter("always")
    res = largevis(x, jax.random.key(7), cfg=cfg, fault=fault)
es, _ = build_samplers_sharded(np.asarray(res.knn_idx),
                               np.asarray(res.weights),
                               mesh=make_data_mesh(res.cfg.data_shards))
np.savez(os.environ["ELASTIC_OUT"], y=np.asarray(res.y),
         idx=np.asarray(res.knn_idx), dist=np.asarray(res.knn_dist),
         w=np.asarray(res.weights), marginals=edge_marginals(es))
meta = {"topo_warns": sum(isinstance(w.message, TopologyChangeWarning)
                          for w in wlist)}
with open(os.environ["ELASTIC_OUT"] + ".json", "w") as f:
    json.dump(meta, f)
print("WORKER_DONE")
"""


def _run_worker(tmp_path, out_name, *, shards, site=None, hit=0):
    env = dict(os.environ,
               ELASTIC_OUT=str(tmp_path / out_name),
               ELASTIC_SITE=site or "", ELASTIC_HIT=str(hit),
               ELASTIC_CKPT=str(tmp_path / "ckpt"),
               ELASTIC_SHARDS=str(shards))
    env.pop("XLA_FLAGS", None)
    script = _WORKER.replace("SRC", repr(os.path.join(REPO, "src")))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


def _load(tmp_path, name):
    data = np.load(str(tmp_path / name) + ".npz")
    with open(str(tmp_path / name) + ".json") as f:
        meta = json.load(f)
    return data, meta


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("resume_shards", [2, 1])
@pytest.mark.parametrize("site,hit", [
    ("stage:graph", 0), ("stage:weights", 0),
])
def test_sigkill_stage_boundary_resume_smaller_mesh(tmp_path, site, hit,
                                                    resume_shards):
    """P=4 SIGKILLed at a graph-prep boundary, resumed on fewer shards:
    graph/weights restore bitwise from the P=4 checkpoint, the layout
    runs entirely on the new mesh, so the final embedding is bitwise
    that of an uninterrupted run at the resume topology."""
    killed = _run_worker(tmp_path, "na", shards=4, site=site, hit=hit)
    assert killed.returncode == -9, (killed.returncode,
                                     killed.stderr[-2000:])
    resumed = _run_worker(tmp_path, "resumed", shards=resume_shards)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    clean_dir = tmp_path / "clean"
    clean_dir.mkdir()
    clean = _run_worker(clean_dir, "clean", shards=resume_shards)
    assert clean.returncode == 0, clean.stderr[-2000:]
    res, res_meta = _load(tmp_path, "resumed")
    ref, _ = _load(clean_dir, "clean")
    for k in ("idx", "dist", "w", "y", "marginals"):
        assert np.array_equal(res[k], ref[k]), k
    # no layout checkpoint existed at the kill -> no topology warning
    assert res_meta["topo_warns"] == 0


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("resume_shards", [2, 1])
def test_sigkill_mid_layout_resume_smaller_mesh(tmp_path, resume_shards):
    """P=4 SIGKILLed mid-layout, resumed on fewer shards: graph prep is
    still bitwise vs an uninterrupted single-shard run, and the layout
    continues from the last committed round with exactly one
    TopologyChangeWarning."""
    killed = _run_worker(tmp_path, "na", shards=4, site="layout_saved",
                         hit=1)
    assert killed.returncode == -9, (killed.returncode,
                                     killed.stderr[-2000:])
    resumed = _run_worker(tmp_path, "resumed", shards=resume_shards)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    clean_dir = tmp_path / "clean"
    clean_dir.mkdir()
    clean = _run_worker(clean_dir, "clean", shards=1)
    assert clean.returncode == 0, clean.stderr[-2000:]
    res, res_meta = _load(tmp_path, "resumed")
    ref, _ = _load(clean_dir, "clean")
    for k in ("idx", "dist", "w"):
        assert np.array_equal(res[k], ref[k]), k
    np.testing.assert_allclose(res["marginals"], ref["marginals"],
                               rtol=1e-6)
    assert res_meta["topo_warns"] == 1
    assert np.all(np.isfinite(res["y"]))


@pytest.mark.slow
@pytest.mark.chaos
def test_sigterm_preemption_guard_saves_before_exit(tmp_path):
    """SIGTERM mid-layout with a checkpoint cadence that would never
    fire: the PreemptionGuard's synchronous save is the only way a
    layout checkpoint can exist — and the resumed run must finish from
    it, bitwise-equal to an uninterrupted run at the same topology."""
    killed = _run_worker(tmp_path, "na", shards=4, site="sigterm")
    assert killed.returncode == -signal.SIGTERM, (killed.returncode,
                                                  killed.stderr[-2000:])
    layout_dir = tmp_path / "ckpt" / "layout"
    committed = [p for p in layout_dir.glob("step_*")
                 if (p / "_COMMITTED").exists()]
    assert committed, "preemption guard did not commit a layout save"
    resumed = _run_worker(tmp_path, "resumed", shards=4)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    clean_dir = tmp_path / "clean"
    clean_dir.mkdir()
    clean = _run_worker(clean_dir, "clean", shards=4)
    assert clean.returncode == 0, clean.stderr[-2000:]
    res, res_meta = _load(tmp_path, "resumed")
    ref, _ = _load(clean_dir, "clean")
    assert np.array_equal(res["y"], ref["y"])
    assert res_meta["topo_warns"] == 0          # same topology: silent


# ---------------------------------------------------------------------------
# PreemptionGuard unit behavior (tier-1)
# ---------------------------------------------------------------------------

def test_preemption_guard_active_registry_and_restore():
    assert PreemptionGuard.active() is None
    guard = PreemptionGuard(signals=(signal.SIGUSR1,)).activate()
    try:
        assert PreemptionGuard.active() is guard
        saves = []
        guard.set_save_fn(lambda: saves.append(1))
        os.kill(os.getpid(), signal.SIGUSR1)
        assert saves == [1] and guard.triggered
    finally:
        guard.restore_handlers()
    assert PreemptionGuard.active() is None
