"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import largevis
from repro.core.metrics import graph_recall, knn_classifier_accuracy
from repro.data.synthetic import gaussian_mixture, mnist_like

KEY = jax.random.key(0)


@pytest.mark.slow
def test_largevis_end_to_end_quality():
    """The full paper pipeline with near-default params separates clusters:
    C4's 'defaults work' property at test scale."""
    x, labels = gaussian_mixture(KEY, 3000, 64, 10)
    cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                         window=32, perplexity=12.0, samples_per_node=3000,
                         batch_size=4096)
    res = largevis(x, KEY, cfg=cfg)
    assert jnp.isfinite(res.y).all()
    assert graph_recall(x, res.knn_idx) > 0.85
    acc = knn_classifier_accuracy(res.y, labels, k=5)
    assert acc > 0.85, acc


@pytest.mark.slow
def test_largevis_high_dim_input():
    """784-dim (MNIST-shaped) input works through the same pipeline."""
    x, labels = mnist_like(KEY, 1500, 784, 10)
    cfg = LargeVisConfig(n_neighbors=10, n_trees=4, n_explore_iters=2,
                         window=32, perplexity=8.0, samples_per_node=4000,
                         batch_size=4096)
    res = largevis(x, KEY, cfg=cfg)
    acc = knn_classifier_accuracy(res.y, labels, k=5)
    assert acc > 0.8, acc


@pytest.mark.slow
def test_train_loop_reduces_loss():
    """A few hundred steps of the production driver reduce LM loss."""
    from repro.launch.train import train
    _, _, losses = train("xlstm-125m", steps=120, batch=8, seq=32,
                         ckpt_dir="/tmp/test_sys_ckpt", resume=False,
                         log_every=1000)
    first = np.mean([l for _, l in losses[:5]])
    last = np.mean([l for _, l in losses[-5:]])
    assert last < first - 0.1, (first, last)


def test_serve_engine_round_trip():
    """Continuous-batching engine serves more requests than slots."""
    from repro.configs import get_config
    from repro.launch.serve import Request, ServeEngine
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 5).tolist(),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.out) >= 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_largevis_deterministic_given_key():
    x, _ = gaussian_mixture(KEY, 500, 16, 4)
    cfg = LargeVisConfig(n_neighbors=8, n_trees=2, n_explore_iters=1,
                         window=16, perplexity=5.0, samples_per_node=200,
                         batch_size=1024)
    y1 = largevis(x, jax.random.key(7), cfg=cfg).y
    y2 = largevis(x, jax.random.key(7), cfg=cfg).y
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
