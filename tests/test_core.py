"""LargeVis core: KNN construction, exploring, weights, samplers, layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.largevis_default import LargeVisConfig
from repro.core import knn as knn_lib
from repro.core import metrics, perplexity
from repro.core import sampler as sampler_lib
from repro.core.largevis import largevis
from repro.core.neighbor_explore import neighbor_explore, reverse_neighbors
from repro.data.synthetic import gaussian_mixture

KEY = jax.random.key(0)


@pytest.fixture(scope="module")
def blobs():
    x, labels = gaussian_mixture(KEY, 2000, 32, 8)
    return x, labels


@pytest.fixture(scope="module")
def true_knn(blobs):
    x, _ = blobs
    return knn_lib.brute_force_knn(x, 15)


def test_brute_force_knn_correct(blobs):
    x, _ = blobs
    idx, dist = knn_lib.brute_force_knn(x[:300], 5)
    # exact check vs numpy on a small slice
    xn = np.asarray(x[:300], np.float64)
    d = ((xn[:, None] - xn[None]) ** 2).sum(-1)
    np.fill_diagonal(d, np.inf)
    want = np.argsort(d, axis=1)[:, :5]
    got_d = np.sort(np.asarray(dist), axis=1)
    want_d = np.sort(np.take_along_axis(d, want, 1), axis=1)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-3)


def test_forest_then_explore_recall_progression(blobs, true_knn):
    """Paper C1 (Fig 3): exploring lifts recall toward 1.0 in <=3 iters."""
    x, _ = blobs
    true_idx, _ = true_knn
    recalls = []
    for iters in (0, 1, 3):
        cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=iters,
                             window=32)
        idx, _ = knn_lib.build_knn_graph(x, KEY, cfg)
        recalls.append(knn_lib.knn_recall(idx, true_idx))
    assert recalls[1] > recalls[0] + 0.1, recalls
    assert recalls[2] > 0.9, recalls


def test_explore_never_worsens(blobs, true_knn):
    """Monotone invariant: merged top-k keeps current neighbors unless a
    strictly closer candidate exists — recall cannot decrease."""
    x, _ = blobs
    true_idx, _ = true_knn
    cfg = LargeVisConfig(n_neighbors=15, n_trees=2, n_explore_iters=0,
                         window=16)
    idx, dist = knn_lib.build_knn_graph(x, KEY, cfg)
    r_prev = knn_lib.knn_recall(idx, true_idx)
    for _ in range(2):
        idx, dist = neighbor_explore(x, idx, dist, iters=1, key=KEY)
        r = knn_lib.knn_recall(idx, true_idx)
        assert r >= r_prev - 1e-6
        r_prev = r


def test_brute_force_map_odd_tiles(blobs):
    """The lax.map oracle handles N % tile != 0 (padded row tiles) and
    never materializes an (N, N) distance matrix when tiled."""
    x, _ = blobs
    x = x[:403]
    idx, dist = knn_lib.brute_force_knn(x, 7, tile=128)
    assert idx.shape == (403, 7) and dist.shape == (403, 7)
    idx_n = np.asarray(idx)
    assert (idx_n != np.arange(403)[:, None]).all(), "self edges"
    assert ((idx_n >= 0) & (idx_n < 403)).all(), "padded rows leaked"
    xn = np.asarray(x, np.float64)
    d = ((xn[:, None] - xn[None]) ** 2).sum(-1)
    np.fill_diagonal(d, np.inf)
    want_d = np.sort(np.sort(d, axis=1)[:, :7], axis=1)
    np.testing.assert_allclose(np.sort(np.asarray(dist), axis=1), want_d,
                               rtol=1e-4, atol=1e-3)
    # one dispatch, tiled: the loop is inside the program, and no tile is
    # ever the full (N, N) matrix
    hlo = knn_lib.brute_force_knn.lower(x, 7, tile=128).as_text()
    assert "403x403" not in hlo, "full NxN distance matrix materialized"


def test_forest_knn_scan_matches_tree_loop(blobs):
    """The lax.scan over stacked tree codes is bitwise the per-tree Python
    loop over the same window fold, the lowered program holds no
    (N, n_trees*(k+1)) all-trees candidate concat, and the compiled body
    appears ONCE regardless of n_trees (same HLO op counts for 2 vs 4
    trees — the old loop unrolled the tree body n_trees times)."""
    from repro.kernels import ref as ref_lib
    x, _ = blobs
    N, k, n_trees, window = x.shape[0], 15, 4, 32
    depth = knn_lib._auto_depth(N, 64)
    idx, dist = knn_lib.forest_knn(x, KEY, n_trees=n_trees, depth=depth,
                                   k=k, window=window)
    # reference: Python loop over trees, same fold (the scan is pure
    # dispatch restructuring — trajectories must be bitwise identical)
    codes = knn_lib.hash_codes(x, KEY, n_trees, depth)
    run_i = jnp.full((N, k), -1, jnp.int32)
    run_d = jnp.full((N, k), ref_lib.INVALID_DIST, jnp.float32)
    for t in range(n_trees):
        run_i, run_d = knn_lib._window_fold_one_tree(
            x, codes[:, t], k, window, run_i, run_d, "auto")
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(run_i))
    np.testing.assert_array_equal(np.asarray(dist), np.asarray(run_d))

    def hlo(nt):
        return knn_lib.forest_knn.lower(
            x, KEY, n_trees=nt, depth=depth, k=k, window=window).as_text()

    h4 = hlo(n_trees)
    assert f"{N}x{n_trees * (k + 1)}x" not in h4, (
        "all-trees candidate concat materialized")
    # one scan body regardless of n_trees: every op the tree body lowers
    # to (sorts from the per-tree argsort, the fold's top-k) appears the
    # same number of times whether the forest has 2 or 4 trees
    h2 = hlo(2)
    for marker in ("sort(", "top-k", "while("):
        assert h4.count(marker) == h2.count(marker), (
            marker, h4.count(marker), h2.count(marker))


def test_merge_candidates_dedup_and_self():
    ids = jnp.array([[1, 1, 2, 0], [3, 2, 2, 1]], jnp.int32)
    d = jnp.array([[1., 1., 2., 3.], [5., 1., 1., 2.]], jnp.float32)
    self_idx = jnp.array([0, 1], jnp.int32)
    mi, md = knn_lib.merge_candidates(ids, d, 2, self_idx=self_idx)
    # row 0: self (0) suppressed, dup 1 suppressed -> [1, 2]
    assert set(np.asarray(mi[0]).tolist()) == {1, 2}
    # row 1: self (1) suppressed, dup 2 suppressed -> [2, 3]
    assert set(np.asarray(mi[1]).tolist()) == {2, 3}


def test_reverse_neighbors_contains_true_reverse():
    idx = jnp.array([[1, 2], [2, 0], [0, 1], [0, 1]], jnp.int32)
    rev = reverse_neighbors(idx, 4)
    # node 0 is listed by 1, 2, 3
    assert {1, 2, 3} <= set(np.asarray(rev[0]).tolist())


def test_perplexity_calibration(blobs):
    x, _ = blobs
    idx, dist = knn_lib.brute_force_knn(x, 30)
    p = perplexity.calibrate_p(dist, 10.0)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-4)
    realized = perplexity.perplexity_of(p)
    assert float(jnp.median(jnp.abs(realized - 10.0))) < 0.5


def test_symmetrize_weight_symmetry(blobs):
    """w_ij == w_ji whenever both directed edges exist."""
    x, _ = blobs
    idx, dist = knn_lib.brute_force_knn(x[:500], 10)
    w = perplexity.edge_weights(idx, dist, 5.0)
    idx_n, w_n = np.asarray(idx), np.asarray(w)
    W = {}
    for i in range(idx_n.shape[0]):
        for k in range(idx_n.shape[1]):
            W[(i, idx_n[i, k])] = w_n[i, k]
    checked = 0
    for (i, j), wij in W.items():
        if (j, i) in W:
            assert abs(wij - W[(j, i)]) < 1e-9
            checked += 1
    assert checked > 100


def test_alias_sampler_distribution():
    probs = np.array([0.1, 0.0, 0.4, 0.5])
    thr, alias = sampler_lib.build_alias(probs)
    idx = sampler_lib.sample_alias(KEY, jnp.asarray(thr), jnp.asarray(alias),
                                   (200_000,))
    freq = np.bincount(np.asarray(idx), minlength=4) / 200_000
    np.testing.assert_allclose(freq, probs, atol=0.01)
    assert freq[1] == 0.0


def test_negative_sampler_power_law():
    idx = jnp.array([[1], [0], [0], [0]], jnp.int32)   # node 0 high degree
    w = jnp.ones((4, 1), jnp.float32)
    ns = sampler_lib.build_negative_sampler(idx, w, power=0.75)
    s = np.asarray(ns.sample(KEY, (100_000,)))
    freq = np.bincount(s, minlength=4) / 100_000
    # deg = [out 1 + in 3, 1+1, 1, 1] = [4, 2, 1, 1] -> ^0.75 normalized
    want = np.array([4.0, 2.0, 1.0, 1.0]) ** 0.75
    want /= want.sum()
    np.testing.assert_allclose(freq, want, atol=0.01)


def test_layout_separates_clusters(blobs):
    """Paper C4 proxy: default hyper-params produce a layout whose 2D KNN
    classifier beats chance by a wide margin."""
    x, labels = blobs
    cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                         window=32, perplexity=10.0, samples_per_node=2000,
                         batch_size=4096)
    res = largevis(x, KEY, cfg=cfg)
    acc = metrics.knn_classifier_accuracy(res.y, labels, k=5)
    assert acc > 0.8, acc                                 # chance = 0.125
    assert jnp.isfinite(res.y).all()


def test_layout_gradient_direction():
    """Attractive edges pull together; negatives push apart (Eqn 6 signs)."""
    from repro.kernels.ref import largevis_grads_ref
    yi = jnp.array([[0.0, 0.0]])
    yj = jnp.array([[1.0, 0.0]])
    yn = jnp.array([[[50.0, 50.0]]])       # far negative: repulsion ~ 0
    gi, gj, gn = largevis_grads_ref(yi, yj, yn, neg_mask=jnp.ones((1, 1)))
    step_i = yi - 0.1 * gi
    assert jnp.linalg.norm(step_i - yj) < jnp.linalg.norm(yi - yj)
    # the positive partner moves toward yi too
    step_j = yj - 0.1 * gj
    assert jnp.linalg.norm(step_j - yi) < jnp.linalg.norm(yj - yi)
    # a CLOSE negative is pushed away from yi by its own step
    yn_close = jnp.array([[[0.3, 0.3]]])
    _, _, gn2 = largevis_grads_ref(yi, yj, yn_close,
                                   neg_mask=jnp.ones((1, 1)))
    step_n = yn_close - 0.1 * gn2
    assert jnp.linalg.norm(step_n[0, 0] - yi[0]) > jnp.linalg.norm(
        yn_close[0, 0] - yi[0])
