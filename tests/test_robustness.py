"""Numerical health guard, degraded-mode routing, and public-API input
validation (PR 8 tentpole part 2 + satellites).

The health/rollback tests drive real divergence through the fault
injector's ``nan`` payload corruption at the ``layout_chunk`` site —
the probe, rollback, lr backoff, and give-up paths all execute on the
actual chunked driver, not on mocks.  Degraded-mode tests monkeypatch
the underlying builder/engine to raise, asserting the demotion happens
once, warns once, and still produces a healthy result.
"""
import dataclasses
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.largevis_default import HealthConfig, LargeVisConfig
from repro.core import sampler as sampler_lib
from repro.core.layout import layout_health, run_layout
from repro.runtime.fault_tolerance import (DegradedModeWarning,
                                           DivergenceWarning, FaultInjector,
                                           LayoutDivergedError, Watchdog)

KEY = jax.random.key(3)
N = 400
CFG = LargeVisConfig(n_neighbors=8, n_trees=2, n_explore_iters=1, window=16,
                     perplexity=6.0, samples_per_node=200, batch_size=128,
                     steps_per_dispatch=20)


@pytest.fixture(scope="module")
def samplers():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, N, (N, 8)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (N, 8)).astype(np.float32)
    return (sampler_lib.build_edge_sampler(idx, w),
            sampler_lib.build_negative_sampler(idx, w))


# ---------------------------------------------------------------------------
# health probe + rollback
# ---------------------------------------------------------------------------

def test_layout_health_probe():
    y = jnp.asarray([[1.0, -2.0], [3.0, 4.0]])
    nf, mx = layout_health(y)
    assert int(nf) == 0 and float(mx) == 4.0
    y_bad = y.at[0, 1].set(jnp.nan).at[1, 0].set(jnp.inf)
    nf, mx = layout_health(y_bad)
    assert int(nf) == 2
    assert float(mx) == 4.0      # non-finite entries can't mask the max


def test_divergence_rolls_back_with_backoff(samplers):
    es, ns = samplers
    cfg = dataclasses.replace(CFG, health=HealthConfig(max_rollbacks=3))
    fi = FaultInjector({"layout_chunk": {1: "nan"}})
    with pytest.warns(DivergenceWarning) as wlog:
        r = run_layout(KEY, es, ns, N, cfg, fault=fi)
    assert len([w for w in wlog
                if issubclass(w.category, DivergenceWarning)]) == 1
    assert r.rollbacks == 1 and r.rho0_scale == 0.5
    assert bool(np.isfinite(np.asarray(r.y)).all())
    # the full sample budget still ran despite the replayed chunk
    assert r.steps * 128 == r.edge_samples


def test_norm_blowup_triggers_rollback(samplers):
    es, ns = samplers
    cfg = dataclasses.replace(CFG, health=HealthConfig(max_abs=1e3))

    def blowup(y):
        return y.at[0, 0].set(1e9)     # finite, but way past max_abs

    fi = FaultInjector({"layout_chunk": {2: blowup}})
    with pytest.warns(DivergenceWarning):
        r = run_layout(KEY, es, ns, N, cfg, fault=fi)
    assert r.rollbacks == 1
    assert float(np.abs(np.asarray(r.y)).max()) < 1e3


def test_persistent_divergence_raises(samplers):
    es, ns = samplers
    cfg = dataclasses.replace(CFG, health=HealthConfig(max_rollbacks=2))
    fi = FaultInjector({"layout_chunk": {i: "nan" for i in range(50)}})
    with pytest.raises(LayoutDivergedError):
        with pytest.warns(DivergenceWarning):
            run_layout(KEY, es, ns, N, cfg, fault=fi)


def test_healthy_run_unaffected_by_health_guard(samplers):
    """The guard must be observation-only on healthy runs: same bits as
    an unguarded run (the probe never perturbs the trajectory)."""
    es, ns = samplers
    r0 = run_layout(KEY, es, ns, N, CFG)
    cfg = dataclasses.replace(CFG, health=HealthConfig())
    r1 = run_layout(KEY, es, ns, N, cfg)
    assert np.array_equal(np.asarray(r0.y), np.asarray(r1.y))
    assert r1.rollbacks == 0 and r1.rho0_scale == 1.0


# ---------------------------------------------------------------------------
# degraded-mode routing
# ---------------------------------------------------------------------------

def test_fused_step_demotes_to_split_on_backend_failure(
        samplers, monkeypatch):
    """A fused-kernel failure on the first chunk demotes the run to the
    split path with ONE DegradedModeWarning; the result is the split
    path's bits (fused and split differ in op fusion, not semantics)."""
    from repro.core import layout_engine
    es, ns = samplers
    cfg = dataclasses.replace(CFG, fused_step=False)
    want = np.asarray(run_layout(KEY, es, ns, N, cfg).y)

    real_chunk = layout_engine.layout_chunk
    calls = {"n": 0}

    def flaky_chunk(y, kr, step_ids, t_fracs, **kw):
        calls["n"] += 1
        if kw.get("fused_step"):
            raise RuntimeError("XLA fused kernel unavailable")
        return real_chunk(y, kr, step_ids, t_fracs, **kw)

    monkeypatch.setattr(layout_engine, "layout_chunk", flaky_chunk)
    cfg_fused = dataclasses.replace(CFG, fused_step=True)
    with pytest.warns(DegradedModeWarning) as wlog:
        r = run_layout(KEY, es, ns, N, cfg_fused,
                       fault=FaultInjector())    # monitored, inert plan
    assert len([w for w in wlog
                if issubclass(w.category, DegradedModeWarning)]) == 1
    assert np.array_equal(np.asarray(r.y), want)


def test_sampler_build_demotes_to_host(monkeypatch):
    """A device sampler-build failure falls back to the numpy Vose
    oracle (bitwise-identical tables — pinned in test_sampler) instead
    of killing the fit."""
    lv = sys.modules["repro.core.largevis"]
    rng = np.random.default_rng(1)
    idx = rng.integers(0, N, (N, 8)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (N, 8)).astype(np.float32)

    real_build = sampler_lib.build_edge_sampler

    def flaky(idx, w, impl="auto", **kw):
        if impl != "host":
            raise RuntimeError("device build exploded")
        return real_build(idx, w, impl=impl, **kw)

    monkeypatch.setattr(lv.sampler_lib, "build_edge_sampler", flaky)
    with pytest.warns(DegradedModeWarning, match="host"):
        res, _ = lv.layout_graph(jnp.asarray(idx), jnp.asarray(w), KEY,
                                 cfg=CFG)
    assert bool(np.isfinite(np.asarray(res.y)).all())


def test_host_impl_failure_is_not_masked(monkeypatch):
    """When the user explicitly routed sampler_impl='host', a failure
    there is real and must propagate, not demote in a loop."""
    lv = sys.modules["repro.core.largevis"]

    def always_boom(*a, **kw):
        raise RuntimeError("host build exploded")

    monkeypatch.setattr(lv.sampler_lib, "build_edge_sampler", always_boom)
    cfg = dataclasses.replace(CFG, sampler_impl="host")
    idx = np.zeros((16, 2), np.int32)
    w = np.ones((16, 2), np.float32)
    with pytest.raises(RuntimeError, match="host build exploded"):
        lv.layout_graph(jnp.asarray(idx), jnp.asarray(w), KEY, cfg=cfg)


# ---------------------------------------------------------------------------
# watchdog wiring
# ---------------------------------------------------------------------------

def test_watchdog_flags_straggler_dispatch(samplers):
    """run_layout observes every blocked dispatch; a straggler chunk
    lands in result.stragglers (injected via a slow callable fault)."""
    import time as _time
    es, ns = samplers
    cfg = dataclasses.replace(CFG, samples_per_node=600)

    def stall(y):
        _time.sleep(0.05)
        return y

    # the fault site runs inside the timed window of each dispatch
    fi = FaultInjector({"layout_chunk": {30: stall}})
    r = run_layout(KEY, es, ns, N, cfg, fault=fi)
    assert any(dt >= 0.05 for _, dt, _ in r.stragglers)


def test_watchdog_observe_math():
    dog = Watchdog(threshold=3.0)
    for i in range(20):
        assert not dog.observe(i, 0.01)
    assert dog.observe(99, 0.5)
    assert dog.stragglers[-1][0] == 99


# ---------------------------------------------------------------------------
# public-API input validation (one regression test per rejected case)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fitted():
    from repro import LargeVis
    x = np.random.default_rng(0).normal(size=(N, 16)).astype(np.float32)
    return LargeVis(cfg=CFG).fit(x, KEY)


def test_fit_rejects_empty():
    from repro import LargeVis
    with pytest.raises(ValueError, match="empty"):
        LargeVis(cfg=CFG).fit(np.zeros((0, 8), np.float32))


def test_fit_rejects_wrong_rank():
    from repro import LargeVis
    with pytest.raises(ValueError, match="2-D"):
        LargeVis(cfg=CFG).fit(np.zeros((64,), np.float32))


def test_fit_rejects_nonfinite_rows():
    from repro import LargeVis
    x = np.random.default_rng(0).normal(size=(32, 8)).astype(np.float32)
    x[7, 3] = np.inf
    with pytest.raises(ValueError, match=r"NaN/Inf.*\[7\]"):
        LargeVis(cfg=CFG).fit(x)


def test_fit_rejects_zero_features():
    from repro import LargeVis
    with pytest.raises(ValueError, match="0 features"):
        LargeVis(cfg=CFG).fit(np.zeros((16, 0), np.float32))


def test_transform_rejects_dim_mismatch(fitted):
    with pytest.raises(ValueError, match="fitted corpus"):
        fitted.transform(np.zeros((4, 7), np.float32))


def test_transform_rejects_empty(fitted):
    with pytest.raises(ValueError, match="empty"):
        fitted.transform(np.zeros((0, 16), np.float32))


def test_transform_rejects_nonfinite(fitted):
    q = np.zeros((3, 16), np.float32)
    q[1] = np.nan
    with pytest.raises(ValueError, match=r"NaN/Inf.*\[1\]"):
        fitted.transform(q)


def test_insert_rejects_dim_mismatch(fitted):
    with pytest.raises(ValueError, match="fitted corpus"):
        fitted.insert(np.zeros((4, 9), np.float32))


def test_insert_rejects_nonfinite(fitted):
    q = np.full((2, 16), np.nan, np.float32)
    with pytest.raises(ValueError, match="NaN/Inf"):
        fitted.insert(q)


def test_insert_empty_is_noop(fitted):
    """Empty insert stays a valid no-op (pre-PR-8 contract), returning
    a (0, s) block — not a ValueError."""
    out = fitted.insert(np.zeros((0, 16), np.float32))
    assert out.shape == (0, 2)
