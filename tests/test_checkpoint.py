"""Checkpoint system: atomicity, rotation, restore fidelity, elastic load."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ck
from repro.checkpoint.manager import CheckpointManager

KEY = jax.random.key(0)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (64, 32)),
            "nested": {"b": jnp.arange(17, dtype=jnp.int32),
                       "scale": jnp.float32(3.5)},
            "stack": jax.random.normal(jax.random.fold_in(k, 1), (4, 8, 8))}


def test_save_restore_bit_identical(tmp_path):
    t = _tree()
    ck.save(tmp_path, 10, t)
    got, step = ck.restore(tmp_path)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    ck.save(tmp_path, 1, t)
    ck.save(tmp_path, 2, t)
    # corrupt checkpoint 2: simulate a crash mid-save (remove commit marker)
    (pathlib.Path(tmp_path) / "step_2" / "_COMMITTED").unlink()
    assert ck.latest_step(tmp_path) == 1
    _, step = ck.restore(tmp_path)
    assert step == 1


def test_rotation_keeps_last_k(tmp_path):
    t = _tree()
    for s in range(1, 8):
        ck.save(tmp_path, s, t, keep=3)
    assert ck.all_steps(tmp_path) == [5, 6, 7]


def test_manager_resume_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=5)
    t = _tree(3)
    assert mgr.maybe_save(3, t) is None           # not on the cadence
    assert mgr.maybe_save(5, t) is not None
    got, step = mgr.resume()
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores onto a different mesh layout."""
    t = _tree()
    ck.save(tmp_path, 1, t)
    from repro.runtime.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None)),
          "nested": {"b": NamedSharding(mesh, P()),
                     "scale": NamedSharding(mesh, P())},
          "stack": NamedSharding(mesh, P(None, None, None))}
    got, _ = ck.restore(tmp_path, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_corrupt_checkpoint_falls_back_to_previous(tmp_path):
    """A committed-but-damaged save (bit rot / truncation that still
    renamed) fails CRC verification and restore() walks back to the
    newest older checkpoint instead of returning garbage."""
    import pytest
    ck.save(tmp_path, 1, _tree(1))
    ck.save(tmp_path, 2, _tree(2))
    shard = pathlib.Path(tmp_path) / "step_2" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:-40] + b"\x00" * 40)   # bit rot
    with pytest.warns(RuntimeWarning, match="corrupt"):
        got, step = ck.restore(tmp_path)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(_tree(1)["w"]))
    # an explicit step request raises instead of silently falling back
    with pytest.raises(ck.CheckpointCorruptError):
        ck.restore(tmp_path, 2)


def test_all_checkpoints_corrupt_raises(tmp_path):
    import pytest
    ck.save(tmp_path, 1, _tree())
    (pathlib.Path(tmp_path) / "step_1" / "meta.json").write_text("{oops")
    with pytest.raises(ck.CheckpointCorruptError):
        with pytest.warns(RuntimeWarning):
            ck.restore(tmp_path)


def test_version_and_schema_rejection(tmp_path):
    import json
    import pytest
    ck.save(tmp_path, 1, _tree(), schema="my-schema")
    # wrong schema tag
    with pytest.raises(ValueError, match="schema"):
        ck.restore(tmp_path, 1, expect_schema="other-schema")
    got, _ = ck.restore(tmp_path, 1, expect_schema="my-schema")
    assert "w" in got
    # a format newer than this reader is refused, never half-parsed
    meta_p = pathlib.Path(tmp_path) / "step_1" / "meta.json"
    meta = json.loads(meta_p.read_text())
    meta["version"] = ck.FORMAT_VERSION + 1
    meta_p.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="newer"):
        ck.restore(tmp_path, 1)


def test_v1_checkpoint_still_loads(tmp_path):
    """Pre-PR-8 checkpoints (no version/CRC fields) remain readable."""
    import json
    ck.save(tmp_path, 1, _tree())
    meta_p = pathlib.Path(tmp_path) / "step_1" / "meta.json"
    meta = json.loads(meta_p.read_text())
    del meta["version"], meta["shard_crc"], meta["schema"]
    meta_p.write_text(json.dumps(meta))
    got, step = ck.restore(tmp_path)
    assert step == 1 and "w" in got


def test_largevis_save_load_roundtrip(tmp_path):
    """LargeVis.save/load: versioned checkpoint (not a pickle), bitwise
    embedding + graph round trip, working samplers/key/cfg — loaded
    models transform() bitwise-identically to the original."""
    from repro import LargeVis, LargeVisConfig
    cfg = LargeVisConfig(n_neighbors=6, n_trees=2, n_explore_iters=1,
                         window=16, perplexity=4.0, samples_per_node=60,
                         batch_size=64, steps_per_dispatch=10)
    x = np.asarray(jax.random.normal(KEY, (128, 8)), np.float32)
    m = LargeVis(cfg=cfg).fit(x, jax.random.key(1))
    m.save(tmp_path / "model")
    m2 = LargeVis.load(tmp_path / "model")
    for f in ("y", "knn_idx", "knn_dist", "weights", "x"):
        np.testing.assert_array_equal(np.asarray(getattr(m.result_, f)),
                                      np.asarray(getattr(m2.result_, f)))
    assert m2.result_.cfg == m.result_.cfg
    q = x[:5] + 0.01
    np.testing.assert_array_equal(np.asarray(m.transform(q)),
                                  np.asarray(m2.transform(q)))
    # wrong schema: loading some other checkpoint as a model is refused
    ck.save(tmp_path / "other", 0, _tree())
    import pytest
    with pytest.raises(ValueError, match="schema"):
        LargeVis.load(tmp_path / "other")


def test_grad_compression_bounds_and_ef():
    from repro.optim.grad_compress import (compress, compression_ratio,
                                           compressed_grads_with_ef,
                                           decompress)
    g = {"a": jax.random.normal(KEY, (1000,)),
         "b": jax.random.normal(jax.random.fold_in(KEY, 1), (64, 64)) * 10}
    q = compress(g, KEY)
    deq = decompress(q, g)
    for orig, rec in zip(jax.tree.leaves(g), jax.tree.leaves(deq)):
        scale = float(jnp.max(jnp.abs(orig)))
        # per-block max error <= scale/127 (one quantization unit + rounding)
        assert float(jnp.max(jnp.abs(orig - rec))) <= scale / 127.0 + 1e-6
    assert compression_ratio(g) < 0.27
    # error feedback: sum over steps of (deq) converges to sum of grads
    ef = None
    acc_deq = jax.tree.map(jnp.zeros_like, g)
    for i in range(20):
        deq, ef = compressed_grads_with_ef(g, ef, jax.random.fold_in(KEY, i))
        acc_deq = jax.tree.map(lambda a, d: a + d, acc_deq, deq)
    # EF guarantees accumulated quantized grads track accumulated true grads
    for orig, acc in zip(jax.tree.leaves(g), jax.tree.leaves(acc_deq)):
        drift = float(jnp.max(jnp.abs(acc / 20.0 - orig)))
        scale = float(jnp.max(jnp.abs(orig)))
        assert drift <= scale / 127.0 + 1e-5, drift
