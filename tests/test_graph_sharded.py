"""Sharded graph-preparation stages (PR 6): correctness + device residency.

In-process tests adapt to the visible device count via
``make_data_mesh(0)`` — under the default single-device pytest run they
exercise the full shard_map plumbing at P=1 (which must be *bitwise*
the single-device path); under the CI mesh-smoke job
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) the same tests
run the real 4-way partitioning.  The ``slow`` subprocess test forces
an 8-device mesh regardless of the parent's configuration.

Two-level sampler correctness is exercised WITHOUT a mesh: the stacked
per-shard tables are plain arrays and ``sample()`` is pure jnp, so a
hand-stacked 2-shard sampler checks the stratified-sampling math
(P(shard) * P(edge | shard) = w_e / T) directly, with a chi-square
bound on empirical frequencies.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.largevis_default import LargeVisConfig
from repro.core import layout as layout_lib
from repro.core import perplexity as perp
from repro.core import sampler as S
from repro.core.largevis import build_graph, largevis
from repro.launch.mesh import make_data_mesh
from repro.runtime.compat import make_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.key(0)


def _graph(n, k, seed=0):
    rng = np.random.default_rng(seed)
    idx = np.empty((n, k), np.int32)
    for i in range(n):                      # distinct neighbors, no self
        idx[i] = rng.choice([j for j in range(n) if j != i], k,
                            replace=False)
    d2 = rng.uniform(0.1, 4.0, (n, k)).astype(np.float32)
    return jnp.asarray(idx), jnp.asarray(d2)


# ---------------------------------------------------------------------------
# bitwise equality vs the single-device oracle (P = visible device count)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [403, 256])   # indivisible and divisible
def test_sharded_weights_bitwise_equal(n):
    idx, d2 = _graph(n, 7, seed=n)
    p_ref = perp.calibrate_p(d2, 5.0)
    p_sh = perp.calibrate_p_sharded(d2, 5.0)
    assert np.array_equal(np.asarray(p_ref), np.asarray(p_sh))

    w_ref = perp.symmetrize(idx, p_ref)
    w_sh = perp.symmetrize_sharded(idx, p_sh)
    assert np.array_equal(np.asarray(w_ref), np.asarray(w_sh))

    e_ref = perp.edge_weights(idx, d2, 5.0)
    e_sh = perp.edge_weights_sharded(idx, d2, 5.0)
    assert np.array_equal(np.asarray(e_ref), np.asarray(e_sh))


def test_sharded_sampler_tables_match_flat():
    """On a 1-shard mesh the per-shard tables ARE the flat tables and the
    sample() key streams match the flat samplers bitwise."""
    idx, _ = _graph(203, 5, seed=3)
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.integers(1, 16, idx.shape).astype(np.float32))
    es, ns = S.build_samplers_sharded(idx, w)
    ef = S.build_edge_sampler(idx, w, impl="device")
    nf = S.build_negative_sampler(idx, w, impl="device")
    if es.n_shards == 1:
        for a, b in ((es.src[0], ef.src), (es.dst[0], ef.dst),
                     (es.threshold[0], ef.threshold),
                     (es.alias[0], ef.alias)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    k = jax.random.key(9)
    if es.n_shards == 1:
        sa, da = es.sample(k, 512)
        sb, db = ef.sample(k, 512)
        assert np.array_equal(np.asarray(sa), np.asarray(sb))
        assert np.array_equal(np.asarray(da), np.asarray(db))
        assert np.array_equal(np.asarray(ns.sample(k, (512,))),
                              np.asarray(nf.sample(k, (512,))))
    # regardless of shard count: every drawn id is a valid node
    ids = np.asarray(ns.sample(k, (2048,)))
    assert ((ids >= 0) & (ids < idx.shape[0])).all()


# ---------------------------------------------------------------------------
# two-level sampler math (mesh-free, hand-stacked 2-shard tables)
# ---------------------------------------------------------------------------

def _stack_edge_sampler(idx, w, n_shards=2):
    """Build a ShardedEdgeSampler by slicing the graph into row blocks
    and alias-building each block independently (what the shard_map
    builder computes per device)."""
    n = idx.shape[0]
    n_loc = n // n_shards
    parts, totals = [], []
    for s in range(n_shards):
        sl = slice(s * n_loc, (s + 1) * n_loc)
        es = S.build_edge_sampler(np.asarray(idx)[sl], np.asarray(w)[sl],
                                  impl="device")
        # slice-local src ids -> global
        parts.append((np.asarray(es.src) + s * n_loc, np.asarray(es.dst),
                      np.asarray(es.threshold), np.asarray(es.alias)))
        totals.append(float(np.asarray(w)[sl].sum()))
    src = jnp.asarray(np.stack([p[0] for p in parts]))
    dst = jnp.asarray(np.stack([p[1] for p in parts]))
    thr = jnp.asarray(np.stack([p[2] for p in parts]))
    ali = jnp.asarray(np.stack([p[3] for p in parts]))
    sthr, sali = S.build_alias(np.asarray(totals))
    return S.ShardedEdgeSampler(src, dst, thr, ali, jnp.asarray(sthr),
                                jnp.asarray(sali), n_shards, n * idx.shape[1])


def _chi2_ok(obs, expected_p, n_draws):
    """Chi-square statistic below mean + 5 sigma of its null
    distribution (df = bins - 1) — no scipy needed."""
    exp = expected_p * n_draws
    stat = float(np.sum((obs - exp) ** 2 / np.maximum(exp, 1e-12)))
    df = len(expected_p) - 1
    return stat < df + 5.0 * np.sqrt(2.0 * df), stat, df


def test_two_level_edge_sampler_matches_global_distribution():
    n, k = 64, 4
    idx, _ = _graph(n, k, seed=7)
    rng = np.random.default_rng(8)
    w = rng.uniform(0.2, 3.0, (n, k)).astype(np.float32)
    sampler = _stack_edge_sampler(idx, jnp.asarray(w), n_shards=2)

    m = 1 << 19
    src, dst = sampler.sample(jax.random.key(5), m)
    pair = np.asarray(src).astype(np.int64) * n + np.asarray(dst)
    # unique global pair id per edge slot (distinct neighbors per row)
    slot_pair = (np.repeat(np.arange(n), k).astype(np.int64) * n
                 + np.asarray(idx).reshape(-1))
    counts = np.zeros(n * k)
    uniq, cnt = np.unique(pair, return_counts=True)
    lookup = {p: i for i, p in enumerate(slot_pair)}
    for p, c in zip(uniq, cnt):
        counts[lookup[int(p)]] = c
    ok, stat, df = _chi2_ok(counts, w.reshape(-1) / w.sum(), m)
    assert ok, f"edge chi-square {stat:.1f} too high for df={df}"


def test_two_level_negative_sampler_matches_global_distribution():
    n, k = 64, 4
    idx, _ = _graph(n, k, seed=9)
    rng = np.random.default_rng(10)
    w = rng.uniform(0.2, 3.0, (n, k)).astype(np.float32)
    # global noise mass: deg(j)^0.75 with deg = out + in weighted degree
    deg = w.sum(1).copy()
    np.add.at(deg, np.asarray(idx).reshape(-1), w.reshape(-1))
    mass = deg ** 0.75

    n_shards, n_loc = 2, n // 2
    thr, ali, totals = [], [], []
    for s in range(n_shards):
        t, a = S.build_alias(mass[s * n_loc:(s + 1) * n_loc])
        thr.append(t); ali.append(a)
        totals.append(mass[s * n_loc:(s + 1) * n_loc].sum())
    sthr, sali = S.build_alias(np.asarray(totals))
    sampler = S.ShardedNodeSampler(
        jnp.asarray(np.stack(thr)), jnp.asarray(np.stack(ali)),
        jnp.asarray(sthr), jnp.asarray(sali), n_shards, n)

    m = 1 << 19
    ids = np.asarray(sampler.sample(jax.random.key(6), (m,)))
    counts = np.bincount(ids, minlength=n).astype(float)
    ok, stat, df = _chi2_ok(counts, mass / mass.sum(), m)
    assert ok, f"negative chi-square {stat:.1f} too high for df={df}"


def test_sharded_builder_marginals_reconstruct_weights():
    """Exactness (not sampling): threshold/alias tables from the sharded
    builder reconstruct each edge's draw probability w_e / T_s, and the
    shard table reconstructs T_s / T."""
    idx, _ = _graph(150, 6, seed=11)       # 150 rows, P | 150 not needed
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.uniform(0.1, 2.0, idx.shape).astype(np.float32))
    es, ns = S.build_samplers_sharded(idx, w)
    P_, E = es.threshold.shape
    w_np = np.asarray(w)
    n_loc = -(-idx.shape[0] // P_)
    for s in range(P_):
        thr = np.asarray(es.threshold[s], np.float64)
        ali = np.asarray(es.alias[s])
        marg = thr.copy()
        np.add.at(marg, ali, 1.0 - thr)
        marg /= E
        rows = slice(s * n_loc, min((s + 1) * n_loc, idx.shape[0]))
        w_loc = w_np[rows].reshape(-1)
        want = np.zeros(E)
        want[:w_loc.size] = w_loc / w_loc.sum()
        np.testing.assert_allclose(marg, want, atol=5e-7)
    sm = np.asarray(es.shard_threshold, np.float64).copy()
    np.add.at(sm, np.asarray(es.shard_alias), 1.0 - sm)
    sm /= P_
    tot = np.array([w_np[s * n_loc:(s + 1) * n_loc].sum() for s in range(P_)])
    np.testing.assert_allclose(sm, tot / tot.sum(), atol=5e-7)


# ---------------------------------------------------------------------------
# layout trajectories + end-to-end device residency
# ---------------------------------------------------------------------------

def test_local_sgd_trajectory_sharded_vs_flat_samplers():
    """Through the local-SGD driver the sharded sampler pytrees must
    reproduce the flat-sampler trajectory bitwise at one device (same
    tables, same key stream); integer weights keep the alias builds
    float-associativity-free."""
    idx, _ = _graph(203, 5, seed=13)
    rng = np.random.default_rng(14)
    w = jnp.asarray(rng.integers(1, 16, idx.shape).astype(np.float32))
    ef = S.build_edge_sampler(idx, w, impl="device")
    nf = S.build_negative_sampler(idx, w, impl="device")
    es, ns = S.build_samplers_sharded(idx, w)
    if es.n_shards != 1:
        pytest.skip("bitwise parity only defined at one device")
    mesh = make_mesh((1,), ("data",))
    cfg = LargeVisConfig(samples_per_node=60, batch_size=64, sync_every=4)
    r_flat = layout_lib.run_layout_local_sgd(KEY, ef, nf, 203, cfg, mesh)
    r_shard = layout_lib.run_layout_local_sgd(KEY, es, ns, 203, cfg, mesh)
    assert np.array_equal(np.asarray(r_flat.y), np.asarray(r_shard.y))


def test_distributed_pipeline_device_resident(monkeypatch):
    """largevis(distributed=True) end to end: the host Vose path is
    booby-trapped AND device->host transfers are disallowed across the
    graph-prep stages — KNN, calibration, symmetrization, and the
    sampler build never leave the mesh."""
    from repro.data.synthetic import gaussian_mixture

    def boom(*_a, **_k):
        raise AssertionError("host alias build reached in distributed mode")

    monkeypatch.setattr(S, "build_alias", boom)
    x, _ = gaussian_mixture(jax.random.key(4), 403, 12, 4)
    cfg = LargeVisConfig(n_neighbors=7, n_trees=2, n_explore_iters=1,
                         window=16, perplexity=5.0, samples_per_node=40,
                         batch_size=64, sync_every=4, distributed=True)
    with jax.transfer_guard_device_to_host("disallow"):
        idx, dist, w, _ = build_graph(x, jax.random.key(5), cfg=cfg)
        es, ns = S.build_samplers_sharded(idx, w, power=cfg.neg_power)
        jax.block_until_ready((es.threshold, ns.threshold))
    res = largevis(x, jax.random.key(6), cfg=cfg)
    assert res.y.shape == (403, cfg.out_dim)
    assert bool(jnp.all(jnp.isfinite(res.y)))


def test_distributed_linear_knn_routing():
    """``knn_distributed=False`` under ``distributed=True`` (the fig6
    scaling configuration): stage 1 is the paper's linear forest KNN —
    bitwise the non-distributed graph — while the weights still come
    out of the sharded calibrate/symmetrize drivers, bitwise-equal to
    the flat oracle, and the graph-prep stages stay device-resident."""
    from repro.data.synthetic import gaussian_mixture

    x, _ = gaussian_mixture(jax.random.key(7), 403, 12, 4)
    cfg = LargeVisConfig(n_neighbors=7, n_trees=2, n_explore_iters=1,
                         window=16, perplexity=5.0, samples_per_node=40,
                         batch_size=64, sync_every=4, distributed=True,
                         knn_distributed=False)
    with jax.transfer_guard_device_to_host("disallow"):
        idx, dist, w, _ = build_graph(x, jax.random.key(5), cfg=cfg)
        jax.block_until_ready(w)
    cfg_flat = dataclasses.replace(cfg, distributed=False)
    idx_f, dist_f, w_f, _ = build_graph(x, jax.random.key(5), cfg=cfg_flat)
    assert np.array_equal(np.asarray(idx), np.asarray(idx_f))
    assert np.array_equal(np.asarray(dist), np.asarray(dist_f))
    assert np.array_equal(np.asarray(w), np.asarray(w_f))


# ---------------------------------------------------------------------------
# real multi-device equality (8 host CPU devices, subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, SRC)
import jax, jax.numpy as jnp, numpy as np

from repro.configs.largevis_default import LargeVisConfig
from repro.core import perplexity as perp
from repro.core import sampler as S
from repro.core import layout as layout_lib
from repro.core.largevis import largevis
from repro.data.synthetic import gaussian_mixture
from repro.launch.mesh import make_data_mesh

assert len(jax.devices()) == 8, jax.devices()
rng = np.random.default_rng(0)
n, k = 2003, 9                                   # 2003 % 8 != 0
idx = np.stack([rng.choice(n - 1, k, replace=False) for _ in range(n)])
idx = jnp.asarray(np.where(idx >= np.arange(n)[:, None], idx + 1, idx),
                  jnp.int32)
d2 = jnp.asarray(rng.uniform(0.1, 4.0, (n, k)).astype(np.float32))

p_ref = perp.calibrate_p(d2, 7.0)
p_sh = perp.calibrate_p_sharded(d2, 7.0)
assert np.array_equal(np.asarray(p_ref), np.asarray(p_sh))
w_ref = perp.symmetrize(idx, p_ref)
w_sh = perp.symmetrize_sharded(idx, p_sh)
assert np.array_equal(np.asarray(w_ref), np.asarray(w_sh))
print("WEIGHTS_BITWISE_OK")

wi = jnp.asarray(rng.integers(1, 16, (n, k)).astype(np.float32))
es, ns = S.build_samplers_sharded(idx, wi)
assert es.n_shards == 8 and es.threshold.shape[0] == 8
n_loc = es.threshold.shape[1] // k
wi_np = np.asarray(wi)
for s in range(8):
    rows = slice(s * n_loc, min((s + 1) * n_loc, n))
    m = rows.stop - rows.start
    if m == n_loc:
        # full shard: tables bitwise == a standalone build of the slice
        ef = S.build_edge_sampler(np.asarray(idx)[rows], wi_np[rows],
                                  impl="device")
        assert np.array_equal(np.asarray(es.threshold[s]),
                              np.asarray(ef.threshold)), s
        assert np.array_equal(np.asarray(es.alias[s]),
                              np.asarray(ef.alias)), s
    # every shard (incl. the zero-padded last one): the table's marginals
    # reconstruct exactly w_e / T_s, zero mass on padded slots
    E = es.threshold.shape[1]
    marg = np.asarray(es.threshold[s], np.float64).copy()
    np.add.at(marg, np.asarray(es.alias[s]), 1.0 - marg)
    marg /= E
    w_loc = wi_np[rows].reshape(-1)
    want = np.zeros(E)
    want[:w_loc.size] = w_loc / w_loc.sum()
    np.testing.assert_allclose(marg, want, atol=5e-7)
print("SHARD_TABLES_OK")

x, _ = gaussian_mixture(jax.random.key(1), 1603, 12, 4)
cfg = LargeVisConfig(n_neighbors=7, n_trees=2, n_explore_iters=1,
                     window=16, perplexity=5.0, samples_per_node=60,
                     batch_size=64, sync_every=4, distributed=True)
res = largevis(x, jax.random.key(2), cfg=cfg)
assert res.y.shape == (1603, 2)
assert bool(jnp.all(jnp.isfinite(res.y)))
print("E2E_OK")
"""


@pytest.mark.slow
def test_sharded_stages_eight_devices():
    script = _SCRIPT.replace("SRC", repr(os.path.join(REPO, "src")))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "WEIGHTS_BITWISE_OK" in proc.stdout
    assert "SHARD_TABLES_OK" in proc.stdout
    assert "E2E_OK" in proc.stdout
