"""Device-resident sampler subsystem (core/sampler.py) + its plumbing.

Covers, per the stage-boundary refactor contract:

* the jitted device alias builder vs the numpy-Vose oracle — alias tables
  need not be identical (any table with the right marginals is valid), so
  the check reconstructs exact per-index marginal probabilities from
  (threshold, alias) and compares those;
* empirical edge / negative sample frequencies against w_ij and deg^0.75;
* EdgeSampler/NodeSampler pytree flatten/unflatten round trips through
  ``jax.jit`` with static metadata preserved;
* degenerate inputs: all-zero weights, a single edge, E not a power of 2;
* HLO/no-host assertions: the device builders lower with zero host
  callbacks and never touch the Python Vose loop (monkeypatch-proven),
  and ``symmetrize`` is ONE compiled computation reused across calls
  (no per-call retrace, no per-tile dispatch);
* bitwise trajectory parity pre/post refactor: pinned-seed layouts with
  host-built tables, driven through the new sampler-pytree plumbing, must
  reproduce the pre-refactor unpacked-six-array step stream exactly on
  all three drivers (per-step loop, scanned chunks, local-SGD) and
  through end-to-end ``largevis()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import hlo_checks

from repro.configs.largevis_default import LargeVisConfig
from repro.core import layout as layout_lib
from repro.core import perplexity
from repro.core import sampler as S
from repro.core.largevis import build_graph, largevis
from repro.core.sampler import sample_alias
from repro.data.synthetic import gaussian_mixture
from repro.kernels import ops
from repro.runtime.compat import make_mesh

KEY = jax.random.key(0)


def _marginals(threshold, alias):
    """Exact per-index probability the (threshold, alias) table samples
    index k: (threshold_k + sum over slots aliasing k of (1-threshold))/n."""
    t = np.asarray(threshold, np.float64)
    a = np.asarray(alias)
    mass = t.copy()
    np.add.at(mass, a, 1.0 - t)
    return mass / t.shape[0]


# ---------------------------------------------------------------------------
# device builder vs the numpy-Vose oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("probs", [
    np.array([0.1, 0.0, 0.4, 0.5]),
    np.ones(7),
    np.random.default_rng(0).random(1000) ** 2 + 1e-9,
    np.random.default_rng(1).pareto(1.5, 513) + 1e-9,     # heavy tail
    np.concatenate([np.zeros(50), np.random.default_rng(2).random(77)]),
])
def test_device_alias_marginals_match_oracle(probs):
    thr_h, ali_h = S.build_alias(probs)
    thr_d, ali_d = S.build_alias_device(jnp.asarray(probs, jnp.float32))
    want = probs / probs.sum()
    np.testing.assert_allclose(_marginals(thr_h, ali_h), want, atol=5e-5)
    np.testing.assert_allclose(_marginals(thr_d, ali_d), want, atol=5e-5)
    t = np.asarray(thr_d)
    assert ((t >= 0.0) & (t <= 1.0)).all()
    a = np.asarray(ali_d)
    assert ((a >= 0) & (a < len(probs))).all()


def test_device_alias_marginals_exact_at_scale():
    """Per-slot RELATIVE marginal error at benchmark scale.  f32 prefix
    sums break down here (individual deficits sink below the cumsum ulp
    around E ~ 1e5, with >100% per-slot error); the f64 pairing scope
    must keep every slot within rounding of its target."""
    rng = np.random.default_rng(17)
    n = 300_000
    p = rng.uniform(0.1, 2.0, n).astype(np.float32)
    thr, ali = S.build_alias_device(jnp.asarray(p))
    want = p.astype(np.float64)
    want /= want.sum()
    rel = np.abs(_marginals(thr, ali) - want) / want
    assert rel.max() < 1e-5, rel.max()


def test_edge_sampler_impls_same_marginals():
    rng = np.random.default_rng(5)
    idx = rng.integers(0, 40, (40, 7)).astype(np.int32)
    w = rng.uniform(0.0, 2.0, (40, 7)).astype(np.float32)
    eh = S.build_edge_sampler(idx, w, impl="host")
    ed = S.build_edge_sampler(idx, w, impl="device")
    np.testing.assert_array_equal(np.asarray(eh.src), np.asarray(ed.src))
    np.testing.assert_array_equal(np.asarray(eh.dst), np.asarray(ed.dst))
    np.testing.assert_allclose(_marginals(eh.threshold, eh.alias),
                               _marginals(ed.threshold, ed.alias), atol=5e-6)
    nh = S.build_negative_sampler(idx, w, impl="host")
    nd = S.build_negative_sampler(idx, w, impl="device")
    np.testing.assert_allclose(_marginals(nh.threshold, nh.alias),
                               _marginals(nd.threshold, nd.alias), atol=5e-6)


def test_device_edge_sample_frequencies_follow_weights():
    """Empirical slot frequencies ~ w_ij / sum(w) (paper's p(e) ∝ w_ij)."""
    rng = np.random.default_rng(7)
    idx = rng.integers(0, 4, (4, 3)).astype(np.int32)
    w = rng.uniform(0.1, 2.0, (4, 3)).astype(np.float32)
    es = S.build_edge_sampler(idx, w, impl="device")
    e = sample_alias(KEY, es.threshold, es.alias, (200_000,))
    freq = np.bincount(np.asarray(e), minlength=12) / 200_000
    np.testing.assert_allclose(freq, w.reshape(-1) / w.sum(), atol=0.01)


def test_device_negative_sampler_power_law():
    """Same fixture as the host-path test: deg^0.75 noise distribution."""
    idx = jnp.array([[1], [0], [0], [0]], jnp.int32)   # node 0 high degree
    w = jnp.ones((4, 1), jnp.float32)
    ns = S.build_negative_sampler(idx, w, power=0.75, impl="device")
    s = np.asarray(ns.sample(KEY, (100_000,)))
    freq = np.bincount(s, minlength=4) / 100_000
    want = np.array([4.0, 2.0, 1.0, 1.0]) ** 0.75
    want /= want.sum()
    np.testing.assert_allclose(freq, want, atol=0.01)


# ---------------------------------------------------------------------------
# pytree behaviour
# ---------------------------------------------------------------------------

def test_sampler_pytrees_roundtrip_through_jit():
    rng = np.random.default_rng(9)
    idx = rng.integers(0, 30, (30, 5)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (30, 5)).astype(np.float32)
    es = S.build_edge_sampler(idx, w, impl="device")
    ns = S.build_negative_sampler(idx, w, impl="device")

    leaves, treedef = jax.tree_util.tree_flatten(es)
    assert len(leaves) == 4                      # src, dst, threshold, alias
    es_r = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(es_r, S.EdgeSampler) and es_r.n_edges == es.n_edges
    assert len(jax.tree_util.tree_leaves(ns)) == 2

    # identity jit: structure, static metadata and leaf values survive
    es_j, ns_j = jax.jit(lambda a, b: (a, b))(es, ns)
    assert isinstance(es_j, S.EdgeSampler) and isinstance(ns_j, S.NodeSampler)
    assert es_j.n_edges == es.n_edges and ns_j.n_nodes == ns.n_nodes
    for got, want in zip(jax.tree_util.tree_leaves(es_j), leaves):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # samplers are legal jit *inputs*; draws are deterministic and in range
    i1, j1 = jax.jit(lambda s, k: s.sample(k, 64))(es, KEY)
    i2, j2 = jax.jit(lambda s, k: s.sample(k, 64))(es, KEY)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(j1), np.asarray(j2))
    assert ((np.asarray(i1) >= 0) & (np.asarray(i1) < 30)).all()


# ---------------------------------------------------------------------------
# degenerate inputs
# ---------------------------------------------------------------------------

def test_device_builder_all_zero_weights_uniform():
    idx = np.arange(1, 7, dtype=np.int32).reshape(6, 1) % 6
    w = np.zeros((6, 1), np.float32)
    es = S.build_edge_sampler(idx, w, impl="device")
    np.testing.assert_allclose(_marginals(es.threshold, es.alias),
                               np.full(6, 1 / 6), atol=1e-6)
    i, j = es.sample(KEY, 128)
    assert jnp.isfinite(es.threshold).all()
    assert ((np.asarray(i) >= 0) & (np.asarray(i) < 6)).all()


def test_device_builder_single_edge():
    idx = np.array([[0]], np.int32)
    w = np.array([[3.0]], np.float32)
    es = S.build_edge_sampler(idx, w, impl="device")
    assert es.n_edges == 1
    np.testing.assert_allclose(_marginals(es.threshold, es.alias), [1.0])
    i, j = es.sample(KEY, 16)
    assert (np.asarray(i) == 0).all() and (np.asarray(j) == 0).all()


@pytest.mark.parametrize("e_total", [15, 37, 1001])   # never a power of two
def test_device_builder_non_power_of_two(e_total):
    rng = np.random.default_rng(e_total)
    p = rng.random(e_total) + 1e-6
    thr, ali = S.build_alias_device(jnp.asarray(p, jnp.float32))
    np.testing.assert_allclose(_marginals(thr, ali), p / p.sum(), atol=5e-5)


# ---------------------------------------------------------------------------
# zero host involvement (HLO + monkeypatch), single-computation symmetrize
# ---------------------------------------------------------------------------

def test_device_builders_lower_without_host_callbacks():
    idx = jnp.zeros((64, 4), jnp.int32)
    w = jnp.ones((64, 4), jnp.float32)
    scope, hi = S._pairing_scope()
    with scope:
        lowereds = (
            S._build_edge_sampler_device.lower(idx, w, hi_dtype=hi),
            S._build_negative_sampler_device.lower(idx, w, power=0.75,
                                                   hi_dtype=hi),
            S._alias_jit.lower(jnp.ones(256, jnp.float32), hi_dtype=hi),
        )
    for lowered in lowereds:
        hlo = lowered.as_text()
        hlo_checks.assert_no_op(hlo, "callback", "infeed",
                                what="host involvement in device builder")
        hlo_checks.assert_has_op(hlo, "cumsum",
                                 what="prefix-sum device construction")


def test_device_builders_never_run_python_vose(monkeypatch):
    """impl="device" must execute zero Python-level per-edge iteration:
    with the host Vose loop booby-trapped, the device path still builds."""
    def boom(*_a, **_k):
        raise AssertionError("host Vose loop reached from impl='device'")

    monkeypatch.setattr(S, "build_alias", boom)
    rng = np.random.default_rng(11)
    idx = rng.integers(0, 50, (50, 4)).astype(np.int32)
    w = rng.uniform(0.1, 1.0, (50, 4)).astype(np.float32)
    es = S.build_edge_sampler(idx, w, impl="device")
    ns = S.build_negative_sampler(idx, w, impl="device")
    assert jnp.isfinite(es.threshold).all() and jnp.isfinite(ns.threshold).all()
    with pytest.raises(AssertionError, match="host Vose"):
        S.build_edge_sampler(idx, w, impl="host")


def test_symmetrize_is_single_compiled_computation():
    """The scanned symmetrize compiles once per (shape, tile) and reuses
    the executable — the pre-refactor form re-created a jax.jit wrapper
    (fresh cache, full retrace) on every call plus one dispatch per tile."""
    rng = np.random.default_rng(13)
    idx = jnp.asarray(rng.integers(0, 200, (200, 6)), jnp.int32)
    p = jax.random.uniform(KEY, (200, 6))

    before = perplexity._symmetrize_scan._cache_size()
    w1 = perplexity.symmetrize(idx, p, tile=64)
    w2 = perplexity.symmetrize(idx, p, tile=64)
    after = perplexity._symmetrize_scan._cache_size()
    assert after - before <= 1, "symmetrize re-traced on a repeat call"
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))

    hlo = perplexity._symmetrize_scan.lower(idx, p, tile=64).as_text()
    hlo_checks.assert_has_op(hlo, "while",
                             what="tile loop fused into the computation")
    hlo_checks.assert_no_op(hlo, "callback")

    # padded remainder tiles (200 % 64 != 0) match the exact-tile values
    w3 = perplexity.symmetrize(idx, p, tile=50)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w3), atol=1e-7)


# ---------------------------------------------------------------------------
# bitwise trajectory parity pre/post refactor (host-built tables through
# the new pytree plumbing vs the pre-refactor unpacked-array step stream)
# ---------------------------------------------------------------------------

def _old_sgd_step(y, key, t_frac, *, edge_src, edge_dst, edge_thr,
                  edge_alias, neg_thr, neg_alias, n_negatives, n_nodes,
                  gamma=7.0, a=1.0, clip=5.0, rho0=1.0, batch=4096):
    """The pre-refactor step body, verbatim: six unpacked table arrays,
    explicit sample_alias + gathers.  The refactored pytree step must
    produce this exact computation."""
    ke, kn, _ = jax.random.split(key, 3)
    e = sample_alias(ke, edge_thr, edge_alias, (batch,))
    i, j = edge_src[e], edge_dst[e]
    negs = sample_alias(kn, neg_thr, neg_alias, (batch, n_negatives))
    neg_mask = ((negs != i[:, None]) &
                (negs != j[:, None])).astype(jnp.float32)
    lr = rho0 * jnp.maximum(1.0 - t_frac, 1e-4)
    return ops.largevis_edge_step(y, i, j, negs, neg_mask, lr,
                                  gamma=gamma, a=a, clip=clip)


_old_step_jit = jax.jit(
    _old_sgd_step, donate_argnums=(0,),
    static_argnames=("n_negatives", "n_nodes", "gamma", "a", "clip",
                     "batch"))


@pytest.fixture(scope="module")
def parity_fixture():
    rng = np.random.default_rng(21)
    n, k = 500, 8
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (n, k)).astype(np.float32)
    es = S.build_edge_sampler(idx, w, impl="host")
    ns = S.build_negative_sampler(idx, w, impl="host")
    return n, es, ns


def _old_tables(es, ns):
    return dict(edge_src=es.src, edge_dst=es.dst, edge_thr=es.threshold,
                edge_alias=es.alias, neg_thr=ns.threshold,
                neg_alias=ns.alias)


def _reference_run_layout(key, es, ns, n, cfg):
    """Pre-refactor run_layout, inlined: per-step loop over the unpacked
    six-array step with the identical key stream and t/T schedule."""
    ky, kr = jax.random.split(key)
    y = (jax.random.normal(ky, (n, cfg.out_dim), jnp.float32)
         * cfg.init_scale)
    total = int(cfg.samples_per_node) * n
    batch = layout_lib._collision_capped_batch(cfg.batch_size, n, total)
    steps = max(1, total // batch)
    tables = _old_tables(es, ns)
    for t in range(steps):
        y = _old_step_jit(y, jax.random.fold_in(kr, t),
                          jnp.float32(t / steps), n_negatives=cfg.n_negatives,
                          n_nodes=n, gamma=cfg.gamma, a=cfg.prob_a,
                          clip=cfg.grad_clip, rho0=cfg.rho0, batch=batch,
                          **tables)
    return y


@pytest.mark.parametrize("steps_per_dispatch", [1, 64])
def test_pytree_plumbing_parity_loop_and_scan_drivers(parity_fixture,
                                                      steps_per_dispatch):
    """Drivers 1+2 (per-step loop, scanned chunks): host tables through
    the new pytree plumbing == the pre-refactor unpacked step stream."""
    n, es, ns = parity_fixture
    cfg = LargeVisConfig(samples_per_node=60, batch_size=4096,
                         steps_per_dispatch=steps_per_dispatch)
    got = layout_lib.run_layout(KEY, es, ns, n, cfg).y
    want = _reference_run_layout(KEY, es, ns, n, cfg)
    assert np.array_equal(np.asarray(got), np.asarray(want)), float(
        np.abs(np.asarray(got) - np.asarray(want)).max())


def test_pytree_plumbing_parity_local_sgd_driver(parity_fixture):
    """Driver 3 (shard_map local-SGD, 1-device mesh): same tables, same
    round-seed schedule, bitwise-identical trajectory."""
    n, es, ns = parity_fixture
    cfg = LargeVisConfig(sync_every=4, samples_per_node=16, batch_size=128)
    mesh = make_mesh((1,), ("data",))
    got = layout_lib.run_layout_local_sgd(KEY, es, ns, n, cfg, mesh).y

    # pre-refactor reference: unpacked-array steps, replicated schedule
    ky, kr = jax.random.split(KEY)
    y = (jax.random.normal(ky, (n, cfg.out_dim), jnp.float32)
         * cfg.init_scale)
    batch = layout_lib._collision_capped_batch(cfg.batch_size, n)
    total = int(cfg.samples_per_node) * n
    steps = max(1, total // batch)
    H = cfg.sync_every
    n_rounds = max(1, steps // H)
    seeds = np.asarray(jax.random.randint(kr, (n_rounds,), 0, 2**31 - 1,
                                          dtype=jnp.int32))
    dt = 1.0 / max(steps, 1)
    tables = _old_tables(es, ns)
    for r in range(n_rounds):
        base_key = jax.random.fold_in(jax.random.key(int(seeds[r])), 0)
        t_fracs = (jnp.float32(r * H * dt)
                   + jnp.float32(dt) * jnp.arange(H, dtype=jnp.float32))
        for h in range(H):
            y = _old_step_jit(y, jax.random.fold_in(base_key, h),
                              t_fracs[h], n_negatives=cfg.n_negatives,
                              n_nodes=n, gamma=cfg.gamma, a=cfg.prob_a,
                              clip=cfg.grad_clip, rho0=cfg.rho0,
                              batch=batch, **tables)
        # pmean over a 1-device mesh is the identity
    assert np.array_equal(np.asarray(got), np.asarray(y)), float(
        np.abs(np.asarray(got) - np.asarray(y)).max())


def test_largevis_end_to_end_bitwise_vs_host_table_path():
    """Acceptance: end-to-end largevis() on a pinned seed == the
    pre-refactor host-built-table composition, bit for bit."""
    x, _ = gaussian_mixture(jax.random.key(5), 400, 16, 4)
    cfg = LargeVisConfig(n_neighbors=10, n_trees=4, n_explore_iters=1,
                         window=32, perplexity=8.0, samples_per_node=100,
                         batch_size=4096, sampler_impl="host")
    got = largevis(x, KEY, cfg=cfg).y

    kg, kl = jax.random.split(KEY)
    idx, dist, w, _ = build_graph(x, kg, cfg=cfg)
    es = S.build_edge_sampler(idx, w, impl="host")
    ns = S.build_negative_sampler(idx, w, power=cfg.neg_power, impl="host")
    want = _reference_run_layout(kl, es, ns, x.shape[0], cfg)
    assert np.array_equal(np.asarray(got), np.asarray(want)), float(
        np.abs(np.asarray(got) - np.asarray(want)).max())


def test_largevis_device_tables_deterministic_and_finite():
    """The device stage boundary is reproducible end to end: same key,
    same tables, same layout — and sampler_s timing is recorded."""
    x, _ = gaussian_mixture(jax.random.key(6), 300, 16, 4)
    cfg = LargeVisConfig(n_neighbors=8, n_trees=4, n_explore_iters=1,
                         window=32, perplexity=6.0, samples_per_node=60,
                         batch_size=2048, sampler_impl="device")
    r1 = largevis(x, KEY, cfg=cfg)
    r2 = largevis(x, KEY, cfg=cfg)
    assert np.array_equal(np.asarray(r1.y), np.asarray(r2.y))
    assert jnp.isfinite(r1.y).all()
    assert "sampler_s" in r1.timings and "layout_s" in r1.timings
