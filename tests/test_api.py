"""Public API surface: the ``repro.LargeVis`` estimator, the ``largevis()``
compat shim, config routing consolidation, and model persistence."""
import dataclasses
import pickle
import warnings

import jax
import numpy as np
import pytest

from repro import (
    LargeVis,
    LargeVisConfig,
    LargeVisResult,
    NotFittedError,
    RoutingConfig,
    largevis,
)
from repro.data.synthetic import mnist_like

KEY = jax.random.key(0)

CFG = LargeVisConfig(n_neighbors=10, n_trees=4, samples_per_node=150,
                     batch_size=128, perplexity=8.0)


@pytest.fixture(scope="module")
def data():
    x, labels = mnist_like(KEY, 300, 16, 5)
    return x, labels


@pytest.fixture(scope="module")
def fitted(data):
    x, _ = data
    return LargeVis(cfg=CFG).fit(x, jax.random.key(1))


def test_public_import_paths():
    """The README-documented names all import from the package root."""
    import repro
    for name in ("LargeVis", "LargeVisConfig", "LargeVisResult",
                 "RoutingConfig", "largevis", "NotFittedError"):
        assert hasattr(repro, name), name
    assert repro.LargeVis is LargeVis


def test_estimator_matches_largevis_bitwise(data, fitted):
    """fit() is the functional pipeline verbatim: same key stream, same
    bits."""
    x, _ = data
    ref = largevis(x, jax.random.key(1), cfg=CFG)
    got = np.asarray(fitted.embedding_, np.float32)
    want = np.asarray(ref.y, np.float32)
    assert np.array_equal(got.view(np.uint32), want.view(np.uint32))


def test_result_is_fitted_model_carrier(fitted):
    r = fitted.result_
    assert isinstance(r, LargeVisResult)
    assert r.x is not None and r.x.shape[0] == r.y.shape[0]
    assert r.edge_sampler is not None and r.neg_sampler is not None
    assert r.cfg == CFG
    assert r.key is not None


def test_not_fitted_error():
    with pytest.raises(NotFittedError):
        LargeVis().transform(np.zeros((2, 4), np.float32))
    with pytest.raises(NotFittedError):
        _ = LargeVis().embedding_


def test_estimator_pickle_round_trip(data, fitted):
    """Model persistence: the estimator pickles whole and transforms
    identically after the round trip."""
    x, _ = data
    m2 = pickle.loads(pickle.dumps(fitted))
    assert np.array_equal(np.asarray(m2.embedding_),
                          np.asarray(fitted.embedding_))
    q = x[:5]
    assert np.array_equal(np.asarray(m2.transform(q)),
                          np.asarray(fitted.transform(q)))


def test_result_pickle_round_trip(fitted):
    r2 = pickle.loads(pickle.dumps(fitted.result_))
    assert np.array_equal(np.asarray(r2.y), np.asarray(fitted.result_.y))
    assert np.array_equal(np.asarray(r2.knn_idx),
                          np.asarray(fitted.result_.knn_idx))
    assert r2.cfg == fitted.result_.cfg


def test_cfg_keyword_only(data):
    """Config-like kwargs are keyword-only as of PR 7."""
    x, _ = data
    with pytest.raises(TypeError):
        largevis(x, KEY, CFG)
    from repro.core.largevis import build_graph, layout_graph
    with pytest.raises(TypeError):
        build_graph(x, KEY, CFG)
    with pytest.raises(TypeError):
        layout_graph(np.zeros((4, 2), np.int32), np.ones((4, 2)), KEY, CFG)


def test_cfg_none_is_fresh_not_singleton():
    """cfg=None constructs a fresh config — the mutable-singleton default
    (cfg: LargeVisConfig = DEFAULT) is gone from every entry point."""
    import importlib
    import inspect

    lv = importlib.import_module("repro.core.largevis")
    for fn in (lv.largevis, lv.build_graph, lv.layout_graph):
        sig = inspect.signature(fn)
        assert sig.parameters["cfg"].default is None, fn.__name__


# ---------------------------------------------------------------------------
# Routing consolidation + deprecated aliases
# ---------------------------------------------------------------------------

def test_routing_namespace_defaults():
    cfg = LargeVisConfig()
    assert cfg.routing == RoutingConfig()
    assert cfg.routing.knn == "auto"
    assert cfg.routing.sampler == "auto"
    assert cfg.routing.layout_step == "auto"
    assert cfg.routing.knn_stage == "auto"


def test_deprecated_knobs_warn_and_fold():
    """Old flat names keep working: DeprecationWarning + routing fold."""
    with pytest.warns(DeprecationWarning, match="fused_step"):
        cfg = LargeVisConfig(fused_step=False)
    assert cfg.routing.layout_step == "split"
    assert not cfg.fused_step

    with pytest.warns(DeprecationWarning, match="knn_distributed"):
        cfg = LargeVisConfig(knn_distributed=False)
    assert cfg.routing.knn_stage == "forest"

    with pytest.warns(DeprecationWarning, match="sampler_impl"):
        cfg = LargeVisConfig(sampler_impl="host")
    assert cfg.routing.sampler == "host"

    with pytest.warns(DeprecationWarning, match="knn_impl"):
        cfg = LargeVisConfig(knn_impl="ref")
    assert cfg.routing.knn == "ref"


def test_resolved_flat_values_readable():
    """After construction the flat aliases hold concrete routing-derived
    values, so legacy readers (cfg.fused_step in the layout drivers etc.)
    keep working without warnings."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg = LargeVisConfig(routing=RoutingConfig(layout_step="split",
                                                   sampler="host"))
        assert cfg.fused_step is not None and not cfg.fused_step
        assert cfg.sampler_impl == "host"
        assert cfg.knn_impl == "auto"
        assert cfg.knn_distributed


def test_replace_round_trips_stay_silent():
    """dataclasses.replace must not re-warn (the resolved flat values are
    marked, so they are recognized as routing-derived, not user-passed)."""
    with pytest.warns(DeprecationWarning):
        cfg = LargeVisConfig(fused_step=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg2 = dataclasses.replace(cfg, n_neighbors=7)
        assert cfg2.routing.layout_step == "split" and not cfg2.fused_step
        # replacing routing outright: routing wins over the stale alias
        cfg3 = dataclasses.replace(cfg, routing=RoutingConfig())
        assert cfg3.fused_step


def test_replace_flat_knob_overrides_stale_routing():
    """replace(cfg, fused_step=False) on a config whose routing already
    folded to 'fused' must flip to split — the fresh (unmarked) user value
    beats the stale routing, with the warning.  Routing wins silently only
    over its own marked derived values, never over new user input."""
    cfg_f = LargeVisConfig(routing=RoutingConfig(layout_step="fused"))
    with pytest.warns(DeprecationWarning):
        cfg_s = dataclasses.replace(cfg_f, fused_step=False)
    assert cfg_s.routing.layout_step == "split"
    assert not cfg_s.fused_step
    # a deprecated knob whose value AGREES with the routing resolution is
    # consistent: no warning, no fold (auto resolves to fused here)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert LargeVisConfig(fused_step=True).routing.layout_step == "auto"


def test_deprecated_split_step_still_runs(data):
    """The old knob spelled through the new machinery still routes the
    pipeline (split-step layout here) end to end."""
    x, _ = data
    with pytest.warns(DeprecationWarning):
        cfg = dataclasses.replace(CFG, fused_step=False)
    res = largevis(x[:120], jax.random.key(2), cfg=cfg)
    assert np.isfinite(np.asarray(res.y)).all()
