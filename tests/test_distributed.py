"""Multi-device semantics tests (4 host devices via a subprocess, so the
main pytest process keeps its single-device jax config).

Covers: sharded-vs-single train step equivalence, the local-SGD layout mode
(the paper's async-SGD analogue) actually running on 4 devices, and the
sharded LargeVis layout step executing (not just compiling).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, SRC)
import jax, jax.numpy as jnp, numpy as np
import dataclasses

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import make_train_step
from repro.models import make_model
from repro.optim.adamw import adamw_init

# ---- 1) sharded train step == single-device train step -------------------
cfg = get_config("llama3-8b").reduced()
model = make_model(cfg)
key = jax.random.key(0)
params = model["init"](key)
opt = adamw_init(params)
toks = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}

mesh = jax.make_mesh((2, 2), ("data", "model"))
shape_cfg = ShapeConfig("t", "train", 64, 8)
step, _, in_sh, out_sh = make_train_step(cfg, mesh, shape_cfg, microbatches=2)
with mesh:
    p2, o2, loss_sharded = jax.jit(step, in_shardings=in_sh,
                                   out_shardings=out_sh)(params, opt, batch)

mesh1 = jax.make_mesh((1, 1), ("data", "model"))
step1, _, in_sh1, out_sh1 = make_train_step(cfg, mesh1, shape_cfg,
                                            microbatches=2)
with mesh1:
    p1, o1, loss_single = jax.jit(step1, in_shardings=in_sh1,
                                  out_shardings=out_sh1)(params, opt, batch)
err = abs(float(loss_sharded) - float(loss_single))
assert err < 2e-3, f"train step loss mismatch: {err}"
# updated params agree
d = jax.tree.map(lambda a, b: float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)))), p1, p2)
mx = max(jax.tree.leaves(d))
assert mx < 2e-2, f"param update mismatch: {mx}"
print("TRAIN_EQUIV_OK", err, mx)

# ---- 2) local-SGD layout on 4 devices -------------------------------------
from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import build_graph
from repro.core.layout import run_layout_local_sgd
from repro.core.metrics import knn_classifier_accuracy
from repro.core import sampler as S
from repro.data.synthetic import gaussian_mixture

x, labels = gaussian_mixture(jax.random.key(1), 1500, 24, 6)
lv = LargeVisConfig(n_neighbors=12, n_trees=4, n_explore_iters=2, window=32,
                    perplexity=8.0, samples_per_node=1500, batch_size=1024,
                    sync_every=8)
idx, dist, w, _ = build_graph(x, jax.random.key(2), cfg=lv)
es = S.build_edge_sampler(idx, w)
ns = S.build_negative_sampler(idx, w)
mesh4 = jax.make_mesh((4,), ("data",))
res = run_layout_local_sgd(jax.random.key(3), es, ns, x.shape[0], lv, mesh4)
assert jnp.isfinite(res.y).all()
acc = knn_classifier_accuracy(res.y, labels, k=5)
assert acc > 0.7, f"local-SGD layout quality too low: {acc}"
print("LOCAL_SGD_OK", acc)

# ---- 3) sharded LargeVis step executes ------------------------------------
from repro.launch.steps import make_largevis_step
mesh22 = jax.make_mesh((2, 2), ("data", "model"))
n, e = x.shape[0], int(idx.size)
fn, specs, in_sh, out_sh = make_largevis_step(mesh22, n_nodes=n, n_edges=e,
                                              batch=512)
y0 = jax.random.normal(jax.random.key(9), (n, 2)) * 1e-3
with mesh22:
    y1 = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)(
        y0, jnp.asarray([7], jnp.int32), jnp.float32(0.0),
        es.src, es.dst, es.threshold, es.alias, ns.threshold, ns.alias)
assert jnp.isfinite(y1).all()
assert float(jnp.max(jnp.abs(y1 - y0))) > 0   # forces applied
print("SHARDED_STEP_OK")
"""


@pytest.mark.slow
def test_multi_device_semantics(tmp_path):
    script = _SCRIPT.replace("SRC", repr(os.path.join(REPO, "src")))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "TRAIN_EQUIV_OK" in proc.stdout
    assert "LOCAL_SGD_OK" in proc.stdout
    assert "SHARDED_STEP_OK" in proc.stdout
