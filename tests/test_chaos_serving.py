"""Fault-injected serving (PR 8): the ProjectionEngine under chaos.

Why per-slot isolation is provable bitwise, not just approximately:
every slot's positive/negative edge endpoints are CORPUS rows (frozen
by the kernel's ``n_frozen`` masking) — slots never touch each other's
rows; randomness is threefry counter-derived per element, so one slot's
values cannot perturb another slot's draws; and submit-time quarantine
keeps poisoned requests out of the queue entirely, so slot assignment
and the key stream match a healthy-only run exactly.  The parity tests
below therefore assert ``array_equal``, not ``allclose``.
"""
import numpy as np
import pytest

import jax

from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import largevis
from repro.launch.serve_projection import (ProjectionEngine, ProjectRequest,
                                           QueueFullError)
from repro.runtime.fault_tolerance import FaultInjector

N, D = 400, 16
CFG = LargeVisConfig(n_neighbors=8, n_trees=2, n_explore_iters=1, window=16,
                     perplexity=6.0, samples_per_node=200, batch_size=128,
                     steps_per_dispatch=20, transform_steps=12)


@pytest.fixture(scope="module")
def model():
    x = np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)
    return largevis(x, jax.random.key(7), cfg=CFG)


def _queries(q=16, seed=5):
    return np.random.default_rng(seed).normal(size=(q, D)).astype(np.float32)


def _drain(model, reqs, **engine_kw):
    eng = ProjectionEngine(model, slots=8, seed=3, **engine_kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng


# ---------------------------------------------------------------------------
# quarantine + parity
# ---------------------------------------------------------------------------

def test_poisoned_queries_quarantined_healthy_bitwise_unaffected(model):
    """Interleave NaN queries with healthy ones: the bad ones complete
    with errors in ``quarantined``; every healthy request's coordinates
    are bitwise what a fault-free, healthy-only run produces."""
    q = _queries(12)
    ref = _drain(model, [ProjectRequest(rid=i, x=q[i]) for i in range(12)])
    ref_y = {r.rid: r.y for r in ref.completed}
    assert len(ref_y) == 12 and not ref.quarantined

    eng = ProjectionEngine(model, slots=8, seed=3)
    bad_rids = []
    for i in range(12):
        assert eng.submit(ProjectRequest(rid=i, x=q[i]))
        if i % 3 == 0:          # interleave poison between healthy traffic
            bad = ProjectRequest(rid=100 + i,
                                 x=np.full(D, np.nan, np.float32))
            assert not eng.submit(bad)      # rejected at the door
            bad_rids.append(bad.rid)
    eng.run()
    assert sorted(r.rid for r in eng.quarantined) == bad_rids
    assert all(r.error is not None and r.y is None
               for r in eng.quarantined)
    assert len(eng.completed) == 12
    for r in eng.completed:
        assert np.array_equal(r.y, ref_y[r.rid]), r.rid


def test_wrong_dim_query_quarantined(model):
    eng = ProjectionEngine(model, slots=4)
    assert not eng.submit(ProjectRequest(rid=0, x=np.zeros(D + 3,
                                                           np.float32)))
    assert eng.quarantined[0].error and "dim" in eng.quarantined[0].error


def test_corpus_bitwise_frozen_under_chaos(model):
    """Slot-row corruption injected mid-flight cannot leak into the
    fitted corpus: corpus rows are bitwise-identical after a chaotic
    drain (kernel n_frozen masking + slot edge structure)."""
    corpus_before = np.asarray(model.y).copy()

    def corrupt_slots(y_full):
        # NaN two slot rows directly in the resident embedding
        return y_full.at[N + 2].set(np.nan).at[N + 5].set(np.nan)

    fi = FaultInjector({"step": {4: corrupt_slots, 9: "exception"}})
    eng = _drain(model, [ProjectRequest(rid=i, x=x)
                         for i, x in enumerate(_queries(20))], fault=fi)
    assert np.array_equal(np.asarray(eng.y_full[:N]), corpus_before)
    assert eng.faults_retried == 1
    # the two corrupted slots' requests were quarantined at retire with
    # a divergence error, everything else completed
    assert all("non-finite" in r.error for r in eng.quarantined)
    assert len(eng.completed) + len(eng.quarantined) == 20


def test_step_exception_retry_is_bitwise_transparent(model):
    """An injected step exception is retried by run() with zero state
    drift — final coordinates bitwise match a fault-free drain."""
    q = _queries(10)
    ref = _drain(model, [ProjectRequest(rid=i, x=q[i]) for i in range(10)])
    fi = FaultInjector({"step": {0: "exception", 5: "exception"}})
    eng = _drain(model, [ProjectRequest(rid=i, x=q[i]) for i in range(10)],
                 fault=fi)
    assert eng.faults_retried == 2
    ref_y = {r.rid: r.y for r in ref.completed}
    assert len(eng.completed) == 10
    for r in eng.completed:
        assert np.array_equal(r.y, ref_y[r.rid])


def test_prefill_corruption_contained_to_its_slot(model):
    """NaN one admitted row's init coords: only that request retires
    with an error; co-admitted requests complete bitwise-clean."""
    q = _queries(6)
    ref = _drain(model, [ProjectRequest(rid=i, x=q[i]) for i in range(6)])
    ref_y = {r.rid: r.y for r in ref.completed}

    def poison_row0(payload):
        nn_idx, p_log, y0 = payload
        return nn_idx, p_log, y0.at[0].set(np.nan)

    fi = FaultInjector({"prefill": {0: poison_row0}})
    eng = _drain(model, [ProjectRequest(rid=i, x=q[i]) for i in range(6)],
                 fault=fi)
    assert [r.rid for r in eng.quarantined] == [0]
    assert "non-finite" in eng.quarantined[0].error
    assert sorted(r.rid for r in eng.completed) == [1, 2, 3, 4, 5]
    for r in eng.completed:
        assert np.array_equal(r.y, ref_y[r.rid])


# ---------------------------------------------------------------------------
# budgets + backpressure
# ---------------------------------------------------------------------------

def test_slot_step_budget_retires_stuck_slot(model):
    """A slot that cannot finish inside its budget is force-retired with
    an error instead of pinning the slot forever (self-healing)."""
    eng = ProjectionEngine(model, slots=4, seed=3, slot_step_budget=5)
    assert eng.slot_step_budget < eng.steps     # guaranteed to trip
    for i, x in enumerate(_queries(4)):
        eng.submit(ProjectRequest(rid=i, x=x))
    eng.run()
    assert len(eng.quarantined) == 4
    assert all("budget" in r.error for r in eng.quarantined)
    assert all(r is None for r in eng.requests)     # slots freed


def test_default_budget_never_trips_healthy_traffic(model):
    eng = _drain(model, [ProjectRequest(rid=i, x=x)
                         for i, x in enumerate(_queries(30))])
    assert not eng.quarantined and len(eng.completed) == 30


def test_queue_backpressure(model):
    eng = ProjectionEngine(model, slots=2, max_queue=3)
    for i in range(3):
        eng.submit(ProjectRequest(rid=i, x=_queries(1)[0]))
    with pytest.raises(QueueFullError):
        eng.submit(ProjectRequest(rid=99, x=_queries(1)[0]))
    eng.run()                                   # drains fine afterwards
    assert len(eng.completed) == 3
