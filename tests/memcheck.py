"""Peak-memory invariant harness for pipeline stages (PR 6 tentpole).

The paper's scaling claim (C5, fig6) only holds if no stage of the
pipeline materializes a super-linear temporary: the forbidden shapes
are the O(N·K·K) candidate blow-ups (e.g. gathering candidate
*coordinates* — an extra ×d — for a whole slab at once) and the
O(N²/P) distance matrices the streaming kernels exist to avoid.  PRs
1/3/5 asserted this per-test with hand-rolled substring matches; this
module is the shared, documented form used by ``tests/test_memcheck.py``
for every stage of ``largevis(distributed=True)`` and available to any
future stage test.

Usage::

    import memcheck
    report = memcheck.check_stage(
        "symmetrize",
        perplexity._symmetrize_scan.lower(idx_spec, p_spec, tile=4096),
        limit_bytes=8 * N_K_BYTES,          # generous linear bound ...
        forbidden=[(N, K, K)],              # ... plus explicit blow-ups
    )

``check_stage`` runs the buffer assertions against BOTH the StableHLO
lowering and (by default) the XLA-optimized HLO after compilation — a
fused lowering can still be rematerialized by the compiler, so only the
post-optimization text proves the peak.  When the backend implements
``compiled.memory_analysis()`` the report also carries XLA's own
``temp_size_in_bytes`` for logging/asserting total (not just
single-buffer) peaks.

Run the whole invariant suite locally with::

    PYTHONPATH=src python -m pytest -q tests/test_memcheck.py
"""
from __future__ import annotations

import dataclasses

import hlo_checks


@dataclasses.dataclass
class StageReport:
    name: str
    largest_lowered: tuple       # (nbytes, dtype, shape)
    largest_compiled: tuple | None
    temp_bytes: int | None       # XLA memory_analysis, when available

    def __str__(self):
        return (f"[{self.name}] lowered max {self.largest_lowered}, "
                f"compiled max {self.largest_compiled}, "
                f"temp {self.temp_bytes}")


def check_stage(name: str, lowered, *, limit_bytes: int,
                forbidden=(), compile: bool = True,
                temp_limit_bytes: int | None = None) -> StageReport:
    """Assert the stage's memory invariants; return a :class:`StageReport`.

    lowered          a ``jax.jit(f).lower(...)`` result (build it from
                     ``jax.ShapeDtypeStruct`` specs — no real buffers
                     needed, so paper-scale N is cheap to check)
    limit_bytes      no single buffer may exceed this.  Pick it between
                     the stage's legitimate output/working-set size and
                     the smallest forbidden blow-up so a super-linear
                     temporary fails loudly.
    forbidden        explicit shape runs that must not appear in any
                     buffer (e.g. ``[(N, K, K), (N, N)]``) — catches
                     blow-ups even when they'd sneak under limit_bytes.
    compile          also compile and re-check the optimized HLO (and
                     collect ``memory_analysis`` when implemented).
    temp_limit_bytes optional bound on XLA's reported total temp
                     allocation; only enforced when the backend
                     implements ``memory_analysis``.
    """
    text = lowered.as_text()
    hlo_checks.assert_no_buffer_larger_than(text, limit_bytes,
                                            what=f"{name}/stablehlo")
    for dims in forbidden:
        hlo_checks.assert_no_buffer(text, dims, what=f"{name}/stablehlo")
    largest_compiled = None
    temp = None
    if compile:
        compiled = lowered.compile()
        ctext = compiled.as_text()
        hlo_checks.assert_no_buffer_larger_than(ctext, limit_bytes,
                                                what=f"{name}/optimized")
        for dims in forbidden:
            hlo_checks.assert_no_buffer(ctext, dims,
                                        what=f"{name}/optimized")
        largest_compiled = hlo_checks.largest_buffer(ctext)
        try:
            temp = int(compiled.memory_analysis().temp_size_in_bytes)
        except Exception:                      # backend without analysis
            temp = None
        if temp is not None and temp_limit_bytes is not None:
            assert temp <= temp_limit_bytes, (
                f"[{name}] XLA temp allocation {temp} B exceeds "
                f"{temp_limit_bytes} B")
    return StageReport(name, hlo_checks.largest_buffer(text),
                       largest_compiled, temp)
