"""Shared HLO/StableHLO assertion helpers for pipeline-stage tests.

PRs 1/3/5 hand-rolled the same two checks in every kernel test — "this
lowering never materializes a buffer shaped like the thing we fused
away" and "this lowering contains no <op>" — as raw substring matches
against ``lowered.as_text()``.  This module promotes them into one
parsed, documented helper set used by every stage test (and by the
``tests/memcheck.py`` peak-memory harness).

Two text formats appear in practice and both are handled:

* StableHLO MLIR from ``jax.jit(f).lower(...).as_text()`` — buffers are
  ``tensor<403x7xf32>``;
* optimized HLO from ``.lower(...).compile().as_text()`` — buffers are
  ``f32[403,7]{1,0}``.

The buffer checks parse every typed buffer out of the text, so they are
robust to formatting (no accidental substring hits inside constants or
metadata) and can reason in *bytes*, which is what the O(N·K·K) /
O(N²/P) memory invariants are actually about.  Op checks stay substring
matches on purpose: HLO op mnemonics (``sort``, ``while``,
``custom-call``, ``dynamic-slice``) are short and the call sites want
"none anywhere in the program" semantics.
"""
from __future__ import annotations

import re

# dtype byte widths for every dtype jax lowers in this repo; unknown
# dtypes conservatively count as 4 bytes
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "i1": 1,
    "i64": 8, "i32": 4, "i16": 2, "i8": 1,
}

# tensor<2x3xf32> / tensor<f32>; dims group is the leading "2x3x"
_MLIR_RE = re.compile(r"tensor<((?:\d+x)*)([a-z][a-z0-9]*)>")
# f32[2,3]{1,0} / pred[] — require the bracket to follow the dtype token
_HLO_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def dtype_bytes(dtype: str) -> int:
    return _DTYPE_BYTES.get(dtype, 4)


def iter_buffers(text: str):
    """Yield every typed buffer in ``text`` as ``(dtype, shape, nbytes)``.

    ``shape`` is a tuple of ints (``()`` for scalars).  Works on both
    StableHLO MLIR and optimized-HLO text; duplicates are yielded as
    often as they appear (callers that want distinct shapes can set()).
    """
    for m in _MLIR_RE.finditer(text):
        dims, dtype = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split("x") if d)
        yield dtype, shape, _nbytes(dtype, shape)
    for m in _HLO_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        yield dtype, shape, _nbytes(dtype, shape)


def _nbytes(dtype: str, shape) -> int:
    n = dtype_bytes(dtype)
    for d in shape:
        n *= d
    return n


def _shape_contains(shape, dims) -> bool:
    """True when ``dims`` appears as a contiguous run inside ``shape``
    (so ``(N, K, K)`` matches both ``tensor<NxKxKxf32>`` and a scanned
    ``tensor<TxNxKxKxf32>``)."""
    dims = tuple(dims)
    k = len(dims)
    return any(shape[i:i + k] == dims for i in range(len(shape) - k + 1))


def has_buffer(text: str, dims, dtype: str | None = None) -> bool:
    """True if any buffer's shape contains the contiguous ``dims`` run
    (optionally restricted to ``dtype``)."""
    for dt, shape, _ in iter_buffers(text):
        if dtype is not None and dt != dtype:
            continue
        if _shape_contains(shape, dims):
            return True
    return False


def assert_no_buffer(text: str, dims, dtype: str | None = None,
                     what: str = ""):
    """Assert no buffer whose shape contains the ``dims`` run exists —
    the "we fused this temporary away" check."""
    offenders = sorted({
        (dt, shape) for dt, shape, _ in iter_buffers(text)
        if (dtype is None or dt == dtype) and _shape_contains(shape, dims)
    })
    assert not offenders, (
        f"forbidden buffer shape {tuple(dims)} "
        f"{'(' + what + ') ' if what else ''}found in lowering: "
        f"{offenders[:8]}")


def assert_no_buffer_larger_than(text: str, limit_bytes: int,
                                 what: str = ""):
    """Assert every buffer in ``text`` is at most ``limit_bytes``.

    This is the shared peak-memory invariant: pick ``limit_bytes``
    between the stage's legitimate output size and the forbidden
    O(N·K·K) / O(N²/P) blow-up, and any super-linear temporary fails
    loudly with its shape and size."""
    offenders = sorted(
        {(nb, dt, shape) for dt, shape, nb in iter_buffers(text)
         if nb > limit_bytes},
        reverse=True)
    assert not offenders, (
        f"buffer(s) over {limit_bytes} bytes "
        f"{'(' + what + ') ' if what else ''}in lowering: "
        + ", ".join(f"{dt}{list(shape)}={nb}B"
                    for nb, dt, shape in offenders[:8]))


def largest_buffer(text: str):
    """(nbytes, dtype, shape) of the largest buffer, or (0, '', ())."""
    best = (0, "", ())
    for dt, shape, nb in iter_buffers(text):
        if nb > best[0]:
            best = (nb, dt, shape)
    return best


def count_op(text: str, op: str) -> int:
    """Occurrences of the op mnemonic (plain substring count — HLO op
    names are short and unambiguous in practice)."""
    return text.count(op)


def assert_no_op(text: str, *ops: str, what: str = ""):
    """Assert none of the op mnemonics appear anywhere in the text
    (e.g. ``assert_no_op(hlo, "sort", "top_k")`` for a fused top-k)."""
    hit = [op for op in ops if op in text]
    assert not hit, (
        f"forbidden op(s) {hit} {'(' + what + ') ' if what else ''}"
        f"present in lowering")


def assert_has_op(text: str, *ops: str, what: str = ""):
    """Assert every op mnemonic appears (sanity check that the lowering
    actually contains the structure the negative checks constrain)."""
    missing = [op for op in ops if op not in text]
    assert not missing, (
        f"expected op(s) {missing} {'(' + what + ') ' if what else ''}"
        f"absent from lowering")
