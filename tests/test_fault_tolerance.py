"""Fault tolerance: kill mid-training -> restart -> bit-identical trajectory;
straggler watchdog; preemption guard."""
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np

from repro.runtime.fault_tolerance import Watchdog

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_train(steps, ckpt_dir, resume=True, collect=True):
    """Run the real training driver in-process and return its loss list."""
    from repro.launch.train import train
    _, _, losses = train("qwen1.5-0.5b", steps=steps, batch=4, seq=32,
                         ckpt_dir=ckpt_dir, save_every=4, resume=resume,
                         log_every=1000)
    return dict(losses)


def test_restart_bit_identical(tmp_path):
    """An interrupted-then-resumed run reproduces the uninterrupted run's
    loss trajectory exactly (checkpoint + deterministic data skip)."""
    ref = _run_train(12, str(tmp_path / "ref"), resume=False)
    # interrupted run: first 6 steps (checkpoint lands at step 4)
    _run_train(6, str(tmp_path / "int"), resume=False)
    resumed = _run_train(12, str(tmp_path / "int"), resume=True)
    for s in range(8, 12):          # steps strictly after the resume point
        assert s in resumed
        np.testing.assert_allclose(resumed[s], ref[s], rtol=0, atol=0), \
            f"step {s}: {resumed[s]} != {ref[s]}"


def test_kill_mid_save_never_corrupts(tmp_path):
    """SIGKILL during checkpointing leaves only committed checkpoints."""
    code = f"""
import sys, os
sys.path.insert(0, {REPO + "/src"!r})
import jax, jax.numpy as jnp
from repro.checkpoint import checkpointer as ck
t = {{"w": jnp.ones((4096, 1024))}}
for s in range(1, 200):
    ck.save({str(tmp_path)!r}, s, t)
"""
    proc = subprocess.Popen([sys.executable, "-c", code])
    from repro.checkpoint import checkpointer as ck
    # wait for the first commit (import + backend init are box-speed
    # dependent), then give the loop a beat so the kill lands mid-save
    deadline = time.time() + 60.0
    while not ck.all_steps(tmp_path) and time.time() < deadline:
        time.sleep(0.1)
    time.sleep(1.5)
    proc.kill()
    proc.wait()
    steps = ck.all_steps(tmp_path)
    assert steps, "no committed checkpoint at all"
    # every committed checkpoint must restore cleanly
    got, step = ck.restore(tmp_path)
    assert float(np.asarray(got["w"]).sum()) == 4096 * 1024


def test_watchdog_flags_stragglers():
    dog = Watchdog(threshold=3.0)
    for s in range(30):
        dog.observe(s, 0.1)
    assert not dog.stragglers
    assert dog.observe(30, 0.9)
    assert dog.stragglers[0][0] == 30


def test_preemption_guard_checkpoints_on_sigterm(tmp_path):
    code = f"""
import sys, os, time, signal
sys.path.insert(0, {REPO + "/src"!r})
import jax.numpy as jnp
from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault_tolerance import PreemptionGuard
mgr = CheckpointManager({str(tmp_path)!r})
state = {{"w": jnp.arange(10)}}
guard = PreemptionGuard(lambda: mgr.save_now(7, state))
print("READY", flush=True)
while not guard.triggered:
    time.sleep(0.05)
print("SAVED", flush=True)
"""
    proc = subprocess.Popen([sys.executable, "-c", code],
                            stdout=subprocess.PIPE, text=True)
    assert proc.stdout.readline().strip() == "READY"
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=30)
    assert "SAVED" in out
    from repro.checkpoint import checkpointer as ck
    assert ck.latest_step(tmp_path) == 7
