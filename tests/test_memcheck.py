"""Peak-memory invariants for every `largevis(distributed=True)` stage.

Each test lowers a pipeline stage from ``jax.ShapeDtypeStruct`` specs at
paper-adjacent scale (N=250k tier-1; N=1M behind ``-m slow``) and runs
the shared ``memcheck.check_stage`` harness: no single buffer above a
stage-specific linear bound, and no buffer shaped like the forbidden
O(N·K·K) candidate-coordinate blow-up or O(N²/P) distance matrix — in
both the StableHLO lowering and the XLA-optimized HLO.

Lowering from specs allocates nothing, so checking million-point shapes
is cheap; the harness proves the *compiled program* cannot allocate the
forbidden temporary, which is stronger than observing one run's RSS.

The tests adapt to the visible device count (``make_data_mesh(0)``):
under the CI mesh-smoke job (4 host devices) the same invariants are
checked against the real per-device partitioning.
"""
import jax
import jax.numpy as jnp
import pytest

import memcheck

from repro.core import knn_sharded
from repro.core import perplexity
from repro.core import sampler as S
from repro.launch.mesh import make_data_mesh
from repro.launch.steps import make_largevis_step_sharded
from repro.runtime import sharding as sh

N = 250_000
K = 15
D = 16
SDS = jax.ShapeDtypeStruct
F32, I32 = jnp.float32, jnp.int32


def _padded(n, mesh):
    p = mesh.shape["data"]
    return sh.rows_per_shard(n, p) * p


def _graph_stage_checks(n):
    """Run the calibrate / symmetrize / sampler-build invariants at n."""
    mesh = make_data_mesh(0)
    np_ = _padded(n, mesh)
    nk = n * K * 4                                   # one (N, K) f32
    e = n * K                                        # directed edge count

    memcheck.check_stage(
        f"calibrate_p[n={n}]",
        perplexity.calibrate_p.lower(SDS((n, K), F32), 50.0, iters=64),
        limit_bytes=4 * nk, forbidden=[(n, K, K)],
        temp_limit_bytes=8 * nk)

    memcheck.check_stage(
        f"calibrate_p_sharded[n={n}]",
        perplexity._make_calibrate_sharded(mesh, "data", 64).lower(
            SDS((np_, K), F32), SDS((), F32)),
        limit_bytes=4 * nk, forbidden=[(n, K, K)],
        temp_limit_bytes=8 * nk)

    memcheck.check_stage(
        f"symmetrize[n={n}]",
        perplexity._symmetrize_scan.lower(SDS((n, K), I32),
                                          SDS((n, K), F32), tile=4096),
        limit_bytes=4 * nk, forbidden=[(n, K, K)],
        temp_limit_bytes=8 * nk)

    tile = int(min(4096, sh.rows_per_shard(n, mesh.shape["data"])))
    memcheck.check_stage(
        f"symmetrize_sharded[n={n}]",
        perplexity._make_symmetrize_sharded(mesh, "data", n, tile).lower(
            SDS((np_, K), I32), SDS((np_, K), F32), SDS((np_,), I32)),
        limit_bytes=4 * nk, forbidden=[(n, K, K)],
        temp_limit_bytes=8 * nk)

    # alias builds sort the (E,) weight vector in the f64 pairing scope:
    # working set is a small multiple of E * 8 bytes, never E * K
    scope, hi = S._pairing_scope()
    with scope:
        memcheck.check_stage(
            f"edge_sampler[n={n}]",
            S._build_edge_sampler_device.lower(
                SDS((n, K), I32), SDS((n, K), F32), hi_dtype=hi),
            limit_bytes=6 * e * 8, forbidden=[(n, K, K)],
            temp_limit_bytes=16 * e * 8)
        memcheck.check_stage(
            f"neg_sampler[n={n}]",
            S._build_negative_sampler_device.lower(
                SDS((n, K), I32), SDS((n, K), F32), power=0.75,
                hi_dtype=hi),
            limit_bytes=6 * e * 8, forbidden=[(n, K, K)],
            temp_limit_bytes=16 * e * 8)
        memcheck.check_stage(
            f"sampler_sharded[n={n}]",
            S._make_sharded_builder_fn(mesh, "data", n, 0.75, hi).lower(
                SDS((np_, K), I32), SDS((np_, K), F32), SDS((np_,), I32)),
            limit_bytes=6 * e * 8, forbidden=[(n, K, K)],
            temp_limit_bytes=16 * e * 8)


def _knn_stage_check(n):
    """KNN ring + explore: candidate *id/distance* tables are the
    accepted per-shard working set (O(n_loc * K^2) ints), but candidate
    *coordinates* (the extra ×d) and any (n, n) distance matrix are
    forbidden — the explore ring tiles its gathers instead."""
    mesh = make_data_mesh(0)
    p = mesh.shape["data"]
    n_loc = sh.rows_per_shard(n, p)
    c = K * K + K
    fn = knn_sharded._make_sharded_fn(
        mesh, "data", n_shards=p, n_real=n, k=K, n_trees=4, depth=8,
        iters=1, sample=0, impl="auto")
    memcheck.check_stage(
        f"knn_ring[n={n},p={p}]",
        fn.lower(SDS((n_loc * p, D), F32), SDS((n_loc * p,), I32),
                 SDS((D, 32), F32), SDS((1,), I32)),
        limit_bytes=n_loc * c * D * 4 // 3,
        forbidden=[(n_loc, c, D), (n_loc, n_loc * p), (n, n)])


def _layout_stage_check(n):
    """Sharded local-SGD step: tables + y only, no (B, n) or (n, n)."""
    mesh = make_data_mesh(0)
    p = mesh.shape["data"]
    e = sh.rows_per_shard(n, p) * p * K
    batch = 4096
    step, specs, in_sh, out_sh = make_largevis_step_sharded(
        mesh, n_nodes=n, n_edges=e, batch=batch)
    memcheck.check_stage(
        f"layout_step_sharded[n={n},p={p}]",
        jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=(0,)).lower(*specs),
        limit_bytes=4 * e * 4, forbidden=[(batch, n), (n, n)])


def test_graph_stage_memory_invariants():
    _graph_stage_checks(N)


def test_knn_stage_memory_invariants():
    _knn_stage_check(N)


def test_layout_stage_memory_invariants():
    _layout_stage_check(N)


@pytest.mark.slow
def test_stage_memory_invariants_1m():
    """The acceptance-criteria scale: one million points."""
    _graph_stage_checks(1_000_000)
    _knn_stage_check(1_000_000)
    _layout_stage_check(1_000_000)
