"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import knn as knn_lib
from repro.core import perplexity
from repro.core import sampler as sampler_lib
from repro.kernels import ref

KEY = jax.random.key(42)
COMMON = dict(deadline=None, max_examples=20)


@settings(**COMMON)
@given(m=st.integers(2, 40), n=st.integers(2, 40), d=st.integers(1, 30),
       seed=st.integers(0, 2**20))
def test_pairwise_sqdist_properties(m, n, d, seed):
    """Nonnegative; zero iff identical rows; matches norm identity."""
    k = jax.random.key(seed)
    a = jax.random.normal(k, (m, d))
    b = jax.random.normal(jax.random.fold_in(k, 1), (n, d))
    D = np.asarray(ref.pairwise_sqdist_ref(a, b))
    assert (D >= 0).all()
    Dself = np.asarray(ref.pairwise_sqdist_ref(a, a))
    np.testing.assert_allclose(np.diag(Dself), 0.0, atol=1e-4)
    # symmetry of the self-distance matrix
    np.testing.assert_allclose(Dself, Dself.T, atol=1e-4)


@settings(**COMMON)
@given(n=st.integers(3, 200), k=st.integers(1, 10), seed=st.integers(0, 99))
def test_brute_force_knn_invariants(n, k, seed):
    """No self edges; distances sorted ascending; ids in range."""
    k = min(k, n - 1)
    x = jax.random.normal(jax.random.key(seed), (n, 8))
    idx, dist = knn_lib.brute_force_knn(x, k)
    idx_n, d_n = np.asarray(idx), np.asarray(dist)
    assert ((idx_n >= 0) & (idx_n < n)).all()
    assert (idx_n != np.arange(n)[:, None]).all()
    assert (np.diff(d_n, axis=1) >= -1e-4).all()


@settings(**COMMON)
@given(rows=st.integers(1, 20), c=st.integers(2, 30), k=st.integers(1, 8),
       seed=st.integers(0, 99))
def test_merge_candidates_invariants(rows, c, k, seed):
    """Output has no duplicate ids per row (where real candidates exist)."""
    k = min(k, c)
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, 50, (rows, c)), jnp.int32)
    d = jnp.asarray(rng.random((rows, c)), jnp.float32)
    mi, md = knn_lib.merge_candidates(ids, d, k)
    mi_n, md_n = np.asarray(mi), np.asarray(md)
    for r in range(rows):
        real = mi_n[r][md_n[r] < 1e37]
        assert len(set(real.tolist())) == len(real)
    # chosen dists are the k smallest achievable over unique ids
    assert (np.diff(md_n, axis=1) >= -1e-5).all()


@settings(**COMMON)
@given(n=st.integers(2, 500), seed=st.integers(0, 99))
def test_alias_table_preserves_distribution(n, seed):
    rng = np.random.default_rng(seed)
    p = rng.random(n) ** 2 + 1e-9
    thr, alias = sampler_lib.build_alias(p)
    # exact invariant of Vose construction: sum of slot masses == n normalized
    mass = thr.copy().astype(np.float64)
    np.add.at(mass, alias, 1.0 - thr)
    np.testing.assert_allclose(mass, p / p.sum() * n, atol=1e-4)


@settings(**COMMON)
@given(n=st.integers(5, 60), k=st.integers(2, 10),
       u=st.floats(1.5, 8.0), seed=st.integers(0, 99))
def test_perplexity_rows_stochastic_and_on_target(n, k, u, seed):
    k = min(k, n - 1)
    u = min(u, k * 0.9)
    x = jax.random.normal(jax.random.key(seed), (n, 6))
    _, dist = knn_lib.brute_force_knn(x, k)
    p = perplexity.calibrate_p(dist, u)
    p_n = np.asarray(p)
    np.testing.assert_allclose(p_n.sum(1), 1.0, atol=1e-3)
    assert (p_n >= -1e-7).all()
    realized = np.asarray(perplexity.perplexity_of(p))
    # perplexity is achievable when u < k; allow boundary slack
    assert np.median(np.abs(realized - u)) < max(0.25 * u, 0.5)


@settings(**COMMON)
@given(n=st.integers(5, 120), k=st.integers(2, 8), p_shards=st.integers(1, 7),
       u=st.floats(1.5, 6.0), seed=st.integers(0, 99))
def test_sharded_weight_decomposition_bitwise(n, k, p_shards, u, seed):
    """The sharded calibrate/symmetrize decomposition is bitwise-exact
    for arbitrary N, K, P — including N not divisible by P.

    The test drives jitted copies of the very body functions the
    shard_map drivers run (`_calibrate_rows` per row block;
    `_reverse_rows_scan` + the combine per block against the padded
    gathered table — the all-gather hands every shard exactly this
    table) through the contiguous-block row layout of
    ``runtime/sharding.py``, so block boundaries, padded rows, and
    remainder tiles are all exercised at shard counts a single-device
    pytest session cannot instantiate as a real mesh (the 8-device
    subprocess test covers the shard_map plumbing itself).  Jitting
    matters: the drivers are jitted, and XLA lowers the constant
    division in the combine to a reciprocal multiply, which an eager
    re-derivation would not reproduce bitwise."""
    import functools
    from repro.runtime import sharding as sh

    k = min(k, n - 1)
    u = min(u, k * 0.9)
    x = jax.random.normal(jax.random.key(seed), (n, 6))
    idx, dist = knn_lib.brute_force_knn(x, k)

    p_ref = perplexity.calibrate_p(dist, u)
    w_ref = perplexity.symmetrize(idx, p_ref)

    cal = jax.jit(perplexity._calibrate_rows, static_argnums=2)

    @functools.partial(jax.jit, static_argnames=("n_real", "tile"))
    def sym_block(idx_pad, p_pad, rows_loc, *, n_real, tile):
        p_loc = p_pad[rows_loc]
        rev = perplexity._reverse_rows_scan(idx_pad, p_pad, rows_loc,
                                            tile=tile)
        return (p_loc + rev) / (2.0 * n_real)

    n_loc = sh.rows_per_shard(n, p_shards)
    d2_pad = sh.pad_rows(dist, p_shards)
    idx_pad = sh.pad_rows(idx, p_shards)
    p_pad = sh.pad_rows(p_ref, p_shards)
    tile = int(min(4096, n_loc))
    p_blocks, w_blocks = [], []
    for s in range(p_shards):
        sl = slice(s * n_loc, (s + 1) * n_loc)
        p_blocks.append(cal(d2_pad[sl], u, 64))
        rows_loc = jnp.arange(sl.start, sl.stop, dtype=jnp.int32)
        w_blocks.append(sym_block(idx_pad, p_pad, rows_loc, n_real=n,
                                  tile=tile))
    p_sh = jnp.concatenate(p_blocks)[:n]
    w_sh = jnp.concatenate(w_blocks)[:n]
    assert np.array_equal(np.asarray(p_ref), np.asarray(p_sh))
    assert np.array_equal(np.asarray(w_ref), np.asarray(w_sh))


@settings(**COMMON)
@given(b=st.integers(1, 32), m=st.integers(1, 6), seed=st.integers(0, 99))
def test_largevis_grad_clip_bound(b, m, seed):
    """Per-coordinate clip bound holds for arbitrary geometry."""
    k = jax.random.key(seed)
    yi = jax.random.normal(k, (b, 2)) * 10
    yj = jax.random.normal(jax.random.fold_in(k, 1), (b, 2)) * 10
    yn = jax.random.normal(jax.random.fold_in(k, 2), (b, m, 2)) * 10
    gi, gj, gn = ref.largevis_grads_ref(yi, yj, yn,
                                        neg_mask=jnp.ones((b, m)))
    for g in (gi, gj, gn):
        assert float(jnp.abs(g).max()) <= 5.0 + 1e-6


@settings(**COMMON)
@given(seed=st.integers(0, 99), scale=st.floats(0.1, 5.0))
def test_rope_preserves_norm_and_relativity(seed, scale):
    """RoPE is a rotation: preserves norms; q.k depends only on pos gap."""
    from repro.models.layers import apply_rope
    k = jax.random.key(seed)
    x = jax.random.normal(k, (1, 8, 2, 16)) * scale
    pos = jnp.arange(8)
    r = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=2e-3)
    # relative property: <r_p q, r_{p+g} k> == <r_0 q, r_g k>
    q = jax.random.normal(jax.random.fold_in(k, 3), (1, 1, 1, 16))
    kk = jax.random.normal(jax.random.fold_in(k, 4), (1, 1, 1, 16))
    def dot_at(p, g):
        rq = apply_rope(q, jnp.array([p]), 10000.0)
        rk = apply_rope(kk, jnp.array([p + g]), 10000.0)
        return float(jnp.sum(rq * rk))
    np.testing.assert_allclose(dot_at(0, 3), dot_at(5, 3), rtol=2e-3,
                               atol=1e-4)


@settings(**COMMON)
@given(seed=st.integers(0, 99))
def test_moe_combine_is_convex(seed):
    """With topk=E and uniform router, MoE output == mean of expert FFNs."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_apply
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              n_experts=2, topk_experts=2)
    k = jax.random.key(seed)
    p = init_moe(k, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))      # uniform routing
    x = jax.random.normal(jax.random.fold_in(k, 1), (1, 8, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    # manual average of both experts
    outs = []
    for e in range(2):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    want = 0.5 * (outs[0] + outs[1])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)
    # uniform routing: f_e = p_e = 1/E  =>  aux = E * E*(1/E^2) = 1
    assert abs(float(aux) - 1.0) < 1e-5
