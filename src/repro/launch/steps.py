"""Step builders: sharded train / prefill / decode steps per (arch, shape).

Each builder returns (fn, in_shardings, out_shardings, arg_specs) ready for
``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*arg_specs)`` —
the dry-run compiles exactly what the production launcher runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import input_specs
from repro.models import make_model, param_specs
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime import sharding as sh
from repro.runtime.compat import shard_map


def _out_tree_shardings(out_specs, mesh, *, global_batch: int):
    """Rule-based shardings for a (logits, cache)-style output pytree."""
    dp = sh.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_first = global_batch % dp_size == 0 and global_batch >= dp_size

    def one(path, leaf):
        s = sh._path_str(path)
        shape = leaf.shape
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if leaf.ndim == 2 and shape[-1] > 1024:          # logits (B, V)
            spec = [dp if batch_first else None, "model"]
            return NamedSharding(mesh, sh._guard(mesh, shape, spec))
        if s.endswith("encoder_out") or s.endswith("_scale") or any(
                s.endswith(t) for t in ("/k", "/v", "/ssm", "/conv", "/C",
                                        "/n", "/m", "/c", "/h")):
            return NamedSharding(
                mesh, sh._cache_pspec(s, shape, mesh, batch_first))
        spec = [dp if batch_first and shape[0] == global_batch else None]
        spec += [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, sh._guard(mesh, shape, spec))

    return jax.tree_util.tree_map_with_path(one, out_specs)


def pick_microbatches(mesh, shape_cfg, *, tokens_budget: int = 8192) -> int:
    """Largest divisor of the per-device batch that brings per-microbatch
    tokens/device under budget (activation memory = one microbatch slice;
    grads accumulate in f32 across microbatches)."""
    dp_size = 1
    for a in sh.dp_axes(mesh):
        dp_size *= mesh.shape[a]
    per_dev_batch = max(1, shape_cfg.global_batch // dp_size)
    per_dev_tokens = per_dev_batch * shape_cfg.seq_len
    target = max(1, per_dev_tokens // tokens_budget)
    n = 1
    for cand in range(1, per_dev_batch + 1):
        if per_dev_batch % cand == 0 and cand <= target:
            n = cand
    return n


def make_train_step(cfg, mesh, shape_cfg, *, opt_cfg: AdamWConfig = None,
                    microbatches: int = 0):
    """Returns (train_step, arg_specs, in_shardings, out_shardings).

    Gradient accumulation over microbatches bounds activation memory: the
    assigned train shape (1M tokens/step global) is far beyond one
    microbatch per 16 GB chip.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    model = make_model(cfg)
    n_micro = microbatches or pick_microbatches(mesh, shape_cfg)

    def train_step(params, opt_state, batch):
        with sh.activation_policy(mesh, global_batch=shape_cfg.global_batch,
                                  train=True):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(model["loss"])(params,
                                                                batch)
            else:
                mb = jax.tree.map(
                    lambda a: sh.constrain_dim(
                        a.reshape((n_micro, a.shape[0] // n_micro)
                                  + a.shape[1:]), 1), batch)

                def micro_fn(carry, one):
                    gacc, lacc = carry
                    l, g = jax.value_and_grad(model["loss"])(params, one)
                    gacc = jax.tree.map(
                        lambda acc, gi: acc + gi.astype(jnp.float32),
                        gacc, g)
                    return (gacc, lacc + l), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(
                    micro_fn, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                loss = lsum / n_micro
            params, opt_state, stats = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        return params, opt_state, loss

    p_specs = param_specs(cfg)
    o_specs = jax.eval_shape(adamw_init, p_specs)
    b_specs = input_specs(cfg, shape_cfg)

    p_shard = sh.params_shardings(p_specs, mesh, train=True)
    o_shard = {"m": sh.params_shardings(p_specs, mesh, train=True),
               "v": sh.params_shardings(p_specs, mesh, train=True),
               "step": NamedSharding(mesh, P())}
    b_shard = sh.batch_shardings(b_specs, mesh,
                                 global_batch=shape_cfg.global_batch)
    in_sh = (p_shard, o_shard, b_shard)
    out_sh = (p_shard, o_shard, NamedSharding(mesh, P()))
    return train_step, (p_specs, o_specs, b_specs), in_sh, out_sh


def make_prefill_step(cfg, mesh, shape_cfg):
    from repro.models.attention import kv_tp_repeat
    kv_rep = kv_tp_repeat(cfg, mesh.shape["model"])
    model = make_model(cfg, kv_repeat=kv_rep)

    def prefill_step(params, batch):
        with sh.activation_policy(mesh, global_batch=shape_cfg.global_batch):
            return model["prefill"](params, batch)

    p_specs = param_specs(cfg, inference=True)
    b_specs = input_specs(cfg, shape_cfg)
    p_shard = sh.params_shardings(p_specs, mesh, train=False)
    b_shard = sh.batch_shardings(b_specs, mesh,
                                 global_batch=shape_cfg.global_batch)
    out_specs = jax.eval_shape(prefill_step, p_specs, b_specs)
    out_sh = _out_tree_shardings(out_specs, mesh,
                                 global_batch=shape_cfg.global_batch)
    return prefill_step, (p_specs, b_specs), (p_shard, b_shard), out_sh


def make_decode_step(cfg, mesh, shape_cfg, *, kv_quant: bool = False):
    from repro.models.attention import kv_tp_repeat
    kv_rep = kv_tp_repeat(cfg, mesh.shape["model"])
    model = make_model(cfg, kv_repeat=kv_rep, kv_quant=kv_quant)

    def decode_step(params, batch):
        with sh.activation_policy(mesh, global_batch=shape_cfg.global_batch):
            return model["decode"](params, batch)

    p_specs = param_specs(cfg, inference=True)
    b_specs = input_specs(cfg, shape_cfg, kv_repeat=kv_rep,
                          kv_quant=kv_quant)
    p_shard = sh.params_shardings(p_specs, mesh, train=False)
    b_shard = sh.batch_shardings(b_specs, mesh,
                                 global_batch=shape_cfg.global_batch)
    out_specs = jax.eval_shape(decode_step, p_specs, b_specs)
    out_sh = _out_tree_shardings(out_specs, mesh,
                                 global_batch=shape_cfg.global_batch)
    return decode_step, (p_specs, b_specs), (p_shard, b_shard), out_sh


def make_step(cfg, mesh, shape_cfg):
    if shape_cfg.kind == "train":
        return make_train_step(cfg, mesh, shape_cfg)
    if shape_cfg.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape_cfg)
    return make_decode_step(cfg, mesh, shape_cfg)


# ---------------------------------------------------------------------------
# LargeVis layout step — the paper technique's own production cell
# ---------------------------------------------------------------------------

def make_largevis_step_local(mesh, *, n_nodes: int, n_edges: int,
                             batch: int, out_dim: int = 2,
                             n_negatives: int = 5, sync_every: int = 8,
                             fused_step: bool = True):
    """§Perf hillclimb 3: per-shard edge sampling + local-SGD sync.

    The v1 step shards the edge alias tables over DP and lets every device
    draw global indices — XLA materializes cross-shard table gathers (~2 GB
    per step).  The reference LargeVis gives each Hogwild thread its OWN
    sampling range, so the faithful distributed form is: each device holds
    a local alias table over its edge shard, samples locally (stratified
    sampling, proportional allocation), applies ``sync_every`` local update
    steps, and replicas merge with one delta-psum — the local-SGD analogue
    of the paper's async SGD (DESIGN.md §2).

    The H local steps are one scanned loop (``layout_engine``), the same
    body the single-device engine dispatches.  The wire format stays six
    flat table arrays (the dry-run lowering interface needs per-array
    shardings: edge tables shard over DP, node tables replicate); the
    body immediately reassembles them into the sampler pytrees the shared
    ``sgd_edge_step`` signature takes — each device's local
    ``EdgeSampler`` covers exactly its edge shard.
    """
    from repro.core.layout_engine import scan_layout_steps
    from repro.core.sampler import EdgeSampler, NodeSampler

    dp = sh.dp_axes(mesh)
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    b_loc = max(1, batch // n_shards)
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    def step(y, seed, t_frac, edge_src, edge_dst, edge_thr, edge_alias,
             neg_thr, neg_alias):
        def body(y, seed, t_frac, esrc, edst, ethr, eali, nthr, nali):
            dev = jax.lax.axis_index(dp[-1])
            if len(dp) > 1:
                dev = dev + mesh.shape[dp[-1]] * jax.lax.axis_index(dp[0])
            y0 = y
            es = EdgeSampler(esrc, edst, ethr, eali, int(esrc.shape[0]))
            ns = NodeSampler(nthr, nali, n_nodes)
            base_key = jax.random.fold_in(jax.random.key(seed[0]), dev)
            step_ids = jnp.arange(sync_every, dtype=jnp.int32)
            y = scan_layout_steps(
                y, base_key, step_ids,
                jnp.broadcast_to(t_frac, (sync_every,)).astype(jnp.float32),
                edge_sampler=es, neg_sampler=ns, n_negatives=n_negatives,
                n_nodes=n_nodes, batch=b_loc, fused_step=fused_step)
            # merge replicas: Hogwild-sum of the deltas (one psum per H
            # steps) — every sampled edge's update lands at full lr, as
            # in the paper's async SGD; a mean would under-step the
            # schedule P-fold (see core/layout.make_local_sgd_fns)
            return y0 + jax.lax.psum(y - y0, dp)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(), P(dp), P(dp), P(dp), P(dp),
                      P(), P()),
            out_specs=P(), check_vma=False,
        )(y, seed, t_frac, edge_src, edge_dst, edge_thr, edge_alias,
          neg_thr, neg_alias)

    rep = NamedSharding(mesh, P())
    table = NamedSharding(mesh, sh._guard(mesh, (n_edges,), [dp]))
    arg_specs = (sds((n_nodes, out_dim), f32), sds((1,), i32), sds((), f32),
                 sds((n_edges,), i32), sds((n_edges,), i32),
                 sds((n_edges,), f32), sds((n_edges,), i32),
                 sds((n_nodes,), f32), sds((n_nodes,), i32))
    in_sh = (rep, rep, rep, table, table, table, table, rep, rep)
    return step, arg_specs, in_sh, rep


def make_largevis_step_sharded(mesh, *, n_nodes: int, n_edges: int,
                               batch: int, out_dim: int = 2,
                               n_negatives: int = 5, sync_every: int = 8,
                               fused_step: bool = True):
    """Local-SGD step over the *per-shard* sampler tables that
    ``sampler.build_samplers_sharded`` emits (PR 6 pipeline form).

    Unlike ``make_largevis_step_local`` — which slices one flat global
    alias table into slabs, leaving alias pointers that cross slab
    boundaries dangling — this builder's wire format is the stacked
    (P, E_loc) tables whose alias entries are LOCAL edge indices, so a
    device's slice is a self-contained alias table over exactly its
    edge shard (the reference implementation's per-thread sampling
    range).  Negatives sample *globally* through the two-level
    :class:`~repro.core.sampler.ShardedNodeSampler` (tiny replicated
    shard-selection table + stacked per-shard node tables), matching
    the paper's noise distribution P_n(j) ∝ deg(j)^0.75 over ALL nodes.

    Wire format: eleven flat arrays (per-array shardings for the
    dry-run lowering interface) — edge tables shard their leading (P,)
    axis over DP; neg + shard-selection tables replicate.
    """
    from repro.core.layout_engine import scan_layout_steps
    from repro.core.sampler import EdgeSampler, ShardedNodeSampler

    dp = sh.dp_axes(mesh)
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    if n_edges % n_shards:
        raise ValueError(f"n_edges={n_edges} not a multiple of the DP "
                         f"size {n_shards} (pad rows first)")
    if n_nodes < n_shards:
        # same constraint the elastic checkpoint restore enforces via its
        # topology tag (checkpoint/largevis_state.py): fewer rows than
        # shards cannot fill the contiguous-block layout
        raise ValueError(f"n_nodes={n_nodes} < DP size {n_shards}: rows "
                         f"cannot cover the mesh one block per device")
    e_loc = n_edges // n_shards
    n_loc = -(-n_nodes // n_shards)
    b_loc = max(1, batch // n_shards)
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    def step(y, seed, t_frac, edge_src, edge_dst, edge_thr, edge_alias,
             neg_thr, neg_alias, neg_sthr, neg_sali):
        def body(y, seed, t_frac, esrc, edst, ethr, eali, nthr, nali,
                 nsthr, nsali):
            dev = jax.lax.axis_index(dp[-1])
            if len(dp) > 1:
                dev = dev + mesh.shape[dp[-1]] * jax.lax.axis_index(dp[0])
            y0 = y
            es = EdgeSampler(esrc[0], edst[0], ethr[0], eali[0], e_loc)
            ns = ShardedNodeSampler(nthr, nali, nsthr, nsali, n_shards,
                                    n_nodes)
            base_key = jax.random.fold_in(jax.random.key(seed[0]), dev)
            step_ids = jnp.arange(sync_every, dtype=jnp.int32)
            y = scan_layout_steps(
                y, base_key, step_ids,
                jnp.broadcast_to(t_frac, (sync_every,)).astype(jnp.float32),
                edge_sampler=es, neg_sampler=ns, n_negatives=n_negatives,
                n_nodes=n_nodes, batch=b_loc, fused_step=fused_step)
            # Hogwild-sum delta merge (see make_largevis_step_local)
            return y0 + jax.lax.psum(y - y0, dp)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(), P(dp, None), P(dp, None), P(dp, None),
                      P(dp, None), P(), P(), P(), P()),
            out_specs=P(), check_vma=False,
        )(y, seed, t_frac, edge_src, edge_dst, edge_thr, edge_alias,
          neg_thr, neg_alias, neg_sthr, neg_sali)

    rep = NamedSharding(mesh, P())
    table = NamedSharding(mesh, sh._guard(mesh, (n_shards, e_loc),
                                          [dp, None]))
    arg_specs = (sds((n_nodes, out_dim), f32), sds((1,), i32), sds((), f32),
                 sds((n_shards, e_loc), i32), sds((n_shards, e_loc), i32),
                 sds((n_shards, e_loc), f32), sds((n_shards, e_loc), i32),
                 sds((n_shards, n_loc), f32), sds((n_shards, n_loc), i32),
                 sds((n_shards,), f32), sds((n_shards,), i32))
    in_sh = (rep, rep, rep, table, table, table, table, rep, rep, rep, rep)
    return step, arg_specs, in_sh, rep


def make_largevis_transform_step(mesh, *, n_corpus: int, n_slots: int,
                                 k: int, out_dim: int = 2,
                                 n_negatives: int = 5, steps: int = 48,
                                 rho0: float = 1.0):
    """The projection server's lockstep "decode" as a launch-harness cell.

    One step of the continuous-batching projection engine
    (``launch/serve_projection.py``): every serving slot draws one
    positive edge from its own calibrated neighbor distribution plus M
    noise negatives and takes a fused edge step at its OWN schedule
    position (the kernel's per-edge (B,) lr mode), with the corpus rows
    of the resident ``[corpus; slots]`` embedding frozen via
    ``n_frozen`` masking.  Same 4-tuple contract as the LM builders;
    everything replicates (the working set is (N+S) x s f32 — tiny).

    Wire format: y_full (N+S, s), seed (1,), p_log (S, k), nn_idx
    (S, k), ages (S,) i32, active (S,) i32, neg_thr (N,), neg_alias (N,).
    """
    from repro.core.layout_engine import apply_edge_batch
    from repro.core.sampler import NodeSampler
    from repro.core.transform import sample_query_edges

    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    def step(y_full, seed, p_log, nn_idx, ages, active, neg_thr, neg_alias):
        ns = NodeSampler(neg_thr, neg_alias, n_corpus)
        key = jax.random.key(seed[0])
        i = n_corpus + jnp.arange(n_slots, dtype=i32)
        j, negs, neg_mask = sample_query_edges(
            key, p_log, nn_idx, ns, n_negatives)
        act = active.astype(bool)
        j = jnp.where(act, j, i)
        neg_mask = neg_mask * active.astype(f32)[:, None]
        lr = rho0 * jnp.maximum(1.0 - ages.astype(f32) / steps, 1e-4)
        return apply_edge_batch(y_full, i, j, negs, neg_mask, lr,
                                n_frozen=n_corpus)

    rep = NamedSharding(mesh, P())
    arg_specs = (sds((n_corpus + n_slots, out_dim), f32), sds((1,), i32),
                 sds((n_slots, k), f32), sds((n_slots, k), i32),
                 sds((n_slots,), i32), sds((n_slots,), i32),
                 sds((n_corpus,), f32), sds((n_corpus,), i32))
    in_sh = (rep,) * len(arg_specs)
    return step, arg_specs, in_sh, rep


def make_largevis_step(mesh, *, n_nodes: int, n_edges: int, batch: int,
                       out_dim: int = 2, n_negatives: int = 5):
    """Sharded layout step: edge batch over DP axes, embedding table
    replicated below 10M nodes (N x 2 f32 is tiny), grads combined by
    scatter-add.  Returns the same 4-tuple as the LM builders.  Flat
    table arrays on the wire (per-array shardings), sampler pytrees
    inside — same shared step signature as every other driver."""
    from repro.core.layout import layout_step
    from repro.core.sampler import EdgeSampler, NodeSampler

    dp = sh.dp_axes(mesh)
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    args = {
        "y": sds((n_nodes, out_dim), f32),
        "edge_src": sds((n_edges,), i32),
        "edge_dst": sds((n_edges,), i32),
        "edge_thr": sds((n_edges,), f32),
        "edge_alias": sds((n_edges,), i32),
        "neg_thr": sds((n_nodes,), f32),
        "neg_alias": sds((n_nodes,), i32),
    }

    def step(y, seed, t_frac, edge_src, edge_dst, edge_thr, edge_alias,
             neg_thr, neg_alias):
        key = jax.random.key(seed[0])
        es = EdgeSampler(edge_src, edge_dst, edge_thr, edge_alias, n_edges)
        ns = NodeSampler(neg_thr, neg_alias, n_nodes)
        return layout_step(
            y, key, t_frac, edge_sampler=es, neg_sampler=ns,
            n_negatives=n_negatives, n_nodes=n_nodes, batch=batch)

    rep = NamedSharding(mesh, P())
    table = NamedSharding(mesh, sh._guard(mesh, (n_edges,), [dp]))
    node_t = NamedSharding(mesh, sh._guard(mesh, (n_nodes,), [dp]))
    arg_specs = (args["y"], sds((1,), i32), sds((), f32), args["edge_src"],
                 args["edge_dst"], args["edge_thr"], args["edge_alias"],
                 args["neg_thr"], args["neg_alias"])
    in_sh = (rep, rep, rep, table, table, table, table, node_t, node_t)
    out_sh = rep
    return step, arg_specs, in_sh, out_sh
