"""Batched serving driver: prefill + decode loop with a continuous batch.

Production shape: requests arrive with prompts; the engine prefilites each
prompt (left-padded into the fixed cache), then decodes all active slots in
lockstep, retiring finished sequences and admitting queued requests into
freed slots (continuous batching).  Greedy or temperature sampling.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import make_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot continuous-batching engine (batch slots x max_len cache)."""

    def __init__(self, cfg, *, slots: int = 4, max_len: int = 128,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.model = make_model(cfg)
        self.params = self.model["init"](jax.random.key(seed))
        self.key = jax.random.key(seed + 1)
        self._decode = jax.jit(self.model["decode"])
        self._prefill = jax.jit(self.model["prefill"],
                                static_argnames=())
        # slot state
        self.active: List[Optional[Request]] = [None] * slots
        self.positions = jnp.zeros((slots,), jnp.int32)
        self.cache = None
        self.queue: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _init_cache(self):
        from repro.models.factory import cache_specs
        specs = cache_specs(self.cfg, self.slots, self.max_len)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs)

    def _admit(self):
        """Fill free slots by prefilling queued prompts (one at a time into
        the batch cache via per-slot dynamic update)."""
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            batch = {"tokens": toks}
            if self.cfg.is_encoder_decoder:
                batch["encoder_frames"] = jnp.zeros(
                    (1, self.cfg.enc_positions, self.cfg.d_model),
                    self.cfg.dtype)
            logits, cache1 = self._prefill(self.params, batch)
            # splice the single-sequence cache into this slot
            def put(full, one):
                if one.ndim >= 2 and one.shape[1] == 1:      # (..,1,..) batch
                    pass
                return full
            self.cache = jax.tree.map(
                lambda full, one: self._splice(full, one, slot),
                self.cache, cache1)
            tok = self._sample(logits)[0]
            req.out.append(int(tok))
            self.active[slot] = req
            self.positions = self.positions.at[slot].set(len(req.prompt))

    def _splice(self, full, one, slot):
        """Insert a prefill cache (batch=1, seq=P) into slot's row."""
        if one.ndim < 2:
            return full
        # stacked leaves: (n_periods, 1, P, ...) -> rows at dim 1
        p = one.shape[2] if one.ndim >= 3 else None
        sl = [slice(None)] * full.ndim
        sl[1] = slice(slot, slot + 1)
        if one.ndim >= 3 and one.shape[2] <= full.shape[2]:
            sl[2] = slice(0, one.shape[2])
        return full.at[tuple(sl)].set(one.astype(full.dtype))

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.temperature, axis=-1)

    def step(self):
        """One lockstep decode over all active slots."""
        if self.cache is None:
            self._init_cache()
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        last = jnp.asarray(
            [[r.out[-1] if r and r.out else 0] for r in self.active],
            jnp.int32)
        batch = {"tokens": last, "cache": self.cache,
                 "position": self.positions}
        logits, self.cache = self._decode(self.params, batch)
        toks = self._sample(logits)
        self.positions = self.positions + 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(toks[slot]))
            if len(req.out) >= req.max_new or \
                    int(self.positions[slot]) >= self.max_len - 1:
                req.done = True
                self.active[slot] = None
        return True

    def run(self, max_steps: int = 10_000):
        done = []
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
            done = [r for r in done]
        return steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    eng = ServeEngine(cfg, slots=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
                    .tolist(), max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    steps = eng.run()
    dt = time.time() - t0
    n_tokens = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {n_tokens} tokens, "
          f"{steps} engine steps, {dt:.1f}s")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
