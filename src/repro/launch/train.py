"""Production training driver: --arch <id> --steps N [--resume].

Wires: model factory -> sharded train step -> checkpoint manager (atomic,
rotating, auto-resume) -> preemption guard -> straggler watchdog.  On this
container it runs reduced configs on the host mesh; on a pod the same
driver runs the full config on make_production_mesh().
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import token_stream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import make_model
from repro.optim.adamw import adamw_init
from repro.runtime.fault_tolerance import PreemptionGuard, Watchdog


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          ckpt_dir: str = "/tmp/repro_ckpt", save_every: int = 20,
          resume: bool = True, reduced: bool = True, production: bool = False,
          seed: int = 0, log_every: int = 10, microbatches: int = 1):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if production else make_host_mesh()
    shape_cfg = ShapeConfig("custom", "train", seq, batch)
    step_fn, _, in_sh, out_sh = make_train_step(
        cfg, mesh, shape_cfg, microbatches=microbatches)
    jstep = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=(0, 1))

    model = make_model(cfg)
    mgr = CheckpointManager(ckpt_dir, save_every=save_every)
    state, start = (mgr.resume() if resume else (None, 0))
    if state is None:
        params = model["init"](jax.random.key(seed))
        opt_state = adamw_init(params)
        start = 0
    else:
        params, opt_state = state["params"], state["opt"]
    guard = PreemptionGuard(lambda: mgr.save_now(
        -1, {"params": params, "opt": opt_state}))
    dog = Watchdog()

    losses = []
    data = token_stream(jax.random.key(seed + 1), steps, batch, seq,
                        cfg.vocab_size)
    for step, batch_data in enumerate(data):
        if step < start:          # deterministic data skip on resume
            continue
        t0 = time.time()
        params, opt_state, loss = jstep(params, opt_state, batch_data)
        loss = float(loss)
        losses.append((step, loss))
        dt = time.time() - t0
        dog.observe(step, dt)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} ({dt*1000:.0f} ms)")
        mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
        if guard.triggered:
            print("preemption: checkpointed and exiting")
            break
    guard.restore_handlers()
    if dog.stragglers:
        print(f"stragglers flagged: {len(dog.stragglers)}")
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          ckpt_dir=args.ckpt_dir, save_every=args.save_every,
          resume=not args.no_resume, reduced=not args.full_config,
          production=args.production_mesh)


if __name__ == "__main__":
    main()
