"""Single-body lowerings for trip-count cost correction (§Roofline).

``cost_analysis`` counts a scan body once, so the full-program numbers
undercount by the trip count.  Per cell we additionally lower:

  * train:   (a) one-microbatch value_and_grad  (the microbatch scan body)
             (b) one layer-period fwd+bwd       (the layer scan body)
  * prefill / decode: one layer-period step
  * enc-dec: one encoder layer + one decoder layer

under the SAME mesh/shardings as the full program, and reconstruct:

  total = full_raw
        + (n_micro - 1) * micro_raw
        + n_micro * [(n_periods - 1) * body_raw + n_periods * inner_corr]

(n_micro = 1 outside training; inner_corr = CostBook corrections for
sequence-level scans inside one period).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import make_model, param_specs
from repro.runtime import sharding as sh


def _period_param_specs(cfg, *, inference=False):
    full = param_specs(cfg, inference=inference)
    blocks = full["blocks"] if "blocks" in full else None
    if blocks is None:
        return None
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), blocks)


def _period_param_shardings(specs, mesh, *, train):
    def one(path, leaf):
        return NamedSharding(mesh, sh.param_pspec(path, leaf, mesh,
                                                  train=train,
                                                  stacked=False))
    return jax.tree_util.tree_map_with_path(one, specs)


def _x_spec(cfg, mesh, batch, seq, *, batch_first):
    dp = sh.dp_axes(mesh)
    spec = sh._guard(mesh, (batch, seq, cfg.d_model),
                     [dp if batch_first else None, None, None])
    return (jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.dtype),
            NamedSharding(mesh, spec))


def lower_period_body(cfg, mesh, shape_cfg):
    """Lower one layer-period under production shardings.
    Returns dict of lowered objects keyed by body name."""
    from repro.models import lm as LM

    kind = shape_cfg.kind
    train = kind == "train"
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    dp_size = 1
    for a in sh.dp_axes(mesh):
        dp_size *= mesh.shape[a]
    batch_first = B % dp_size == 0 and B >= dp_size
    out = {}

    if cfg.is_encoder_decoder:
        return _lower_encdec_bodies(cfg, mesh, shape_cfg, batch_first)

    pp_specs = _period_param_specs(cfg, inference=not train)
    pp_shard = _period_param_shardings(pp_specs, mesh, train=train)

    if kind == "train":
        from repro.launch.steps import pick_microbatches
        n_micro = pick_microbatches(mesh, shape_cfg)
        b_micro = B // n_micro
        x_specs, x_shard = _x_spec(cfg, mesh, b_micro, S,
                                   batch_first=batch_first)

        def body(pp, x):
            with sh.activation_policy(mesh, global_batch=b_micro,
                                      train=True):
                def f(pp, x):
                    y, _, aux = LM.apply_period(cfg, pp, x, mode="fwd",
                                                positions=jnp.arange(S))
                    return jnp.sum(y.astype(jnp.float32)) + aux
                return jax.grad(jax.checkpoint(f), argnums=(0, 1))(pp, x)

        out["period"] = (body, (pp_specs, x_specs), (pp_shard, x_shard),
                         dict(n_micro=n_micro, b_micro=b_micro))

        # one-microbatch loss+grad (micro scan body)
        model = make_model(cfg)
        full_p = param_specs(cfg)
        p_shard = sh.params_shardings(full_p, mesh, train=True)
        mb_specs = {
            "tokens": jax.ShapeDtypeStruct((b_micro, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b_micro, S), jnp.int32)}
        mb_shard = sh.batch_shardings(mb_specs, mesh, global_batch=b_micro)

        def micro(params, batch):
            with sh.activation_policy(mesh, global_batch=b_micro,
                                      train=True):
                return jax.value_and_grad(model["loss"])(params, batch)

        out["micro"] = (micro, (full_p, mb_specs), (p_shard, mb_shard),
                        dict())
        return out

    if kind == "prefill":
        x_specs, x_shard = _x_spec(cfg, mesh, B, S, batch_first=batch_first)

        def body(pp, x):
            with sh.activation_policy(mesh, global_batch=B):
                y, cache, _ = LM.apply_period(cfg, pp, x, mode="prefill",
                                              positions=jnp.arange(S))
                return y, cache

        out["period"] = (body, (pp_specs, x_specs), (pp_shard, x_shard),
                         dict(n_micro=1))
        return out

    # decode
    from repro.configs import kv_cache_specs
    from repro.models.attention import kv_tp_repeat
    kv_rep = kv_tp_repeat(cfg, mesh.shape["model"])
    cache_full = kv_cache_specs(cfg, B, S, kv_repeat=kv_rep)
    cache_slice = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), cache_full)

    def cache_shard(path, leaf):
        s = sh._path_str(path)
        spec = sh._cache_pspec("cache/" + s, (1,) + leaf.shape, mesh,
                               batch_first)
        return NamedSharding(mesh, P(*spec[1:]))

    c_shard = jax.tree_util.tree_map_with_path(cache_shard, cache_slice)
    x_specs, x_shard = _x_spec(cfg, mesh, B, 1, batch_first=batch_first)
    pos_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_shard = NamedSharding(mesh, sh._guard(
        mesh, (B,), [sh.dp_axes(mesh) if batch_first else None]))

    def body(pp, x, cache, position):
        with sh.activation_policy(mesh, global_batch=B):
            y, new_cache, _ = LM.apply_period(cfg, pp, x, mode="decode",
                                              cache=cache,
                                              position=position)
            return y, new_cache

    out["period"] = (body, (pp_specs, x_specs, cache_slice, pos_spec),
                     (pp_shard, x_shard, c_shard, pos_shard),
                     dict(n_micro=1))
    return out


def _lower_encdec_bodies(cfg, mesh, shape_cfg, batch_first):
    """whisper: one decoder layer (+ encoder layer for train/prefill)."""
    from repro.models import encdec as ED
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    train = shape_cfg.kind == "train"
    out = {}
    dec_specs = jax.eval_shape(
        lambda k: ED.init_dec_layer(k, cfg), jax.random.key(0))
    dec_shard = _period_param_shardings(dec_specs, mesh, train=train)
    sq = S if shape_cfg.kind != "decode" else 1
    x_specs, x_shard = _x_spec(cfg, mesh, B, sq, batch_first=batch_first)
    enc_specs, enc_shard = _x_spec(cfg, mesh, B, cfg.enc_positions,
                                   batch_first=batch_first)

    if shape_cfg.kind == "decode":
        from repro.configs import kv_cache_specs
        cache_full = kv_cache_specs(cfg, B, S)["self"]
        cache_slice = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), cache_full)
        c_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, sh._guard(
                mesh, s.shape,
                [sh.dp_axes(mesh) if batch_first else None]
                + [None] * (len(s.shape) - 1))), cache_slice)
        pos_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos_shard = NamedSharding(mesh, sh._guard(
            mesh, (B,), [sh.dp_axes(mesh) if batch_first else None]))

        def body(lp, x, enc, cache, position):
            with sh.activation_policy(mesh, global_batch=B):
                return ED._dec_layer(cfg, lp, x, enc, mode="decode",
                                     cache=cache, position=position)

        out["period"] = (body, (dec_specs, x_specs, enc_specs, cache_slice,
                                pos_spec),
                         (dec_shard, x_shard, enc_shard, c_shard, pos_shard),
                         dict(n_micro=1))
        return out

    def body(lp, x, enc):
        with sh.activation_policy(mesh, global_batch=B, train=train):
            if train:
                def f(lp, x):
                    y, _ = ED._dec_layer(cfg, lp, x, enc, mode="fwd",
                                         positions=jnp.arange(sq))
                    return jnp.sum(y.astype(jnp.float32))
                return jax.grad(f, argnums=(0, 1))(lp, x)
            y, c = ED._dec_layer(cfg, lp, x, enc, mode="prefill",
                                 positions=jnp.arange(sq))
            return y, c

    out["period"] = (body, (dec_specs, x_specs, enc_specs),
                     (dec_shard, x_shard, enc_shard), dict(n_micro=1))
    return out
