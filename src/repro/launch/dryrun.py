import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax-importing import: jax locks device count on init.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * 512 placeholder CPU devices back the production meshes
    (16x16 single-pod, 2x16x16 multi-pod);
  * every applicable (architecture x input shape) cell lowers and compiles
    with its production in/out shardings;
  * memory_analysis() (fits-per-device) and cost_analysis() (FLOPs/bytes)
    are printed and archived, plus the parsed collective-byte table the
    roofline consumes (launch/hlo_analysis.py).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs-file cells.txt]
  python -m repro.launch.dryrun --arch largevis --shape layout_4m --mesh single
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"

LARGEVIS_SHAPES = {
    # paper scale: LiveJournal ~4M nodes, K=150 edges/node
    "layout_4m": dict(n_nodes=4_000_000, n_edges=600_000_000,
                      batch=1 << 20),
    # §Perf hillclimb 3: per-shard sampling + local-SGD (H=8)
    "layout_4m_local": dict(n_nodes=4_000_000, n_edges=600_000_000,
                            batch=1 << 20, local=True),
    "layout_64m": dict(n_nodes=64_000_000, n_edges=9_600_000_000,
                       batch=1 << 22),
}


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: pathlib.Path,
             quiet: bool = False) -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_analysis as H
    from repro.models import costbook

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "devices": int(len(jax.devices())), "status": "ok"}
    t0 = time.time()
    try:
        if arch == "largevis":
            from repro.launch.steps import (make_largevis_step,
                                            make_largevis_step_local)
            spec = dict(LARGEVIS_SHAPES[shape])
            local = spec.pop("local", False)
            builder = make_largevis_step_local if local \
                else make_largevis_step
            fn, arg_specs, in_sh, out_sh = builder(mesh, **spec)
            rec["cell_kind"] = "largevis_layout"
        else:
            from repro.configs import get_config, SHAPES, cell_applicable
            from repro.launch.steps import make_step
            cfg = get_config(arch)
            shape_cfg = SHAPES[shape]
            ok, why = cell_applicable(cfg, shape_cfg)
            if not ok:
                rec["status"] = "skipped"
                rec["reason"] = why
                out_dir.mkdir(parents=True, exist_ok=True)
                (out_dir / f"{arch}__{shape}__{mesh_kind}.json").write_text(
                    json.dumps(rec, indent=1))
                if not quiet:
                    print(f"SKIP {arch} x {shape} x {mesh_kind}: {why}")
                return rec
            if os.environ.get("REPRO_KV_QUANT") and \
                    shape_cfg.kind == "decode":
                from repro.launch.steps import make_decode_step
                fn, arg_specs, in_sh, out_sh = make_decode_step(
                    cfg, mesh, shape_cfg, kv_quant=True)
                rec["kv_quant"] = True
            else:
                fn, arg_specs, in_sh, out_sh = make_step(cfg, mesh,
                                                         shape_cfg)
            rec["cell_kind"] = shape_cfg.kind
        donate = (0, 1) if rec.get("cell_kind") == "train" else ()
        if rec.get("cell_kind") == "decode":
            donate = (1,)                       # cache updated in place
        if arch == "largevis":
            donate = (0,)                       # layout table updated in place
        with mesh:
            with costbook.recording() as book:
                lowered = jax.jit(fn, in_shardings=in_sh,
                                  out_shardings=out_sh,
                                  donate_argnums=donate).lower(*arg_specs)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        mem = H.memory_stats(compiled)
        cost = H.cost_stats(compiled)
        hlo = compiled.as_text()
        coll = H.collective_bytes(hlo)
        coll.pop("while_trip_counts", None)
        if not quiet:
            print(f"== {arch} x {shape} x {mesh_kind} ==")
            print("memory_analysis:", json.dumps(mem))
            print("cost_analysis:", json.dumps(cost))
            print("collectives:", json.dumps(coll))
        rec.update(
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory=mem, cost=cost, collectives=coll,
            costbook=[dict(label=e.label, total_flops=e.total_flops,
                           total_bytes=e.total_bytes, trips=e.trips)
                      for e in book.entries],
            hlo_ops=hlo.count("\n"),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if not quiet:
            print(f"FAILED {arch} x {shape} x {mesh_kind}: {rec['error']}",
                  file=sys.stderr)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape}__{mesh_kind}.json"
    path.write_text(json.dumps(rec, indent=1))
    return rec


def run_body_cell(arch: str, shape: str, mesh_kind: str,
                  out_dir: pathlib.Path, quiet: bool = False) -> dict:
    """Lower the scan-body functions for the trip-count cost correction."""
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_analysis as H
    from repro.launch.body_lower import lower_period_body
    from repro.models import costbook
    from repro.configs import get_config, SHAPES, cell_applicable

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "status": "ok",
           "bodies": {}}
    t0 = time.time()
    try:
        cfg = get_config(arch)
        shape_cfg = SHAPES[shape]
        ok, why = cell_applicable(cfg, shape_cfg)
        if not ok:
            rec["status"] = "skipped"
            rec["reason"] = why
        else:
            rec["n_periods"] = (cfg.n_layers if cfg.is_encoder_decoder
                                else cfg.n_periods)
            bodies = lower_period_body(cfg, mesh, shape_cfg)
            with mesh:
                for name, (fn, arg_specs, in_sh, meta) in bodies.items():
                    with costbook.recording() as book:
                        lowered = jax.jit(fn, in_shardings=in_sh).lower(
                            *arg_specs)
                    compiled = lowered.compile()
                    hlo = compiled.as_text()
                    coll = H.collective_bytes(hlo)
                    coll.pop("while_trip_counts", None)
                    rec["bodies"][name] = dict(
                        cost=H.cost_stats(compiled), collectives=coll,
                        costbook=[dict(label=e.label,
                                       total_flops=e.total_flops,
                                       total_bytes=e.total_bytes,
                                       trips=e.trips)
                                  for e in book.entries],
                        **meta)
            rec["seconds"] = round(time.time() - t0, 1)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if not quiet:
            print(f"BODY FAILED {arch} x {shape}: {rec['error']}",
                  file=sys.stderr)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape}__{mesh_kind}__body.json").write_text(
        json.dumps(rec, indent=1))
    if not quiet and rec["status"] == "ok":
        print(f"body ok {arch} x {shape} x {mesh_kind} "
              f"({rec.get('seconds', 0)}s)")
    return rec


def all_cells(mesh_kinds):
    from repro.configs import ARCH_NAMES, SHAPES
    cells = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            for mk in mesh_kinds:
                cells.append((arch, shape, mk))
    for shape in ("layout_4m",):
        for mk in mesh_kinds:
            cells.append(("largevis", shape, mk))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--mode", default="full", choices=["full", "body"])
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in mesh_kinds:
            if args.mode == "body":
                rec = run_body_cell(args.arch, args.shape, mk, out_dir)
            else:
                rec = run_cell(args.arch, args.shape, mk, out_dir)
            if rec["status"] == "error":
                sys.exit(1)
        return

    # --all: subprocess per cell (fresh XLA state, bounded memory)
    cells = all_cells(mesh_kinds)
    if args.mode == "body":
        cells = [(a, s, m) for a, s, m in cells
                 if a != "largevis" and m == "single"]
    results = []
    for arch, shape, mk in cells:
        suffix = "__body" if args.mode == "body" else ""
        path = out_dir / f"{arch}__{shape}__{mk}{suffix}.json"
        if path.exists() and not args.force:
            rec = json.loads(path.read_text())
            results.append(rec)
            print(f"cached {arch} x {shape} x {mk}: {rec['status']}")
            continue
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mk, "--out", str(out_dir),
             "--mode", args.mode],
            capture_output=True, text=True, timeout=3600)
        if path.exists():
            rec = json.loads(path.read_text())
        else:
            rec = {"arch": arch, "shape": shape, "mesh": mk,
                   "status": "crash", "error": proc.stderr[-2000:]}
            out_dir.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(rec, indent=1))
        results.append(rec)
        print(f"{arch:18s} {shape:12s} {mk:6s} -> {rec['status']:8s}"
              f" ({time.time()-t0:.0f}s)")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_bad = len(results) - n_ok - n_skip
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_bad} FAILED "
          f"of {len(results)} cells")
    sys.exit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
