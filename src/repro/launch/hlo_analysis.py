"""HLO post-processing for the roofline: collective bytes + cost extraction.

``cost_analysis()`` has no collective accounting, so collective traffic is
parsed from the (optimized, SPMD-partitioned) HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute contributes
its largest-operand byte size.  Ops inside ``while`` bodies are multiplied
by the loop trip count when XLA annotates it (known_trip_count) — our layer
stacks are scans, so this matters.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'f32[128,256]{...}' -> 131072; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computation_blocks(hlo: str):
    """Split HLO text into (name, body) computation blocks."""
    blocks = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(%?[\w\.\-]+)\s*(\([^)]*\))?\s*->.*{$", stripped)
        if (stripped.startswith("ENTRY") or m) and stripped.endswith("{"):
            if cur_name is not None:
                blocks[cur_name] = cur_lines
            name = stripped.split()[0].lstrip("%")
            if stripped.startswith("ENTRY"):
                name = stripped.split()[1].lstrip("%")
            cur_name, cur_lines = name, []
        elif stripped == "}" and cur_name is not None:
            blocks[cur_name] = cur_lines
            cur_name, cur_lines = None, []
        elif cur_name is not None:
            cur_lines.append(stripped)
    if cur_name is not None:
        blocks[cur_name] = cur_lines
    return blocks


def _trip_counts(hlo: str, blocks) -> dict:
    """body-computation name -> known trip count (1 if unknown)."""
    trips = {}
    for line in hlo.splitlines():
        if " while(" in line or " = while(" in line or "while(" in line:
            if "body=" not in line:
                continue
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            tm = re.search(r'known_trip_count=\{?"?n"?[:=]"?(\d+)', line)
            if not tm:
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if bm:
                trips[bm.group(1)] = int(tm.group(1)) if tm else 1
    return trips


def collective_bytes(hlo: str) -> dict:
    """Sum collective operand bytes, trip-count aware.

    Returns {op_name: bytes, ..., 'total': bytes}.
    """
    blocks = _computation_blocks(hlo)
    trips = _trip_counts(hlo, blocks)
    out = {op: 0 for op in COLLECTIVE_OPS}

    def block_mult(name: str) -> int:
        return trips.get(name, 1)

    for name, lines in blocks.items():
        mult = block_mult(name)
        for line in lines:
            for op in COLLECTIVE_OPS:
                # match "= f32[...] all-gather(" etc.
                m = re.search(rf"=\s*([^=]*?)\s{re.escape(op)}(-start|-done)?\(",
                              line)
                if m and f" {op}" in line:
                    if m.group(2) == "-done":
                        continue        # counted at -start
                    out[op] += _shape_bytes(m.group(1)) * mult
                    break
    out["total"] = sum(out[o] for o in COLLECTIVE_OPS)
    out["while_trip_counts"] = trips
    return out


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = int(getattr(ma, k, 0) or 0)
    return out


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}
