"""Continuous-batching projection server: LargeVis ``transform`` as a
serving loop.

The LM serving driver (``launch/serve.py``) holds a fixed-slot batch and
steps every active sequence in lockstep — admit into freed slots, decode
all slots at once, retire finished sequences.  Projection serving is the
same shape with "decode" replaced by the fused frozen-corpus edge step:

* **prefill** — a queued query gets its corpus neighborhood (one batched
  ``ops.topk_sqdist`` over the whole admit block), its perplexity-
  calibrated neighbor distribution, and its weighted-mean init spliced
  into a free slot row of the resident ``[corpus; slots]`` embedding.
* **decode** — ONE ``layout_engine.apply_edge_batch`` dispatch moves all
  slots: each slot contributes one positive edge (slot -> neighbor ∝ its
  own p) plus M negatives from the fitted noise sampler, with a
  **per-slot learning rate** at the slot's own schedule position (the
  (B,) lr form of the fused kernel) — freshly admitted and nearly-done
  queries share the same lockstep dispatch.  Corpus rows are frozen by
  the kernel's ``n_frozen`` masking, so the fitted embedding stays
  bit-identical no matter how much traffic flows through.
* **retire** — a slot that has taken ``steps`` updates completes its
  request with the slot row's coordinates and frees the slot.

Inactive slots loop their positive edge back onto themselves with all
negatives masked — an exactly-zero gradient — so the step shape never
depends on occupancy and the engine compiles twice (prefill + step),
total, regardless of traffic.

``benchmarks/serve_latency.py`` drives this engine at 1k-100k concurrent
requests and reports queries/sec and p50/p99 latency.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.largevis_default import LargeVisConfig
from repro.core import perplexity as perp_lib
from repro.core.layout_engine import apply_edge_batch
from repro.core.transform import sample_query_edges, uniform_node_sampler
from repro.runtime.fault_tolerance import InjectedFault


class QueueFullError(RuntimeError):
    """Admission backpressure: ``submit`` refused because the engine's
    queue is at ``max_queue``.  The caller sheds load or retries later —
    unbounded queueing would instead grow latency without bound."""


@dataclasses.dataclass
class ProjectRequest:
    rid: int
    x: np.ndarray                      # (d,) query point
    y: Optional[np.ndarray] = None     # (s,) result, set at retire
    t_submit: float = 0.0
    t_done: float = 0.0
    done: bool = False
    error: Optional[str] = None        # set when quarantined/retired-on-error

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@functools.partial(jax.jit, static_argnames=("k", "perplexity", "iters"))
def _prefill_block(xq, x, y, *, k: int, perplexity: float, iters: int):
    """Neighborhoods + init coords for one admit block (A, d).

    Returns (nn_idx (A, k), p_log (A, k), y0 (A, s))."""
    from repro.kernels import ops
    nn_idx, nn_dist = ops.topk_sqdist(xq, x, k)
    p = perp_lib.calibrate_p(nn_dist, perplexity, iters=iters)
    return nn_idx, jnp.log(p), jnp.einsum("qk,qks->qs", p, y[nn_idx])


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("n_negatives", "steps", "rho0",
                                    "prob_fn", "a", "gamma", "clip",
                                    "fused_step"))
def _lockstep_step(y_full, key, p_log, nn_idx, ages, active, neg_sampler, *,
                   n_negatives: int, steps: int, rho0: float, prob_fn: str,
                   a: float, gamma: float, clip: float, fused_step: bool):
    """One lockstep transform step over all S slots (active or not).

    Slot s sits at schedule position ages[s]/steps -> its own lr (the
    fused kernel's per-edge (B,) lr mode).  Inactive slots are no-ops:
    positive edge looped onto the slot itself (zero attractive force)
    and negatives masked out.  ``y_full`` is donated — one resident
    (N+S, s) buffer across the engine's whole lifetime."""
    n_frozen = y_full.shape[0] - p_log.shape[0]
    s = p_log.shape[0]
    i = n_frozen + jnp.arange(s, dtype=jnp.int32)
    j, negs, neg_mask = sample_query_edges(
        key, p_log, nn_idx, neg_sampler, n_negatives)
    j = jnp.where(active, j, i)
    neg_mask = neg_mask * active[:, None].astype(jnp.float32)
    t_frac = ages.astype(jnp.float32) / steps
    lr = rho0 * jnp.maximum(1.0 - t_frac, 1e-4)
    y_full = apply_edge_batch(
        y_full, i, j, negs, neg_mask, lr, prob_fn=prob_fn, a=a, gamma=gamma,
        clip=clip, fused_step=fused_step, n_frozen=n_frozen)
    return y_full, ages + active.astype(jnp.int32)


class ProjectionEngine:
    """Fixed-slot continuous-batching engine over a fitted LargeVis model.

    ``model`` is anything with the fitted-carrier fields — a
    :class:`repro.core.largevis.LargeVisResult` or a fitted
    :class:`repro.LargeVis` estimator's ``result_``: ``x`` (N, d) corpus,
    ``y`` (N, s) frozen layout, optional ``neg_sampler``, ``cfg``.
    """

    def __init__(self, model, *, slots: int = 256,
                 cfg: LargeVisConfig | None = None, seed: int = 0,
                 max_queue: Optional[int] = None,
                 slot_step_budget: Optional[int] = None,
                 fault=None):
        cfg = cfg or getattr(model, "cfg", None) or LargeVisConfig()
        self.cfg = cfg
        self.slots = slots
        self.x = jnp.asarray(model.x)
        self.n = int(self.x.shape[0])
        self.k = min(cfg.n_neighbors, self.n)
        self.steps = int(cfg.transform_steps)
        self.neg_sampler = (getattr(model, "neg_sampler", None)
                            or uniform_node_sampler(self.n))
        y = jnp.asarray(model.y, jnp.float32)
        self.s_dim = int(y.shape[1])
        # resident [corpus; slots] embedding — corpus rows frozen forever
        self.y_full = jnp.concatenate(
            [y, jnp.zeros((slots, self.s_dim), jnp.float32)])
        self.p_log = jnp.full((slots, self.k), -jnp.inf, jnp.float32)
        # row 0 at p=1 so categorical on an inactive slot is well-defined
        self.p_log = self.p_log.at[:, 0].set(0.0)
        self.nn_idx = jnp.zeros((slots, self.k), jnp.int32)
        self.ages = jnp.zeros((slots,), jnp.int32)
        self.active = jnp.zeros((slots,), bool)
        # host mirror of ages (deterministic: +1 per step while occupied)
        # so retire checks never force a device sync
        self._host_ages = np.zeros((slots,), np.int64)
        self.key = jax.random.key(seed)
        self.step_no = 0
        self.queue: List[ProjectRequest] = []
        self.requests: List[Optional[ProjectRequest]] = [None] * slots
        self.completed: List[ProjectRequest] = []
        # robustness (PR 8): admission backpressure, per-slot step budget
        # (a stuck slot is force-retired with an error instead of pinning
        # its slot forever), the quarantine list for rejected/poisoned
        # requests, and the deterministic fault injector for chaos tests
        self.max_queue = max_queue
        self.slot_step_budget = (slot_step_budget if slot_step_budget
                                 else 4 * self.steps)
        self.fault = fault
        self.quarantined: List[ProjectRequest] = []
        self.faults_retried = 0
        # engine step at which each slot was admitted (budget clock)
        self._slot_born = np.zeros((slots,), np.int64)

    # ------------------------------------------------------------------
    def submit(self, req: ProjectRequest) -> bool:
        """Queue a request; returns False when it was quarantined instead.

        Validation happens HERE, not in the hot loop: a query row with
        the wrong dimensionality or any NaN/Inf never enters the queue
        (it completes immediately with ``req.error`` set and lands in
        ``self.quarantined``), so faulty traffic cannot perturb the slot
        assignment, key stream, or results of healthy requests — the
        healthy subset of a poisoned workload retires bitwise-equal to a
        fault-free run (tests/test_chaos_serving.py).  Raises
        :class:`QueueFullError` at ``max_queue`` (backpressure)."""
        req.t_submit = req.t_submit or time.time()
        if self.fault is not None:
            req = self.fault.fire("submit", req)
        xq = np.asarray(req.x, np.float32).reshape(-1)
        d = int(self.x.shape[1])
        if xq.shape[0] != d:
            req.error = (f"query dim {xq.shape[0]} != corpus dim {d}")
        elif not np.all(np.isfinite(xq)):
            req.error = "query contains NaN/Inf"
        if req.error is not None:
            req.done, req.t_done = True, time.time()
            self.quarantined.append(req)
            return False
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise QueueFullError(
                f"queue at max_queue={self.max_queue}; retry later")
        self.queue.append(req)
        return True

    def _admit(self):
        """Fill every free slot from the queue with ONE batched prefill.

        The admit block pads to the full slot count, so the prefill
        compiles once; padded rows are discarded."""
        free = [s for s in range(self.slots) if self.requests[s] is None]
        if not free or not self.queue:
            return
        n_adm = min(len(free), len(self.queue))
        batch = [self.queue.pop(0) for _ in range(n_adm)]
        xq = np.zeros((self.slots, self.x.shape[1]), np.float32)
        for b, req in enumerate(batch):
            xq[b] = req.x
        nn_idx, p_log, y0 = _prefill_block(
            jnp.asarray(xq), self.x, self.y_full[:self.n],
            k=self.k, perplexity=float(min(self.cfg.perplexity, self.k)),
            iters=self.cfg.perplexity_iters)
        if self.fault is not None:
            nn_idx, p_log, y0 = self.fault.fire("prefill",
                                                (nn_idx, p_log, y0))
        rows = jnp.asarray(free[:n_adm], jnp.int32)
        take = jnp.arange(n_adm)
        self.nn_idx = self.nn_idx.at[rows].set(nn_idx[take])
        self.p_log = self.p_log.at[rows].set(p_log[take])
        self.y_full = self.y_full.at[self.n + rows].set(y0[take])
        self.ages = self.ages.at[rows].set(0)
        self.active = self.active.at[rows].set(True)
        for b, req in enumerate(batch):
            self.requests[free[b]] = req
            self._host_ages[free[b]] = 0
            self._slot_born[free[b]] = self.step_no

    def _retire(self):
        """Complete finished slots; quarantine poisoned or stuck ones.

        Two error paths free a slot WITHOUT returning coordinates:
        a slot whose retired row contains NaN/Inf (corruption escaped
        into the embedding) and a slot still unfinished after
        ``slot_step_budget`` engine steps (stuck — e.g. its ages stopped
        advancing after a fault).  Both complete their request with
        ``req.error`` set into ``self.quarantined``; the engine keeps
        serving every other slot."""
        done_rows, stuck_rows = [], []
        for s in range(self.slots):
            if self.requests[s] is None:
                continue
            if self._host_ages[s] >= self.steps:
                done_rows.append(s)
            elif self.step_no - self._slot_born[s] >= self.slot_step_budget:
                stuck_rows.append(s)
        all_rows = done_rows + stuck_rows
        if not all_rows:
            return
        coords = np.asarray(self.y_full[self.n + jnp.asarray(all_rows)])
        if self.fault is not None:
            coords = self.fault.fire("retire", coords)
        now = time.time()
        rows = jnp.asarray(all_rows, jnp.int32)
        self.active = self.active.at[rows].set(False)
        self.ages = self.ages.at[rows].set(0)
        for c, s in enumerate(all_rows):
            req = self.requests[s]
            req.t_done, req.done = now, True
            if s in stuck_rows:
                req.error = (f"slot {s} exceeded its step budget "
                             f"({self.slot_step_budget} engine steps) "
                             f"before finishing; force-retired")
                self.quarantined.append(req)
            elif not np.all(np.isfinite(coords[c])):
                req.error = "projection diverged: non-finite coordinates"
                self.quarantined.append(req)
            else:
                req.y = coords[c]
                self.completed.append(req)
            self.requests[s] = None

    def step(self) -> bool:
        """Admit -> one lockstep fused transform step -> retire.

        Returns False when there is nothing left to do.  The ``step``
        fault site fires BEFORE the dispatch and before any engine state
        advances, so an injected exception here is retryable with zero
        drift: ``step_no``/ages move only on success, and the retried
        step replays the identical key -> bitwise the same trajectory as
        a fault-free run (``run`` does this automatically)."""
        self._admit()
        if not any(r is not None for r in self.requests):
            return False
        if self.fault is not None:
            self.y_full = self.fault.fire("step", self.y_full)
        rho0 = self.cfg.transform_rho0 or self.cfg.rho0
        self.y_full, self.ages = _lockstep_step(
            self.y_full, jax.random.fold_in(self.key, self.step_no),
            self.p_log, self.nn_idx, self.ages, self.active,
            self.neg_sampler, n_negatives=self.cfg.n_negatives,
            steps=self.steps, rho0=float(rho0), prob_fn=self.cfg.prob_fn,
            a=self.cfg.prob_a, gamma=self.cfg.gamma,
            clip=self.cfg.grad_clip, fused_step=bool(self.cfg.fused_step))
        self.step_no += 1
        for s in range(self.slots):
            if self.requests[s] is not None:
                self._host_ages[s] += 1
        self._retire()
        return True

    def run(self, max_steps: int = 10_000_000) -> int:
        """Drain the queue; returns the number of engine step attempts.

        An :class:`~repro.runtime.fault_tolerance.InjectedFault` raised
        at the ``step`` site is caught and the step retried (counted in
        ``faults_retried``); retries are bitwise-transparent because no
        engine state advanced (see :meth:`step`).  Real exceptions
        propagate."""
        n = 0
        while (self.queue or any(r is not None for r in self.requests)) \
                and n < max_steps:
            try:
                progressed = self.step()
            except InjectedFault:
                self.faults_retried += 1
                n += 1
                continue
            if not progressed:
                break
            n += 1
        jax.block_until_ready(self.y_full)
        return n
