"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax

from repro.runtime.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_data_mesh(data: int = 0):
    """1-D "data" mesh for the sharded KNN pipeline (0 = all devices)."""
    n = len(jax.devices())
    data = n if data <= 0 else min(data, n)
    return make_mesh((data,), ("data",))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh((data, model), ("data", "model"))
