"""Interleaved best-of-N wall-clock timing — the repo's one methodology.

Moved here from ``benchmarks/common.py`` (which re-exports both helpers,
so every bench module keeps its import path) because the runtime
autotuner (`repro.runtime.autotune`) consumes the exact same timing
discipline and must be importable with only ``src`` on the path — the
benchmarks tree is not an installed package.

Why interleaved best-of: this repo's reference box is a single-core
container with ±20 % load noise over tens of seconds.  Back-to-back
repeats of one config land entirely inside one load regime, which makes
cross-config ratios meaningless; alternating configs every round spreads
all of them across the same load windows, so the per-config *minima* are
comparable.  ``AUTOTUNE_REPEATS = 8`` is the pairing depth the autotuner
uses for its adopt/reject decision (best-of-8 minima are stable to a few
percent on this box where best-of-3 still wobbles ~10 %); see
benchmarks/README.md ("Timing methodology").
"""
from __future__ import annotations

import time

import jax

# pairing depth for autotuner adopt/reject decisions (paired interleaved
# best-of-8 — the established mitigation for this box's ±20 % noise)
AUTOTUNE_REPEATS = 8


def _report_stragglers(watchdog, label: str):
    """One stderr line when timed repeats hit load-spike outliers.

    best-of timing already discards stragglers from the *numbers*; the
    report makes the discard visible so a row measured during a load
    spike is never mistaken for a clean one."""
    if watchdog is not None and watchdog.stragglers:
        import sys
        worst = max(dt for _, dt, _ in watchdog.stragglers)
        med = watchdog.stragglers[-1][2]
        print(f"[bench] {label}: {len(watchdog.stragglers)} straggler "
              f"repeat(s) (worst {worst:.3f}s vs median {med:.3f}s) — "
              f"using best-of, but treat this row with suspicion",
              file=sys.stderr)


def best_of_interleaved(fns, repeats: int):
    """Best-of-``repeats`` per fn, *alternating* fns every round.

    Machine-load drift over tens of seconds is the dominant noise source
    for comparison rows on a shared CPU; back-to-back repeats of one
    config land entirely inside one load regime and make cross-config
    ratios meaningless.  Interleaving spreads every config across the
    same load windows, so the per-config minima are comparable.  Each fn
    gets one untimed warmup call first (compile time never lands in a
    number).  A per-fn :class:`~repro.runtime.fault_tolerance.Watchdog`
    flags outlier repeats (load spikes) on stderr.  Returns
    (outs, best_seconds), one entry per fn.
    """
    from repro.runtime.fault_tolerance import Watchdog
    outs = [jax.block_until_ready(f()) for f in fns]   # warmup / compile
    best = [float("inf")] * len(fns)
    dogs = [Watchdog() for _ in fns]
    for r in range(repeats):
        for f_i, f in enumerate(fns):
            t0 = time.time()
            outs[f_i] = jax.block_until_ready(f())
            dt = time.time() - t0
            best[f_i] = min(best[f_i], dt)
            dogs[f_i].observe(r, dt)
    for f_i, dog in enumerate(dogs):
        _report_stragglers(dog, f"fn[{f_i}]")
    return outs, best


def timed(fn, *args, repeats: int = 1, warmup: int = 1, **kw):
    """(result, best_seconds) with jax block_until_ready.

    ``warmup`` untimed calls run first so jit compilation never lands in
    the timed repeats — with the old behaviour every ``repeats=1`` number
    (all of fig2–fig7) measured compile time, not runtime.  Pass
    ``warmup=0`` only when compilation is the thing being measured.
    A :class:`~repro.runtime.fault_tolerance.Watchdog` over the repeats
    reports load-spike outliers on stderr.
    """
    from repro.runtime.fault_tolerance import Watchdog
    out = None
    for _ in range(max(0, warmup)):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    best = float("inf")
    dog = Watchdog()
    for r in range(repeats):
        t0 = time.time()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        dt = time.time() - t0
        best = min(best, dt)
        dog.observe(r, dt)
    _report_stragglers(dog, getattr(fn, "__name__", "timed"))
    return out, best
