"""Fault tolerance + straggler posture for 1000+ node runs.

What executes here (and is tested):
  * checkpoint/restart — atomic saves, auto-resume, bit-identical
    continuation (tests/test_fault_tolerance.py and tests/test_resume.py
    kill runs mid-stream and verify the restarted trajectory matches an
    uninterrupted one exactly);
  * elastic re-scale — host-gathered checkpoints restore onto a different
    device count / mesh shape (re-shard on load);
  * straggler mitigation — a step-time watchdog flags outlier steps; the
    LargeVis layout runs under local-SGD (sync_every=H) so a slow worker
    delays the psum only every H steps; LM training uses bounded-staleness
    gradient accumulation (microbatches absorb jitter between syncs);
  * deterministic fault injection — :class:`FaultInjector` fires NaN
    corruption / exceptions / SIGKILL at *named sites* threaded through
    the LargeVis pipeline (``largevis(..., fault=...)``) and the
    projection server (``ProjectionEngine(fault=...)``), driving the
    kill/resume and chaos-serving test matrices;
  * degraded-mode + divergence signalling — the structured warning
    categories the pipeline emits exactly once per demotion/rollback.

What is posture-only on this CPU container (documented, not simulated away):
real preemption signals (SIGTERM hooks call CheckpointManager.save_now) and
multi-controller re-initialization are wired but exercised single-host.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import signal
import time
from typing import Callable, Optional


@dataclasses.dataclass
class Watchdog:
    """Step-time outlier detection (straggler flagging)."""
    window: int = 50
    threshold: float = 3.0          # x median
    _times: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=200), init=False)
    stragglers: list = dataclasses.field(default_factory=list, init=False)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(dt)
        if len(self._times) < 10:
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if dt > self.threshold * med:
            self.stragglers.append((step, dt, med))
            return True
        return False


class DegradedModeWarning(UserWarning):
    """A pipeline stage demoted its implementation after a backend failure
    (``fused -> ref/split`` kernels, ``device -> host`` sampler builds).
    Emitted exactly once per demotion with the stage, the route taken,
    and the original error."""

    def __init__(self, stage: str, from_impl: str, to_impl: str, cause):
        self.stage, self.from_impl, self.to_impl = stage, from_impl, to_impl
        self.cause = cause
        super().__init__(
            f"degraded mode: {stage} demoted {from_impl!r} -> {to_impl!r} "
            f"after {type(cause).__name__}: {cause}")


class DivergenceWarning(UserWarning):
    """The layout health probe detected non-finite coordinates or a norm
    blowup; the driver rolled back to the last healthy chunk with the
    learning rate backed off."""

    def __init__(self, step: int, rollback_to: int, nonfinite: int,
                 max_abs: float, rho0_scale: float):
        self.step, self.rollback_to = step, rollback_to
        self.nonfinite, self.max_abs = nonfinite, max_abs
        self.rho0_scale = rho0_scale
        super().__init__(
            f"layout diverged at step {step} (nonfinite={nonfinite}, "
            f"max|y|={max_abs:.3g}): rolled back to step {rollback_to}, "
            f"lr scale now {rho0_scale:g}")


class LayoutDivergedError(RuntimeError):
    """The layout kept diverging after ``HealthConfig.max_rollbacks``
    rollback/backoff attempts."""


class InjectedFault(RuntimeError):
    """The exception :class:`FaultInjector` raises for ``"exception"``
    specs — catchable separately from real failures."""

    def __init__(self, site: str, hit: int):
        self.site, self.hit = site, hit
        super().__init__(f"injected fault at site {site!r} (hit #{hit})")


class FaultInjector:
    """Deterministic fault injection at named sites.

    ``plan`` maps a site name to ``{hit_index: spec}`` — the spec fires on
    the ``hit_index``-th time (0-based) that site is reached.  Specs:

    * ``"nan"``       — corrupt the site's payload: every float array in
      it is filled with NaN (the payload is returned corrupted);
    * ``"exception"`` — raise :class:`InjectedFault`;
    * ``"kill"``      — ``SIGKILL`` the current process (no atexit, no
      flushing — a real preemption, for subprocess kill/resume tests);
    * a callable      — ``spec(payload) -> payload`` for targeted
      corruption (e.g. NaN one row of a prefill block).

    Sites fire via ``payload = injector.fire("site", payload)``; an
    instance with an empty plan is inert (one dict lookup per site).
    Every firing is recorded in ``log`` as ``(site, hit, kind)``.
    """

    def __init__(self, plan: Optional[dict] = None):
        self.plan = dict(plan or {})
        self.counts: dict = {}
        self.log: list = []

    def fire(self, site: str, payload=None):
        hit = self.counts.get(site, 0)
        self.counts[site] = hit + 1
        spec = self.plan.get(site, {}).get(hit)
        if spec is None:
            return payload
        if callable(spec):
            self.log.append((site, hit, "callable"))
            return spec(payload)
        self.log.append((site, hit, spec))
        if spec == "nan":
            return _poison(payload)
        if spec == "exception":
            raise InjectedFault(site, hit)
        if spec == "kill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise ValueError(f"unknown fault spec {spec!r} at site {site!r}")


def _poison(payload):
    """Fill every inexact (float) array leaf of the payload with NaN."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    def bad(leaf):
        if isinstance(leaf, np.ndarray) and np.issubdtype(
                leaf.dtype, np.floating):
            return np.full_like(leaf, np.nan)
        if isinstance(leaf, jax.Array) and jnp.issubdtype(
                leaf.dtype, jnp.floating):
            return jnp.full_like(leaf, jnp.nan)
        return leaf

    return jax.tree.map(bad, payload)


class PreemptionGuard:
    """SIGTERM/SIGINT -> checkpoint-now-then-exit hook (cluster preemption)."""

    def __init__(self, save_fn: Callable[[], None]):
        self._save_fn = save_fn
        self.triggered = False
        self._prev = {}
        for sig in (signal.SIGTERM,):
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.triggered = True
        self._save_fn()

    def restore_handlers(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
