"""Fault tolerance + straggler posture for 1000+ node runs.

What executes here (and is tested):
  * checkpoint/restart — atomic saves, auto-resume, bit-identical
    continuation (tests/test_fault_tolerance.py and tests/test_resume.py
    kill runs mid-stream and verify the restarted trajectory matches an
    uninterrupted one exactly);
  * elastic re-scale — host-gathered checkpoints restore onto a different
    device count / mesh shape (re-shard on load); the LargeVis stage
    checkpoints are topology-portable (fingerprint excludes the mesh
    shape, a topology tag rides in the metadata) and a shard lost
    mid-run (:class:`ShardFailedError` from a per-shard fault site)
    degrades the job onto a smaller mesh instead of killing it;
  * straggler mitigation — a step-time watchdog flags outlier steps; the
    LargeVis layout runs under local-SGD (sync_every=H) so a slow worker
    delays the psum only every H steps; LM training uses bounded-staleness
    gradient accumulation (microbatches absorb jitter between syncs);
  * deterministic fault injection — :class:`FaultInjector` fires NaN
    corruption / exceptions / SIGKILL at *named sites* threaded through
    the LargeVis pipeline (``largevis(..., fault=...)``) and the
    projection server (``ProjectionEngine(fault=...)``), driving the
    kill/resume and chaos-serving test matrices;
  * degraded-mode + divergence signalling — the structured warning
    categories the pipeline emits exactly once per demotion/rollback.

What is posture-only on this CPU container (documented, not simulated away):
real preemption signals (SIGTERM hooks call CheckpointManager.save_now) and
multi-controller re-initialization are wired but exercised single-host.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import signal
import time
from typing import Callable, Optional


@dataclasses.dataclass
class Watchdog:
    """Step-time outlier detection (straggler flagging)."""
    window: int = 50
    threshold: float = 3.0          # x median
    _times: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=200), init=False)
    stragglers: list = dataclasses.field(default_factory=list, init=False)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(dt)
        if len(self._times) < 10:
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if dt > self.threshold * med:
            self.stragglers.append((step, dt, med))
            return True
        return False


class DegradedModeWarning(UserWarning):
    """A pipeline stage demoted its implementation after a backend failure
    (``fused -> ref/split`` kernels, ``device -> host`` sampler builds,
    ``mesh[P] -> mesh[P/2]`` after a shard failure).  Emitted exactly once
    per demotion with the stage, the route taken, and the original error."""

    def __init__(self, stage: str, from_impl: str, to_impl: str, cause):
        self.stage, self.from_impl, self.to_impl = stage, from_impl, to_impl
        self.cause = cause
        super().__init__(
            f"degraded mode: {stage} demoted {from_impl!r} -> {to_impl!r} "
            f"after {type(cause).__name__}: {cause}")


class TopologyChangeWarning(UserWarning):
    """A stage checkpoint written on a different mesh resumed here.

    Graph-prep stages restore bitwise across any shard count (global
    arrays re-sharded on load), so they resume silently; the local-SGD
    layout's *trajectory* is P-dependent by construction (per-replica
    key streams), so a cross-topology layout resume continues from the
    last committed round boundary — same embedding state, new key
    schedule — and announces itself exactly once with this warning."""

    def __init__(self, stage: str, saved_shards: int, new_shards: int,
                 resumed_at: int):
        self.stage, self.resumed_at = stage, resumed_at
        self.saved_shards, self.new_shards = saved_shards, new_shards
        super().__init__(
            f"{stage} checkpoint written on a {saved_shards}-shard mesh "
            f"resumed on {new_shards} shard(s): continuing from the last "
            f"committed boundary (round {resumed_at}); the trajectory "
            f"from here follows the new mesh's key schedule")


class ShardFailedError(RuntimeError):
    """A single shard of a sharded pipeline stage failed mid-run.

    Raised by the per-shard fault sites (:func:`fire_per_shard`) —
    and, on a real deployment, by the multi-controller runtime when a
    device drops out.  ``core/largevis.py`` catches it, emits one
    :class:`DegradedModeWarning`, rebuilds a smaller mesh, and re-enters
    from the last committed stage via the re-shard restore path."""

    def __init__(self, stage: str, shard: int, cause=None):
        self.stage, self.shard, self.cause = stage, shard, cause
        super().__init__(
            f"shard {shard} failed in stage {stage!r}"
            + (f" ({type(cause).__name__}: {cause})" if cause else ""))


class DivergenceWarning(UserWarning):
    """The layout health probe detected non-finite coordinates or a norm
    blowup; the driver rolled back to the last healthy chunk with the
    learning rate backed off."""

    def __init__(self, step: int, rollback_to: int, nonfinite: int,
                 max_abs: float, rho0_scale: float):
        self.step, self.rollback_to = step, rollback_to
        self.nonfinite, self.max_abs = nonfinite, max_abs
        self.rho0_scale = rho0_scale
        super().__init__(
            f"layout diverged at step {step} (nonfinite={nonfinite}, "
            f"max|y|={max_abs:.3g}): rolled back to step {rollback_to}, "
            f"lr scale now {rho0_scale:g}")


class LayoutDivergedError(RuntimeError):
    """The layout kept diverging after ``HealthConfig.max_rollbacks``
    rollback/backoff attempts."""


class InjectedFault(RuntimeError):
    """The exception :class:`FaultInjector` raises for ``"exception"``
    specs — catchable separately from real failures."""

    def __init__(self, site: str, hit: int):
        self.site, self.hit = site, hit
        super().__init__(f"injected fault at site {site!r} (hit #{hit})")


# Every site the pipeline and the projection server actually fire.  A
# FaultInjector plan naming anything else raises ValueError at plan
# (construction) time — a typo'd site would otherwise silently never
# fire and let a chaos test pass vacuously.  Exported so tests can
# enumerate coverage against it.
FAULT_SITES = frozenset({
    # largevis() pipeline stage boundaries (core/largevis.py)
    "stage:graph", "stage:weights", "stage:samplers",
    # layout drivers (core/layout.py)
    "layout_chunk", "layout_saved", "layout_round",
    # projection server (launch/serve_projection.py)
    "submit", "prefill", "retire", "step",
})

# Per-shard sites inside the sharded stages: the plan names them
# ``"<site>:<shard_index>"`` (e.g. ``"knn_ring_step:2"``) and they fire
# once per shard per pass through the stage via :func:`fire_per_shard`.
SHARDED_FAULT_SITES = frozenset({
    "knn_ring_step",        # core/knn_sharded.py ring dispatch
    "calibrate_shard",      # core/perplexity.py calibrate_p_sharded
    "symmetrize_exchange",  # core/perplexity.py symmetrize_sharded
    "local_sgd_round",      # core/layout.py run_layout_local_sgd
})


def _valid_site(site: str) -> bool:
    if site in FAULT_SITES:
        return True
    base, _, shard = site.rpartition(":")
    return base in SHARDED_FAULT_SITES and shard.isdigit()


class FaultInjector:
    """Deterministic fault injection at named sites.

    ``plan`` maps a site name to ``{hit_index: spec}`` — the spec fires on
    the ``hit_index``-th time (0-based) that site is reached.  Site names
    are validated against :data:`FAULT_SITES` /
    :data:`SHARDED_FAULT_SITES` at construction (``ValueError`` on an
    unknown name).  Specs:

    * ``"nan"``       — corrupt the site's payload: every float array in
      it is filled with NaN (the payload is returned corrupted);
    * ``"exception"`` — raise :class:`InjectedFault`;
    * ``"kill"``      — ``SIGKILL`` the current process (no atexit, no
      flushing — a real preemption, for subprocess kill/resume tests);
    * a callable      — ``spec(payload) -> payload`` for targeted
      corruption (e.g. NaN one row of a prefill block).

    Sites fire via ``payload = injector.fire("site", payload)``; an
    instance with an empty plan is inert (one dict lookup per site).
    Every firing is recorded in ``log`` as ``(site, hit, kind)``.
    """

    def __init__(self, plan: Optional[dict] = None):
        self.plan = dict(plan or {})
        unknown = sorted(s for s in self.plan if not _valid_site(s))
        if unknown:
            raise ValueError(
                f"unknown fault site(s) {unknown}: registered sites are "
                f"{sorted(FAULT_SITES)} plus per-shard "
                f"{sorted(SHARDED_FAULT_SITES)} as '<site>:<shard>'")
        self.counts: dict = {}
        self.log: list = []

    def fire(self, site: str, payload=None):
        hit = self.counts.get(site, 0)
        self.counts[site] = hit + 1
        spec = self.plan.get(site, {}).get(hit)
        if spec is None:
            return payload
        if callable(spec):
            self.log.append((site, hit, "callable"))
            return spec(payload)
        self.log.append((site, hit, spec))
        if spec == "nan":
            return _poison(payload)
        if spec == "exception":
            raise InjectedFault(site, hit)
        if spec == "kill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise ValueError(f"unknown fault spec {spec!r} at site {site!r}")


def fire_per_shard(fault, site: str, n_shards: int, *, stage: str,
                   payloads=None):
    """Fire a per-shard site once per shard; shard faults become
    :class:`ShardFailedError`.

    The host driver fires ``"<site>:<s>"`` for every shard ``s`` around
    the stage's single SPMD dispatch (a single-controller mesh has no
    per-shard host code to instrument — naming the shard in the site is
    what parameterizes the failure).  An injected exception is wrapped
    as ``ShardFailedError(stage, s)`` so the mesh-recovery loop in
    ``core/largevis.py`` can distinguish a lost shard from any other
    failure; ``"kill"`` specs still SIGKILL, and callable specs may
    transform the optional per-shard ``payloads`` (e.g. inflate one
    shard's observed round time to simulate a straggler).  Returns the
    (possibly transformed) payload list."""
    if fault is None:
        return payloads
    out = list(payloads) if payloads is not None else [None] * n_shards
    for s in range(n_shards):
        try:
            out[s] = fault.fire(f"{site}:{s}", out[s])
        except InjectedFault as e:
            raise ShardFailedError(stage, s, e) from e
    return out


def _poison(payload):
    """Fill every inexact (float) array leaf of the payload with NaN."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    def bad(leaf):
        if isinstance(leaf, np.ndarray) and np.issubdtype(
                leaf.dtype, np.floating):
            return np.full_like(leaf, np.nan)
        if isinstance(leaf, jax.Array) and jnp.issubdtype(
                leaf.dtype, jnp.floating):
            return jnp.full_like(leaf, jnp.nan)
        return leaf

    return jax.tree.map(bad, payload)


class PreemptionGuard:
    """SIGTERM/SIGINT -> checkpoint-now-then-exit hook (cluster preemption).

    ``largevis()`` installs one (SIGTERM + SIGINT) whenever checkpointing
    is enabled and registers it as the process-wide *active* guard; the
    layout drivers look the active guard up and keep its ``save_fn``
    pointed at a synchronous save of the newest stage boundary
    (:meth:`set_save_fn` — late binding, since the state worth saving
    changes every chunk).  On a signal the guard runs the save, restores
    the previous handlers, and — with ``exit_after_save`` — re-raises
    the signal so the process still dies by it (exit code 128+signum,
    what a preempting scheduler expects).  ``restore_handlers`` on
    normal completion puts the prior handlers back untouched."""

    _active: Optional["PreemptionGuard"] = None

    def __init__(self, save_fn: Optional[Callable[[], None]] = None, *,
                 signals=(signal.SIGTERM,), exit_after_save: bool = False):
        self._save_fn = save_fn
        self._exit = exit_after_save
        self.triggered = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handle)

    @classmethod
    def active(cls) -> Optional["PreemptionGuard"]:
        return cls._active

    def activate(self):
        """Make this the guard ``active()`` returns (one per process)."""
        PreemptionGuard._active = self
        return self

    def set_save_fn(self, fn: Optional[Callable[[], None]]):
        self._save_fn = fn

    def _handle(self, signum, frame):
        self.triggered = True
        if self._save_fn is not None:
            self._save_fn()
        if self._exit:
            self.restore_handlers()
            os.kill(os.getpid(), signum)

    def restore_handlers(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        if PreemptionGuard._active is self:
            PreemptionGuard._active = None
