"""Fault tolerance + straggler posture for 1000+ node runs.

What executes here (and is tested):
  * checkpoint/restart — atomic saves, auto-resume, bit-identical
    continuation (tests/test_fault_tolerance.py kills a training run
    mid-stream and verifies the restarted loss trajectory matches an
    uninterrupted one exactly);
  * elastic re-scale — host-gathered checkpoints restore onto a different
    device count / mesh shape (re-shard on load);
  * straggler mitigation — a step-time watchdog flags outlier steps; the
    LargeVis layout runs under local-SGD (sync_every=H) so a slow worker
    delays the psum only every H steps; LM training uses bounded-staleness
    gradient accumulation (microbatches absorb jitter between syncs).

What is posture-only on this CPU container (documented, not simulated away):
real preemption signals (SIGTERM hooks call CheckpointManager.save_now) and
multi-controller re-initialization are wired but exercised single-host.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import time
from typing import Callable, Optional


@dataclasses.dataclass
class Watchdog:
    """Step-time outlier detection (straggler flagging)."""
    window: int = 50
    threshold: float = 3.0          # x median
    _times: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=200), init=False)
    stragglers: list = dataclasses.field(default_factory=list, init=False)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(dt)
        if len(self._times) < 10:
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if dt > self.threshold * med:
            self.stragglers.append((step, dt, med))
            return True
        return False


class PreemptionGuard:
    """SIGTERM/SIGINT -> checkpoint-now-then-exit hook (cluster preemption)."""

    def __init__(self, save_fn: Callable[[], None]):
        self._save_fn = save_fn
        self.triggered = False
        self._prev = {}
        for sig in (signal.SIGTERM,):
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.triggered = True
        self._save_fn()

    def restore_handlers(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
