"""Computation-environment presets: platform, XLA flags, host devices.

Benchmark runs (``benchmarks/run.py``) call :func:`apply_bench_preset`
first so numbers from different boxes are produced under one declared
environment instead of whatever flags the shell happened to carry.  All
helpers only take full effect *before* the JAX backend initializes —
call them at process start (they warn, not fail, when applied late).

Unlike the usual one-shot recipes, every ``XLA_FLAGS`` edit here is a
**merge**: existing flags survive, and a flag already set by the user
wins over the preset — overwriting the whole variable (the common bug)
silently drops e.g. a mesh-smoke job's ``device_count`` flag.
"""
from __future__ import annotations

import os
import warnings
from multiprocessing import cpu_count

import jax

# the GPU preset from JAX's gpu_performance_tips page: fusion + async
# collectives + latency-hiding scheduling — the flags every serious GPU
# deployment sets, declared once instead of per shell
GPU_XLA_PRESET = {
    "--xla_gpu_enable_triton_softmax_fusion": "true",
    "--xla_gpu_triton_gemm_any": "True",
    "--xla_gpu_enable_async_collectives": "true",
    "--xla_gpu_enable_latency_hiding_scheduler": "true",
    "--xla_gpu_enable_highest_priority_async_stream": "true",
}


def _backend_initialized() -> bool:
    # jax.config updates after backend init silently do nothing for
    # platform selection; detect so callers get a warning instead
    try:
        return jax._src.xla_bridge._backends != {}     # noqa: SLF001
    except Exception:                                  # jax internals moved
        return False


def merge_xla_flags(flags: dict[str, str], *, override: bool = False) -> str:
    """Merge ``{--flag: value}`` into ``XLA_FLAGS``, preserving existing.

    Existing flags win unless ``override``.  Returns the new value (also
    written to ``os.environ``)."""
    current: dict[str, str] = {}
    order: list[str] = []
    for tok in os.environ.get("XLA_FLAGS", "").split():
        key, _, val = tok.partition("=")
        if key not in current:
            order.append(key)
        current[key] = val
    for key, val in flags.items():
        if key not in current:
            order.append(key)
            current[key] = val
        elif override:
            current[key] = val
    merged = " ".join(
        k if current[k] == "" else f"{k}={current[k]}" for k in order)
    os.environ["XLA_FLAGS"] = merged
    return merged


def set_platform(platform: str = "cpu") -> None:
    """Select the JAX platform ('cpu' | 'gpu' | 'tpu') + its flag preset.

    Only effective before backend initialization (warns otherwise).  On
    'gpu' the :data:`GPU_XLA_PRESET` flags merge into ``XLA_FLAGS``.
    """
    if _backend_initialized():
        warnings.warn(
            f"set_platform({platform!r}) after JAX backend init has no "
            "effect; call it at process start", stacklevel=2)
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        merge_xla_flags(GPU_XLA_PRESET)


def set_host_device_count(n: int) -> None:
    """Expose ``n`` host CPU devices (the mesh-smoke / fig6 mechanism).

    Clamps to the physical core count with a warning; only effective
    before backend initialization."""
    n = int(n)
    total = cpu_count()
    if n > total:
        warnings.warn(
            f"only {total} CPUs available; exposing {total} devices",
            stacklevel=2)
        n = total
    if _backend_initialized():
        warnings.warn(
            "set_host_device_count after JAX backend init has no effect; "
            "call it at process start", stacklevel=2)
    merge_xla_flags(
        {"--xla_force_host_platform_device_count": str(n)}, override=True)


def set_debug_nan(flag: bool) -> None:
    """Raise on NaN production (jax_debug_nans) — debugging aid."""
    jax.config.update("jax_debug_nans", bool(flag))


def apply_bench_preset() -> None:
    """The benchmark harness's reproducible-environment preset.

    Pins the platform to the detected default backend (making the run's
    environment explicit in one place) and applies that platform's flag
    preset.  Safe to call after backend init — it only re-applies flags
    that already match the live backend."""
    backend = jax.default_backend()
    if backend == "gpu":
        merge_xla_flags(GPU_XLA_PRESET)
    # no platform switch here: the bench measures the environment it is
    # launched in; the preset's job is flag hygiene, not redirection
