"""Sharding rules: DP / FSDP / TP / EP / SP as PartitionSpec patterns.

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single-pod.

  * batch (DP)          -> ("pod", "data")
  * param FSDP shards   -> "data"  (pods replicate params; only the gradient
                           all-reduce crosses the pod axis — hierarchical DP)
  * heads / ff / vocab / expert-ff (TP, EP) -> "model"
  * long-context decode (batch < dp size) -> KV sequence over "data" (SP;
    XLA inserts the flash-decoding logsumexp/psum combine automatically)

Every proposed axis is divisibility-guarded: a dim that doesn't divide over
its mesh axis falls back to replication (e.g. kv_heads=8 on model=16) —
so one rule set covers all ten architectures.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY = threading.local()


@contextlib.contextmanager
def activation_policy(mesh: Mesh, *, global_batch: int, train: bool = False):
    """Trace-time policy: models call ``constrain_batch_major`` to anchor
    activation shardings (batch over DP axes), which stops the SPMD
    partitioner from resolving param-vs-batch axis conflicts by
    replicating the batch (the 37 GiB-logits failure mode).  MoE reads the
    policy to switch to shard_map local dispatch."""
    prev = getattr(_POLICY, "v", None)
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    ok = global_batch % dp_size == 0 and global_batch >= dp_size
    _POLICY.v = (mesh, dp if ok else None, train)
    try:
        yield
    finally:
        _POLICY.v = prev


def current_policy():
    """(mesh, dp_axes_or_None, train) or None."""
    return getattr(_POLICY, "v", None)


def constrain_batch_major(x):
    """Shard dim 0 over the DP axes (no-op outside a policy or when the
    batch doesn't cover the DP extent)."""
    pol = getattr(_POLICY, "v", None)
    if pol is None or pol[1] is None:
        return x
    mesh, dp = pol[0], pol[1]
    spec = _guard(mesh, x.shape, [dp] + [None] * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_dim(x, dim: int):
    """Shard dimension ``dim`` over the DP axes (policy-gated no-op)."""
    pol = getattr(_POLICY, "v", None)
    if pol is None or pol[1] is None:
        return x
    mesh, dp = pol[0], pol[1]
    spec = [None] * x.ndim
    spec[dim] = dp
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _guard(mesh, x.shape, spec)))


def constrain_logits(x):
    """(..., V) logits: batch over DP, vocab over model."""
    pol = getattr(_POLICY, "v", None)
    if pol is None:
        return x
    mesh, dp = pol[0], pol[1]
    spec = [dp] + [None] * (x.ndim - 2) + ["model"]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _guard(mesh, x.shape, spec)))


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 0


def _guard(mesh: Mesh, shape, spec):
    """Replace non-divisible / absent axes with None."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = _axis_size(mesh, ax)
        if size and size > 0 and dim % size == 0 and dim >= size:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


# ---------------------------------------------------------------------------
# Row-sharding layout for the 1-D "data" mesh pipeline stages
# ---------------------------------------------------------------------------
#
# Every mesh stage of the LargeVis pipeline (the KNN ring, perplexity
# calibration, symmetrization, the sampler build) shards its N rows the
# same way: pad N up to a multiple of the shard count, then give each
# shard one contiguous block of ``rows_per_shard`` rows — so shard s owns
# global rows [s * rows_per_shard, (s + 1) * rows_per_shard) and a local
# row l maps to global id ``s * rows_per_shard + l``.  Keeping one layout
# across stages is what lets the graph stay device-resident between them:
# a stage's output shards are exactly the next stage's input shards.

def rows_per_shard(n: int, n_shards: int) -> int:
    """Rows each shard owns after padding ``n`` to a shard multiple."""
    return -(-n // max(1, n_shards))


def pad_rows(x, n_shards: int, fill=0):
    """Pad axis 0 of ``x`` to ``rows_per_shard(n, P) * P`` rows with
    ``fill`` (device-resident — ``jnp.pad``, no host round trip)."""
    import jax.numpy as jnp
    n = x.shape[0]
    n_pad = rows_per_shard(n, n_shards) * n_shards - n
    if n_pad == 0:
        return x
    widths = [(0, n_pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def shard_rows(x, mesh, axis: str = "data"):
    """Place a GLOBAL row array onto the mesh: dim 0 split over ``axis``
    into the contiguous blocks of ``rows_per_shard`` rows when the row
    count divides the shard count, replicated otherwise (the shape is
    never changed — downstream stages read ``shape[0]`` as N, then
    ``pad_rows`` for their own shard_map dispatch exactly as they do
    for fresh global inputs).

    The elastic-restore primitive: stage checkpoints store global
    (host-gathered, unsharded) arrays, and this is how
    ``StageCheckpointer.restore`` re-shards them onto whatever mesh the
    *resuming* process happens to have — any shard count, not just the
    one that wrote the checkpoint."""
    import jax.numpy as jnp
    x = jnp.asarray(x)
    spec = _guard(mesh, x.shape, [axis] + [None] * (x.ndim - 1))
    return jax.device_put(x, NamedSharding(mesh, spec))


def fsdp_axis(mesh: Mesh, train: bool):
    return "data" if train else None


# ---------------------------------------------------------------------------
# Parameter rules (path-pattern -> axis proposal per dim)
# ---------------------------------------------------------------------------

_RULES = [
    # (regex on joined path, proposal builder given ndim)
    (r"(embed|lm_head)/table$", lambda nd: ["model", "fsdp"]),
    (r"dec_pos$|enc_pos$", lambda nd: ["fsdp", None]),
    (r"attn/w[qkv]$|xattn/w[qkv]$", lambda nd: ["fsdp", "model", None]),
    (r"attn/wo$|xattn/wo$", lambda nd: ["model", None, "fsdp"]),
    (r"attn/b[qkv]$", lambda nd: ["model", None]),
    (r"mlp/w_(gate|up)$", lambda nd: ["fsdp", "model"]),
    (r"mlp/w_down$", lambda nd: ["model", "fsdp"]),
    (r"mlp/b_up$", lambda nd: ["model"]),
    (r"mlp/b_down$", lambda nd: [None]),
    (r"moe/router$", lambda nd: ["fsdp", None]),
    (r"moe/w_(gate|up)$", lambda nd: ["expert", "fsdp", "model"]),
    (r"moe/w_down$", lambda nd: ["expert", "model", "fsdp"]),
    (r"mamba/w_in$", lambda nd: ["fsdp", "model"]),
    (r"mamba/conv_w$", lambda nd: [None, "model"]),
    (r"mamba/conv_b$|mamba/d_skip$|mamba/dt_bias$", lambda nd: ["model"]),
    (r"mamba/w_[bc]$|mamba/a_log$|mamba/w_dt_down$", lambda nd: ["model", None]),
    (r"mamba/w_dt_up$", lambda nd: [None, "model"]),
    (r"mamba/w_out$", lambda nd: ["model", "fsdp"]),
    (r"core/w_up$|core/w_x$", lambda nd: ["fsdp", "model"]),
    (r"core/w_[qkv]$", lambda nd: [None, "model"]),
    (r"core/w_[if]$", lambda nd: ["model", None]),
    (r"core/b_[ifx]$", lambda nd: ["model"]),
    (r"core/r$", lambda nd: [None, None, None]),
    (r"core/w_down$|core/w_out$", lambda nd: ["model", "fsdp"]),
    (r"core/norm/scale$", lambda nd: ["model"]),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_pspec(path, leaf, mesh: Mesh, *, train: bool,
                stacked: bool) -> P:
    """PartitionSpec for one param leaf.  ``stacked`` leaves carry a leading
    period axis (never sharded)."""
    s = _path_str(path)
    shape = leaf.shape
    fsdp = fsdp_axis(mesh, train)
    body = shape[1:] if stacked else shape
    proposal: Optional[list] = None
    for pat, builder in _RULES:
        if re.search(pat, s):
            proposal = builder(len(body))
            break
    if proposal is None or len(proposal) != len(body):
        proposal = [None] * len(body)
    resolved = []
    for ax in proposal:
        if ax == "fsdp":
            resolved.append(fsdp)
        elif ax == "expert":
            # EP: experts over "data" at inference (no FSDP there);
            # during training "data" is taken by FSDP, so replicate E
            resolved.append(None if train else "data")
        else:
            resolved.append(ax)
    spec = _guard(mesh, body, resolved)
    if stacked:
        spec = P(None, *spec)
    return spec


def params_shardings(param_tree, mesh: Mesh, *, train: bool):
    """NamedSharding pytree for params (stacked block detection by path)."""
    def one(path, leaf):
        s = _path_str(path)
        stacked = "blocks/" in s or "_layers/" in s
        return NamedSharding(mesh, param_pspec(path, leaf, mesh, train=train,
                                               stacked=stacked))
    return jax.tree_util.tree_map_with_path(one, param_tree)


# ---------------------------------------------------------------------------
# Batch / activation / cache rules
# ---------------------------------------------------------------------------

def batch_shardings(batch_tree, mesh: Mesh, *, global_batch: int):
    """Tokens/labels over DP axes; decode caches batch- or sequence-sharded
    depending on whether the batch covers the DP extent (SP fallback)."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_first = global_batch % dp_size == 0 and global_batch >= dp_size

    def one(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if "cache" in s:
            return NamedSharding(mesh, _cache_pspec(s, shape, mesh,
                                                    batch_first))
        # tokens / labels / position / encoder_frames
        if len(shape) >= 1 and batch_first:
            spec = [dp] + [None] * (len(shape) - 1)
        else:
            spec = [None] * len(shape)
        if s.endswith("encoder_frames") and len(shape) == 3:
            spec = [dp if batch_first else None, None, None]
        return NamedSharding(mesh, _guard(mesh, shape, spec))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def _cache_pspec(s: str, shape, mesh: Mesh, batch_first: bool) -> P:
    """Stacked cache leaves: (n_periods, B, ...).

    attention k/v (n_per,B,S,KVH,hd): batch-sharded when possible, else
    sequence-parallel over "data"; head/hd dim over "model".
    mamba ssm (n_per,B,inner,state) / conv (n_per,B,K-1,inner);
    xlstm C (n_per,B,nh,dh,dh) n (n_per,B,nh,dh) m (n_per,B,nh).
    encoder_out (B,F,d).
    """
    dp = dp_axes(mesh)
    if s.endswith("encoder_out"):
        return _guard(mesh, shape, [dp if batch_first else None, None, None])
    nd = len(shape)
    if s.endswith("/k") or s.endswith("/v") or \
            s.endswith("_scale"):
        if batch_first:
            return _guard(mesh, shape, [None, dp, None, "model", None]
                          if shape[3] % max(_axis_size(mesh, "model"), 1) == 0
                          else [None, dp, None, None, "model"])
        # SP: shard the KV sequence over "data" (+ heads/hd over model)
        return _guard(mesh, shape, [None, None, "data", "model", None]
                      if shape[3] % max(_axis_size(mesh, "model"), 1) == 0
                      else [None, None, "data", None, "model"])
    if s.endswith("/ssm"):
        return _guard(mesh, shape,
                      [None, dp if batch_first else None, "model", None])
    if s.endswith("/conv"):
        return _guard(mesh, shape,
                      [None, dp if batch_first else None, None, "model"])
    if s.endswith("/C"):
        return _guard(mesh, shape,
                      [None, dp if batch_first else None, None, "model", None])
    if s.endswith("/n") or s.endswith("/m") or s.endswith("/c") or \
            s.endswith("/h"):
        spec = [None, dp if batch_first else None] + [None] * (nd - 2)
        return _guard(mesh, shape, spec)
    spec = [None, dp if batch_first else None] + [None] * (nd - 2)
    return _guard(mesh, shape, spec)


def out_shardings_for(kind: str, mesh: Mesh, *, global_batch: int):
    """Loss: replicated scalar.  Logits: (B, V) -> (dp, model)."""
    dp = dp_axes(mesh)
    if kind == "loss":
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(dp, "model"))
