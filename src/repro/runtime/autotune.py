"""Backend-aware kernel autotuner: per (kernel, backend, shape-bucket) tiles.

Every tile-size decision in the repo routes through :func:`get`:

    cfg = autotune.get("topk_sqdist", dict(m=M, n=N, d=d, k=k),
                       default=dict(bm=2048, bn=None, lane=1, merge="auto"))

``default`` is the call site's legacy hard-coded config and doubles as
the key filter: only keys present in ``default`` are taken from a tuned
entry, so a cached ref-path config (which carries ``merge``) can never
leak an unknown keyword into the Pallas call path.

Modes (the ``AUTOTUNE`` env var, ``RoutingConfig.autotune``, or
:func:`set_mode`):

  ``off``    always return ``default`` — bitwise reproduction of the
             pre-autotuner hard-coded repo, the CI determinism anchor.
  ``cache``  (default) consult the user cache
             (``~/.cache/repro-autotune/autotune_<backend>.json``,
             directory overridable via ``REPRO_AUTOTUNE_CACHE``), then
             the committed in-repo table (``autotune_defaults.json``
             next to this module — swept on the reference box, committed
             for CI determinism), then fall back to ``default``.  Never
             measures anything.
  ``sweep``  like ``cache``, but a miss triggers a measurement sweep of
             the kernel's candidate grid and persists the winner to the
             user cache.

The sweep uses the repo's one timing methodology
(:func:`repro.runtime.timing.best_of_interleaved`): a best-of-3
interleaved pass shortlists the candidate grid, then the shortlist
winner meets the legacy default in a **paired interleaved best-of-8**
run and is adopted only if it beats the default by more than
:data:`ADOPT_MARGIN` — on a single-core box with ±20 % load noise an
unpaired few-percent win is indistinguishable from drift, so ties keep
the default (stability beats chasing noise).

Results-preservation contract: every knob the tuner is allowed to touch
is a pure performance parameter — row/column tiling of row-local
computations (``topk_sqdist`` bm/bn/merge/lane, ``symmetrize`` tile,
grad-kernel tile), the fused edge step's edge-tile/gather-mode/y-tile
(the canonical per-edge update order is tile-invariant; see
``kernels/largevis_step.py``), and scan-dispatch chunking.  Anything
that would change results (e.g. ``neighbor_explore``'s per-tile key
stream when ``sample > 0``) must not consult the tuner — call sites
gate that themselves.

Cache files are versioned: a file whose ``version`` differs from
:data:`AUTOTUNE_VERSION` is ignored wholesale (configs measured under
old candidate semantics must not leak forward).

Tuned values resolve at *trace time* (Python wrappers or ops-layer
calls under tracing), so a process sees a consistent config per shape
for its lifetime; :func:`set_mode` clears the jit caches when the mode
actually changes so already-traced call sites cannot serve stale tile
choices.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile

import jax

AUTOTUNE_VERSION = 1
ADOPT_MARGIN = 0.97        # winner must beat the default by > 3 % (paired)
SHORTLIST_REPEATS = 3      # stage-1 interleaved pass over the whole grid

_ENV = "AUTOTUNE"
_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
MODES = ("off", "cache", "sweep")

_mode_override: str | None = None
_mem: dict[str, dict] = {}       # bucket key -> tuned config (session memo)
_sweeping = False                # re-entrancy guard: no sweeps inside sweeps


# ---------------------------------------------------------------------------
# mode + cache plumbing
# ---------------------------------------------------------------------------

def mode() -> str:
    """Current mode: :func:`set_mode` override, else the AUTOTUNE env."""
    if _mode_override is not None:
        return _mode_override
    m = os.environ.get(_ENV, "cache").strip().lower()
    return m if m in MODES else "cache"


def set_mode(m: str | None) -> None:
    """Override the mode for this process (None restores the env value).

    Clears the jit caches on an actual change: tuned tiles are baked
    into traces as static arguments, so a mode flip must invalidate
    every already-compiled call site."""
    global _mode_override
    if m is not None and m not in MODES:
        raise ValueError(f"autotune mode {m!r}; expected one of {MODES}")
    changed = m != _mode_override
    _mode_override = m
    if changed:
        _mem.clear()
        jax.clear_caches()


def cache_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get(
        _CACHE_ENV, "~/.cache/repro-autotune")).expanduser()


def _cache_path(backend: str) -> pathlib.Path:
    return cache_dir() / f"autotune_{backend}.json"


def _defaults_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent / "autotune_defaults.json"


def _read_entries(path: pathlib.Path) -> dict:
    """Entries of a versioned cache file ({} on miss/mismatch/corruption)."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError):
        return {}
    if not isinstance(doc, dict) or doc.get("version") != AUTOTUNE_VERSION:
        return {}                      # version rejection: stale semantics
    entries = doc.get("entries")
    return entries if isinstance(entries, dict) else {}


def _write_entry(backend: str, key: str, entry: dict) -> None:
    """Merge one entry into the user cache file (atomic replace)."""
    path = _cache_path(backend)
    path.parent.mkdir(parents=True, exist_ok=True)
    entries = _read_entries(path)
    entries[key] = entry
    doc = {"version": AUTOTUNE_VERSION, "jax": jax.__version__,
           "entries": entries}
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# ---------------------------------------------------------------------------
# shape bucketing
# ---------------------------------------------------------------------------

def _bucket(v: int) -> int:
    """Round up to the next power of two (shapes in a bucket share a config)."""
    v = int(v)
    return 1 if v <= 1 else 1 << (v - 1).bit_length()


def bucket_key(kernel: str, shape: dict, backend: str | None = None) -> str:
    backend = backend or jax.default_backend()
    dims = "_".join(f"{k}{_bucket(v)}" for k, v in sorted(shape.items()))
    return f"{backend}/{kernel}/{dims}"


def bucket_shape(shape: dict) -> dict:
    """The bucket-representative shape a sweep measures at."""
    return {k: _bucket(v) for k, v in shape.items()}


# ---------------------------------------------------------------------------
# lookup
# ---------------------------------------------------------------------------

def get(kernel: str, shape: dict, default: dict) -> dict:
    """Resolve the tile config for one kernel call.

    ``shape`` holds the call's size-determining integers (bucketed to
    powers of two for the cache key); ``default`` is the legacy
    hard-coded config — returned verbatim in ``off`` mode and on any
    miss, and acting as the key whitelist for tuned entries."""
    out = dict(default)
    m = mode()
    if m == "off":
        return out
    key = bucket_key(kernel, shape)
    cfg = _mem.get(key)
    if cfg is None:
        backend = jax.default_backend()
        cfg = _read_entries(_cache_path(backend)).get(key)
        if cfg is None:
            cfg = _read_entries(_defaults_path()).get(key)
        if cfg is not None:
            cfg = cfg.get("config", cfg)
    if cfg is None and m == "sweep" and not _sweeping:
        cfg = sweep(kernel, shape, default)
    if cfg:
        _mem[key] = cfg
        for k, v in cfg.items():
            if k in out:
                out[k] = v
    return out


def legacy_default(kernel: str, backend: str | None = None) -> dict:
    """The pre-autotuner hard-coded config (what ``AUTOTUNE=off`` runs).

    One registry so tests and the autotune bench can pin "today's
    config" without copying constants out of call sites."""
    backend = backend or jax.default_backend()
    if kernel == "topk_sqdist":
        if backend == "tpu":
            return dict(bm=256, bn=512, lane=128)        # knn_topk kernel
        return dict(bm=2048, bn=None, lane=1, merge="auto")   # ref oracle
    if kernel == "largevis_edge_step":
        return dict(tile=1024, gather="take", y_tile=0)
    if kernel == "largevis_grads":
        return dict(tile=2048)
    if kernel == "symmetrize":
        return dict(tile=4096)
    if kernel == "neighbor_explore":
        return dict(tile=1024)
    if kernel == "layout_chunk":
        return dict(steps=0)       # 0 = driver keeps its own default
    raise KeyError(kernel)


# ---------------------------------------------------------------------------
# sweeping
# ---------------------------------------------------------------------------

def sweep(kernel: str, shape: dict, default: dict | None = None) -> dict:
    """Measure the candidate grid for one (kernel, backend, bucket) cell.

    Returns the chosen config and persists it to the user cache.  The
    decision rule (see module docstring): interleaved best-of-3
    shortlist, then paired best-of-8 winner-vs-default with the
    :data:`ADOPT_MARGIN` adopt threshold."""
    global _sweeping
    backend = jax.default_backend()
    default = dict(default) if default else legacy_default(kernel, backend)
    builder = _SWEEPS.get(kernel)
    if builder is None:
        return dict(default)
    key = bucket_key(kernel, shape, backend)
    built = builder(bucket_shape(shape), backend)
    if not built:
        return dict(default)
    candidates, make_thunk = built
    cand_list = [dict(default)] + [c for c in candidates if c != default]
    _sweeping = True
    try:
        from repro.runtime.timing import AUTOTUNE_REPEATS, best_of_interleaved
        fns = [make_thunk({**default, **c}) for c in cand_list]
        _, best = best_of_interleaved(fns, SHORTLIST_REPEATS)
        win = min(range(len(best)), key=best.__getitem__)
        chosen, us, us_default = dict(default), best[0] * 1e6, best[0] * 1e6
        if win != 0:
            # paired confirmation against the incumbent, best-of-8
            _, (t_def, t_win) = best_of_interleaved(
                [fns[0], fns[win]], AUTOTUNE_REPEATS)
            us_default = t_def * 1e6
            if t_win < ADOPT_MARGIN * t_def:
                chosen, us = dict(cand_list[win]), t_win * 1e6
            else:
                us = us_default
    finally:
        _sweeping = False
    entry = {"config": chosen, "us": round(us, 1),
             "us_default": round(us_default, 1),
             "shape": bucket_shape(shape)}
    _write_entry(backend, key, entry)
    _mem[key] = chosen
    return chosen


def _uniq(seq):
    out = []
    for c in seq:
        if c not in out:
            out.append(c)
    return out


# --- per-kernel candidate grids + input builders (lazy imports: ops
# imports this module at module level, so the reverse import must happen
# at sweep time only) -------------------------------------------------------

def _sweep_topk(shape, backend):
    import jax.numpy as jnp

    from repro.kernels import ops
    m, n = shape.get("m", 2048), shape.get("n", 16384)
    d, k = shape.get("d", 128), min(shape.get("k", 32), n - 1)
    ka, kb = jax.random.split(jax.random.key(0))
    a = jax.random.normal(ka, (m, d), jnp.float32)
    b = jax.random.normal(kb, (n, d), jnp.float32)
    if backend == "tpu":
        cands = _uniq(dict(bm=bm, bn=bn, lane=128)
                      for bm in (128, 256, 512) for bn in (256, 512, 1024))
    else:
        cands = _uniq(dict(bm=min(bm, m), bn=min(bn, n), lane=1, merge=mg)
                      for bm in (1024, 2048, 4096)
                      for bn in (2048, 4096, 8192)
                      for mg in ("tile", "concat"))

    def make_thunk(cfg):
        def thunk():
            return ops.topk_sqdist(a, b, k, **cfg)
        return thunk

    return cands, make_thunk


def _sweep_window_fold(shape, backend):
    # the forest window fold's inner dispatch: a (W, d) block against its
    # (3W, d) neighborhood with dedup + running-state seed.  The thunk
    # measures that dispatch directly (the surrounding lax.map is
    # identical across candidates); bm/bn candidates stay within the
    # structural bounds bm <= W, bn <= 3W.
    import jax.numpy as jnp

    from repro.kernels import ops, ref
    w, kk, d = shape.get("w", 1024), shape.get("k", 32), shape.get("d", 128)
    kk = min(kk, w - 1)
    ka, kb = jax.random.split(jax.random.key(5))
    aw = jax.random.normal(ka, (w, d), jnp.float32)
    bw = jnp.concatenate([aw, jax.random.normal(kb, (2 * w, d), jnp.float32)])
    a_ids = jnp.arange(w, dtype=jnp.int32)
    b_ids = jnp.arange(3 * w, dtype=jnp.int32)
    init_i = jnp.full((w, kk), -1, jnp.int32)
    init_d = jnp.full((w, kk), ref.INVALID_DIST, jnp.float32)
    cands = _uniq(dict(bm=bm, bn=bn)
                  for bm in (max(8, w // 4), max(8, w // 2), w)
                  for bn in (w, 3 * w // 2, 3 * w))

    def make_thunk(cfg):
        def thunk():
            return ops.topk_sqdist(aw, bw, kk, a_ids=a_ids, b_ids=b_ids,
                                   init_ids=init_i, init_dists=init_d,
                                   dedup=True, bm=min(cfg["bm"], w),
                                   bn=min(cfg["bn"], 3 * w))
        return thunk

    return cands, make_thunk


def _sweep_edge_step(shape, backend):
    import jax.numpy as jnp

    from repro.kernels import ops
    n = shape.get("n", 16384)
    bsz, mneg, s = shape.get("b", 4096), shape.get("m", 8), shape.get("s", 2)
    keys = jax.random.split(jax.random.key(1), 4)
    y = jax.random.normal(keys[0], (n, s), jnp.float32) * 1e-2
    i = jax.random.randint(keys[1], (bsz,), 0, n, jnp.int32)
    j = jax.random.randint(keys[2], (bsz,), 0, n, jnp.int32)
    negs = jax.random.randint(keys[3], (bsz, mneg), 0, n, jnp.int32)
    nm = ((negs != i[:, None]) & (negs != j[:, None])).astype(jnp.float32)
    tiles = [t for t in (256, 512, 1024, 2048, 4096) if t <= bsz] or [bsz]
    gathers = ("take", "loop") if backend == "tpu" else ("take",)
    cands = _uniq(dict(tile=t, gather=g) for t in tiles for g in gathers)

    def make_thunk(cfg):
        def thunk():
            return ops.largevis_edge_step(y, i, j, negs, nm, 0.5, **cfg)
        return thunk

    return cands, make_thunk


def _sweep_grads(shape, backend):
    if backend != "tpu":
        # the CPU production route is the vectorized jnp oracle — no tile
        return None
    import jax.numpy as jnp

    from repro.kernels import ops
    bsz, mneg, s = shape.get("b", 4096), shape.get("m", 8), shape.get("s", 2)
    keys = jax.random.split(jax.random.key(2), 4)
    yi = jax.random.normal(keys[0], (bsz, s), jnp.float32)
    yj = jax.random.normal(keys[1], (bsz, s), jnp.float32)
    yn = jax.random.normal(keys[2], (bsz, mneg, s), jnp.float32)
    nm = (jax.random.uniform(keys[3], (bsz, mneg)) > 0.1).astype(jnp.float32)
    tiles = [t for t in (512, 1024, 2048, 4096) if t <= bsz] or [bsz]

    def make_thunk(cfg):
        def thunk():
            return ops.largevis_grads(yi, yj, yn, nm, impl="pallas", **cfg)
        return thunk

    return [dict(tile=t) for t in tiles], make_thunk


def _sweep_symmetrize(shape, backend):
    del backend
    import jax.numpy as jnp

    from repro.core import perplexity
    n, kk = shape.get("n", 16384), shape.get("k", 64)
    keys = jax.random.split(jax.random.key(3))
    idx = jax.random.randint(keys[0], (n, kk), 0, n, jnp.int32)
    p = jax.random.uniform(keys[1], (n, kk), jnp.float32)
    tiles = [t for t in (512, 1024, 2048, 4096, 8192) if t <= n] or [n]

    def make_thunk(cfg):
        def thunk():
            return perplexity._symmetrize_scan(idx, p, tile=cfg["tile"])
        return thunk

    return [dict(tile=t) for t in tiles], make_thunk


def _sweep_explore(shape, backend):
    del backend
    import jax.numpy as jnp

    from repro.core import neighbor_explore as ne
    n, kk, d = shape.get("n", 8192), shape.get("k", 32), shape.get("d", 128)
    keys = jax.random.split(jax.random.key(4), 2)
    x = jax.random.normal(keys[0], (n, d), jnp.float32)
    from repro.core.knn import brute_force_knn
    idx, dist = brute_force_knn(x[:min(n, 4096)], min(kk, 32))
    # explore over the brute-forced subgraph: real distances, real dup
    # structure — a random graph would sweep an unrepresentative gather
    nn = idx.shape[0]
    tiles = [t for t in (256, 512, 1024, 2048) if t <= nn] or [nn]

    def make_thunk(cfg):
        def thunk():
            return ne._explore_round(x[:nn], idx, dist, keys[1], sample=0,
                                     tile=cfg["tile"], r_cap=idx.shape[1])
        return thunk

    return [dict(tile=t) for t in tiles], make_thunk


_SWEEPS = {
    "topk_sqdist": _sweep_topk,
    "knn_window_fold": _sweep_window_fold,
    "largevis_edge_step": _sweep_edge_step,
    "largevis_grads": _sweep_grads,
    "symmetrize": _sweep_symmetrize,
    "neighbor_explore": _sweep_explore,
    # "layout_chunk" has no sweep builder on purpose: dispatch chunking
    # is tunable only via the cache/committed table (a sweep would need a
    # full layout driver per candidate — the fig6/table2 benches already
    # measure that trade-off end to end)
}
