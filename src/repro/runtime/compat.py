"""Version-portability shims over the JAX API surface.

``shard_map`` graduated from ``jax.experimental.shard_map`` (<= 0.4.x,
``check_rep=`` keyword) to ``jax.shard_map`` (>= 0.5, ``check_vma=``
keyword).  Every multi-device code path in this repo (KNN pipeline,
local-SGD layout, sharded layout step) goes through :func:`shard_map`
below so the rest of the code is written once against the new calling
convention and runs on either JAX.  :func:`make_mesh` covers the same
split for ``jax.make_mesh`` (added in 0.4.35; the CI jax floor is
0.4.30).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` if available, else the experimental fallback.

    Mirrors the modern keyword API; ``check_vma`` maps onto the old
    ``check_rep`` flag (both gate the replication/varying-axes checker).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` if available (>= 0.4.35), else a hand-built
    ``jax.sharding.Mesh`` over the first ``prod(axis_shapes)`` devices."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    n = math.prod(axis_shapes)
    devs = np.asarray(jax.devices()[:n]).reshape(axis_shapes)
    return jax.sharding.Mesh(devs, axis_names)
