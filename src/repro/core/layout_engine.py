"""Scan-fused layout engine: H SGD steps per device dispatch (paper §3.2).

The layout stage is the paper's linear-time hot path, and a per-step Python
driver re-dispatches one jitted ``layout_step`` per SGD step — at the
collision-capped batch sizes (≤ N/2) that is thousands of host round trips,
so dispatch overhead dominates exactly the regime the paper optimizes.  This
module fuses the loop into the compiled program:

* :func:`sgd_edge_step` — the single-step body (alias edge/negative sampling
  + fused gradient + one scatter-add), shared by every driver so the scanned
  and per-step paths stay numerically identical.  Samplers enter as the
  :class:`~repro.core.sampler.EdgeSampler` / ``NodeSampler`` pytrees —
  one argument per sampler threaded through ``jit``/``scan``/``shard_map``,
  not six unpacked table arrays.  Samplers are duck-typed: anything with
  ``.sample(key, ...)`` works, so the per-shard samplers from
  ``sampler.build_samplers_sharded`` (a device's local ``EdgeSampler``
  slice, the two-level ``ShardedNodeSampler``) flow through the same
  step body unchanged — sharding lives in the drivers, not here.
* :func:`scan_layout_steps` — ``jax.lax.scan`` over the step body.  Used
  unjitted inside ``shard_map`` by the local-SGD drivers (replacing their
  hand-rolled ``fori_loop`` wiring) and jitted below for the single-device
  driver.
* :func:`layout_chunk` — the jitted, **y-donating** dispatch unit: one device
  round trip runs ``len(step_ids)`` steps.  Donation keeps peak memory at one
  (N, s) buffer instead of two.

Step identity is carried by ``step_ids`` (global step numbers, folded into
the PRNG key) and ``t_fracs`` (t/T learning-rate schedule positions), both
precomputed per chunk, so a scanned trajectory is step-for-step the same
stream of (key, lr) pairs the per-step Python loop produces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import objective
from repro.kernels import ops

# static hyper-parameters of the step body (everything that changes the
# traced program rather than just its inputs)
STATIC_ARGNAMES = (
    "n_negatives",
    "n_nodes",
    "prob_fn",
    "a",
    "gamma",
    "clip",
    "batch",
    "fused_step",
)


def dispatch_steps(requested: int, *, n_nodes: int, batch: int) -> int:
    """Resolve the scan-chunk length (steps per device dispatch).

    ``requested`` (``cfg.steps_per_dispatch``) wins when positive; 0/None
    asks the autotuner for the "layout_chunk" cell — a cache/table-only
    tunable (no sweep builder: measuring it needs a full layout driver
    per candidate, which the fig6/table2 benches already do end to end).
    Chunking is results-neutral: the (key, lr) stream is precomputed per
    global step id, so any chunking yields the same trajectory.
    Returns 0 when neither source picks (drivers keep their own default).
    """
    if requested:
        return int(requested)
    from repro.runtime import autotune
    # off mode (and any miss) returns the sentinel 0 = "no opinion"
    cfg = autotune.get("layout_chunk", dict(n=n_nodes, b=batch),
                       dict(steps=0))
    return int(cfg["steps"])


def apply_edge_batch(
    y,
    i,
    j,
    negs,
    neg_mask,
    lr,
    *,
    prob_fn: str = "inv_quadratic",
    a: float = 1.0,
    gamma: float = 7.0,
    clip: float = 5.0,
    fused_step: bool = True,
    n_frozen: int = 0,
):
    """Apply one pre-sampled edge batch to the (N, s) embedding.

    The update body shared by :func:`sgd_edge_step` (which samples the
    batch from the alias samplers) and the out-of-sample transform /
    serving paths (`core/transform.py`, which sample per-query neighbor
    edges) — one definition of the fused/split routing and of the
    canonical per-edge interleaved update order, so every consumer stays
    bitwise-consistent with the fused kernel.

    ``lr`` is a scalar or a (B,) per-edge vector; ``n_frozen`` masks
    updates to rows below that index to -0.0 (a bitwise no-op add) — the
    frozen-corpus transform mode.  ``fused_step`` routes through the
    fully-fused edge-step kernel (``kernels/largevis_step.py``); the
    split gather/grad/scatter path below remains for autodiff
    ``prob_fn``s, embeddings past the kernel's TPU VMEM bound
    (``ops.fused_step_supported``), and debugging; both paths apply
    updates in the same canonical per-edge interleaved order, so their
    trajectories match bitwise.
    """
    if (
        fused_step
        and prob_fn == "inv_quadratic"
        and ops.fused_step_supported(y.shape[0], y.shape[1])
    ):
        return ops.largevis_edge_step(
            y, i, j, negs, neg_mask, lr, gamma=gamma, a=a, clip=clip,
            n_frozen=n_frozen
        )

    yi, yj, yneg = y[i], y[j], y[negs]
    if prob_fn == "inv_quadratic":
        gi, gj, gneg = ops.largevis_grads(
            yi, yj, yneg, neg_mask, gamma=gamma, a=a, clip=clip
        )
    else:
        gi, gj, gneg = objective.grads_autodiff(
            yi, yj, yneg, neg_mask, prob_fn=prob_fn, a=a, gamma=gamma, clip=clip
        )
    # single fused scatter-add (3 separate .at[].add calls triple the
    # y read/write traffic — §Perf hillclimb 3 iter 2), per-edge
    # interleaved [i_e, j_e, negs_e] so the duplicate-accumulation order
    # matches the fused kernel's sequential loop bitwise
    s = y.shape[1]
    idx = jnp.concatenate([i[:, None], j[:, None], negs], axis=1).reshape(-1)
    upd = jnp.concatenate([gi[:, None], gj[:, None], gneg], axis=1).reshape(-1, s)
    lr = jnp.asarray(lr, jnp.float32)
    if lr.ndim:                        # (B,) per-edge -> per update row
        lr = jnp.repeat(lr, 2 + negs.shape[1])[:, None]
    upd = -lr * upd
    if n_frozen:
        upd = jnp.where((idx >= n_frozen)[:, None], upd, jnp.float32(-0.0))
    return y.at[idx].add(upd)


def sgd_edge_step(
    y,
    key,
    t_frac,
    *,
    edge_sampler,
    neg_sampler,
    n_negatives: int,
    n_nodes: int,
    prob_fn: str = "inv_quadratic",
    a: float = 1.0,
    gamma: float = 7.0,
    clip: float = 5.0,
    rho0: float = 1.0,
    batch: int = 4096,
    fused_step: bool = True,
):
    """One SGD step over a freshly sampled edge batch.  t_frac = t/T.

    ``edge_sampler`` / ``neg_sampler`` are the :class:`~repro.core.sampler`
    pytrees — one argument each instead of six unpacked table arrays, the
    same signature for every driver (the sampled index stream is bitwise
    identical to the unpacked form: ``EdgeSampler.sample`` is exactly the
    old ``sample_alias`` + two gathers).

    Unjitted on purpose: ``core.layout.layout_step`` wraps it for per-step
    dispatch, :func:`scan_layout_steps` scans it, and the shard_map local-SGD
    bodies inline it — one definition, three drivers.

    ``fused_step`` routes the update through the fully-fused edge-step
    kernel (``kernels/largevis_step.py``: in-kernel gather + grad +
    scatter-accumulate, y aliased in place, no (B, M, s) intermediates or
    (B*(2+M), s) concat buffer).  The split gather/grad/scatter path below
    remains for autodiff ``prob_fn``s, embeddings past the kernel's TPU
    VMEM bound (``ops.fused_step_supported``), and ``fused_step=False``
    debugging; both paths apply updates in the same canonical per-edge
    interleaved order, so their trajectories match bitwise.
    """
    ke, kn, _ = jax.random.split(key, 3)
    i, j = edge_sampler.sample(ke, batch)
    negs = neg_sampler.sample(kn, (batch, n_negatives))
    # mask collisions: negative == source or target of the positive edge
    neg_mask = ((negs != i[:, None]) & (negs != j[:, None])).astype(jnp.float32)
    lr = rho0 * jnp.maximum(1.0 - t_frac, 1e-4)
    del n_nodes  # == y.shape[0] in every driver; apply_edge_batch re-derives
    return apply_edge_batch(
        y, i, j, negs, neg_mask, lr,
        prob_fn=prob_fn, a=a, gamma=gamma, clip=clip, fused_step=fused_step
    )


def scan_layout_steps(y, base_key, step_ids, t_fracs, **kw):
    """Run ``len(step_ids)`` SGD steps as one ``lax.scan``.

    step k uses key ``fold_in(base_key, step_ids[k])`` and lr position
    ``t_fracs[k]`` — the same (key, lr) stream as a Python loop over
    ``sgd_edge_step``, so trajectories match the per-step driver.
    """

    def one(y, x):
        sid, tf = x
        return sgd_edge_step(y, jax.random.fold_in(base_key, sid), tf, **kw), None

    y, _ = jax.lax.scan(one, y, (step_ids, t_fracs))
    return y


@functools.partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=STATIC_ARGNAMES,
)
def layout_chunk(
    y,
    base_key,
    step_ids,
    t_fracs,
    *,
    edge_sampler,
    neg_sampler,
    n_negatives: int,
    n_nodes: int,
    prob_fn: str = "inv_quadratic",
    a: float = 1.0,
    gamma: float = 7.0,
    clip: float = 5.0,
    rho0: float = 1.0,
    batch: int = 4096,
    fused_step: bool = True,
):
    """Jitted dispatch unit: ``len(step_ids)`` scanned steps, donated ``y``.

    The chunk length is static (it is a shape), so a driver using a fixed
    ``steps_per_dispatch`` plus one remainder chunk compiles at most twice.
    """
    return scan_layout_steps(
        y,
        base_key,
        step_ids,
        t_fracs,
        edge_sampler=edge_sampler,
        neg_sampler=neg_sampler,
        n_negatives=n_negatives,
        n_nodes=n_nodes,
        prob_fn=prob_fn,
        a=a,
        gamma=gamma,
        clip=clip,
        rho0=rho0,
        batch=batch,
        fused_step=fused_step,
    )
