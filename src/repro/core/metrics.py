"""Evaluation metrics: KNN recall (graph accuracy, paper Figs 2-3) and the
KNN-classifier accuracy on 2D coordinates (paper's layout quality proxy,
Fig 5)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn as knn_lib


def knn_classifier_accuracy(y2d, labels, *, k: int = 5,
                            n_test: int = 1000, key=None) -> float:
    """Hold out n_test points; classify each by majority label of its k
    nearest neighbors (in the 2D layout) among the remaining points."""
    if key is None:
        key = jax.random.key(0)
    n = y2d.shape[0]
    n_test = min(n_test, n // 4)
    perm = jax.random.permutation(key, n)
    test, train = perm[:n_test], perm[n_test:]
    from repro.kernels import ops
    d = ops.pairwise_sqdist(y2d[test], y2d[train])
    _, ni = jax.lax.top_k(-d, k)
    votes = labels[train][ni]                             # (n_test, k)
    n_classes = int(labels.max()) + 1
    counts = jax.nn.one_hot(votes, n_classes).sum(axis=1)
    pred = jnp.argmax(counts, axis=1)
    return float(jnp.mean((pred == labels[test]).astype(jnp.float32)))


def graph_recall(x, knn_idx, *, n_eval: int = 2000, key=None) -> float:
    """Recall vs exact KNN on a random node subset (paper's 'accuracy')."""
    if key is None:
        key = jax.random.key(1)
    n, k = knn_idx.shape
    rows = jax.random.permutation(key, n)[:min(n_eval, n)]
    from repro.kernels import ops
    d = ops.pairwise_sqdist(x[rows], x)
    d = d.at[jnp.arange(rows.shape[0]), rows].set(3.4e38)
    _, true_idx = jax.lax.top_k(-d, k)
    got = knn_idx[rows]
    matches = (got[:, :, None] == true_idx[:, None, :]).any(-1)
    return float(jnp.mean(matches.astype(jnp.float32)))
