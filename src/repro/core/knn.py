"""Approximate KNN graph construction (paper §3.1, Algo 1 — TPU-native).

The paper builds random-projection trees and repairs a cheap initial graph
with neighbor exploring.  Pointer-chasing trees don't map to TPU, so the
*forest* here is one of two MXU-friendly variants (see DESIGN.md §2):

  rp_mode="hash":  per tree, D sign-projections (one matmul) give each point
                   a D-bit bucket code; points are sorted by code and each
                   point brute-forces a contiguous ±window in the sorted
                   order (blocked distance matmuls).  Cheapest, weakest
                   splits — exactly the regime the paper's neighbor
                   exploring is designed to repair.
  rp_mode="tree":  per-node hyperplanes gathered by the point's current code
                   (level-by-level descent, vectorized over all points) —
                   closer to the paper's RP trees; hyperplanes are sampled
                   from global point pairs.

All distance->top-k work routes through the streaming fused kernel
(``kernels.ops.topk_sqdist``): each (bm, bn) distance tile folds into a
running (bm, k) best state, so no path here materializes an (M, N)
distance matrix or a post-hoc top_k/merge pass.  ``forest_knn`` scans the
stacked tree codes with the running top-k as carry — one compiled tree
body regardless of n_trees, with cross-tree duplicate suppression done
in-fold (``dedup=True``).

Multi-device: `core/knn_sharded.py` builds the same graph with the point
set sharded over the mesh "data" axis — per-shard codes and ring-streamed
`topk_sqdist` calls whose running state is carried across ring steps
(peak buffers (N/P, N/P), never (N, N)), plus a sharded
neighbor-exploring driver.  `build_knn_graph` dispatches there when
``cfg.distributed`` is set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels import ref as ref_lib
from repro.runtime import autotune

INF = jnp.float32(3.4e38)


# ---------------------------------------------------------------------------
# Exact oracle
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "tile", "impl"))
def brute_force_knn(x: jax.Array, k: int, *, tile: int | None = None,
                    impl: str = "auto"):
    """Exact KNN.  Returns (idx (N,k) int32, sqdist (N,k) f32).

    One fused dispatch: ``ops.topk_sqdist(x, x, k)`` streams column tiles
    of the point set into a running top-k per row tile — the (t, N)
    distance buffer of the old materialize-then-top_k formulation never
    exists.  ``tile`` forces the row-tile height (bm); the default None
    leaves bm/bn to the ops-layer autotuner (whose ``AUTOTUNE=off``
    fallback is the same bm=2048 this wrapper used to hard-code).
    Self-edges are masked in-fold via a_ids == b_ids.
    """
    N, d = x.shape
    k = min(int(k), N - 1)
    ids = jnp.arange(N, dtype=jnp.int32)
    kw = {} if tile is None else dict(bm=min(int(tile), N))
    return ops.topk_sqdist(x, x, k, a_ids=ids, b_ids=ids, impl=impl, **kw)


# ---------------------------------------------------------------------------
# Candidate merging (gather-based candidate lists, e.g. neighbor exploring)
# ---------------------------------------------------------------------------

def merge_candidates(ids: jax.Array, dists: jax.Array, k: int,
                     self_idx: jax.Array = None):
    """Per-row top-k over candidate (ids, dists) with duplicate suppression.

    ids: (R, C) int32; dists: (R, C) f32.  Duplicates (same id twice in a
    row) and self-edges get +inf distance.  Returns (idx (R,k), dist (R,k)).

    This is the merge for *gather-based* candidate lists (neighbor
    exploring), where the same id can appear many times within one row —
    the argsort-by-id pass suppresses all copies.  Tile-structured
    distance work (brute force, window candidates, the sharded ring) goes
    through ``ops.topk_sqdist`` instead, which folds tiles into a running
    state without any argsort.
    """
    R, C = ids.shape
    if self_idx is not None:
        dists = jnp.where(ids == self_idx[:, None], INF, dists)
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(dists, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((R, 1), bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=1)
    d_s = jnp.where(dup, INF, d_s)
    nd, ni = jax.lax.top_k(-d_s, k)
    return jnp.take_along_axis(ids_s, ni, axis=1), -nd


# ---------------------------------------------------------------------------
# Projection forest
# ---------------------------------------------------------------------------

def _auto_depth(n: int, leaf_target: int) -> int:
    return max(2, min(24, int(np.ceil(np.log2(max(n, 2) / leaf_target)))))


def hash_codes(x: jax.Array, key, n_trees: int, depth: int, *,
               proj: jax.Array = None) -> jax.Array:
    """Sign-random-projection bucket codes: (N, n_trees) int32.

    ``proj`` (d, n_trees*depth) overrides the key-derived hyperplanes —
    the sharded pipeline passes one shared matrix to every shard."""
    if proj is None:
        d = x.shape[1]
        proj = jax.random.normal(key, (d, n_trees * depth), jnp.float32)
    bits = (x.astype(jnp.float32) @ proj) > 0.0          # (N, NT*D)
    bits = bits.reshape(x.shape[0], n_trees, depth)
    weights = (1 << jnp.arange(depth, dtype=jnp.int32))
    return jnp.sum(bits.astype(jnp.int32) * weights, axis=-1)


def tree_codes(x: jax.Array, key, n_trees: int, depth: int) -> jax.Array:
    """Per-node hyperplane descent codes (paper-faithful RP-tree variant).

    Hyperplanes follow the paper's construction: equidistant to two sampled
    data points (h = x_a - x_b, offset = h.(x_a+x_b)/2); per tree level
    there are 2^level nodes, each with its own sampled pair, and every point
    gathers the hyperplane of the node its code currently addresses.
    """
    N, d = x.shape
    codes = []
    xf = x.astype(jnp.float32)
    for t in range(n_trees):
        tkey = jax.random.fold_in(key, t)
        code = jnp.zeros((N,), jnp.int32)
        for level in range(depth):
            lkey = jax.random.fold_in(tkey, level)
            n_nodes = 1 << level
            pairs = jax.random.randint(lkey, (n_nodes, 2), 0, N)
            xa, xb = xf[pairs[:, 0]], xf[pairs[:, 1]]
            h = xa - xb                                   # (n_nodes, d)
            b = jnp.sum(h * (xa + xb) * 0.5, axis=1)      # (n_nodes,)
            side = jnp.einsum("nd,nd->n", xf, h[code]) > b[code]
            code = code * 2 + side.astype(jnp.int32)
        codes.append(code)
    return jnp.stack(codes, axis=1)                       # (N, NT)


def _window_fold_one_tree(x: jax.Array, code: jax.Array, k: int,
                          window: int, run_ids: jax.Array,
                          run_d: jax.Array, impl: str):
    """Fold one tree's sorted-window candidates into the running top-k.

    Points sort by bucket code; each W-block tiles directly against its
    ±window neighborhood (3W rows) through ``ops.topk_sqdist``, seeded
    with the running (k) state of the block's rows — the (N, k+1)
    per-tree candidate buffer and the argsort-based merge of the old
    formulation never materialize.  Self-edges mask in-fold (no k+1
    over-fetch); the boundary blocks' duplicated neighbor segment (block
    0's "lo" is itself, block nb-1's "hi" is itself) is invalidated by
    id=-1 so no candidate is ever offered twice within a tile, and
    cross-tree duplicates are suppressed against the running state
    (``dedup=True``).  Returns the merged (idx, dist) in original index
    order.
    """
    N, d = x.shape
    W = min(window, N)
    # the structural tiling (bm=W row blocks against their 3W
    # neighborhood) is only the default — the fold is correct for any
    # bm <= W / bn <= 3W, so the sub-tiling is autotunable (resolved at
    # trace time; W is static here)
    tcfg = autotune.get("knn_window_fold", dict(w=W, k=k, d=d),
                        dict(bm=W, bn=3 * W))
    order = jnp.argsort(code).astype(jnp.int32)           # (N,) sorted->orig
    Np = int(np.ceil(N / W)) * W
    pad = Np - N
    order_p = jnp.concatenate(
        [order, jnp.full((pad,), -1, jnp.int32)]) if pad else order
    safe = jnp.clip(order_p, 0, N - 1)
    xs = x[safe]                                          # (Np, d)
    st_i = jnp.where(order_p[:, None] >= 0, run_ids[safe], -1)
    st_d = jnp.where(order_p[:, None] >= 0, run_d[safe],
                     ref_lib.INVALID_DIST)
    nb = Np // W
    blocks = xs.reshape(nb, W, d)
    ids = order_p.reshape(nb, W)

    def block_fold(j):
        lo = jnp.clip(j - 1, 0, nb - 1)
        hi = jnp.clip(j + 1, 0, nb - 1)
        bx = jnp.concatenate([blocks[lo], blocks[j], blocks[hi]])  # (3W, d)
        bid = jnp.concatenate([
            jnp.where(j == 0, -1, ids[lo]),               # lo==j dup at j=0
            ids[j],
            jnp.where(j == nb - 1, -1, ids[hi]),          # hi==j dup at end
        ])
        rows = jax.lax.dynamic_slice_in_dim(st_i.reshape(nb, W, -1), j, 1)
        rd = jax.lax.dynamic_slice_in_dim(st_d.reshape(nb, W, -1), j, 1)
        return ops.topk_sqdist(
            blocks[j], bx, k, a_ids=ids[j], b_ids=bid,
            init_ids=rows[0], init_dists=rd[0], dedup=True,
            bm=min(tcfg["bm"], W), bn=min(tcfg["bn"], 3 * W), impl=impl)

    cid, cd = jax.lax.map(block_fold, jnp.arange(nb))
    flat_ids = cid.reshape(Np, k)[:N]
    flat_d = cd.reshape(Np, k)[:N]
    # rows are in sorted order; scatter back to original index space
    inv = jnp.zeros((N,), jnp.int32).at[order].set(
        jnp.arange(N, dtype=jnp.int32))
    return flat_ids[inv], flat_d[inv]


@functools.partial(jax.jit, static_argnames=("n_trees", "depth", "k",
                                             "window", "rp_mode", "impl"))
def forest_knn(x: jax.Array, key, *, n_trees: int, depth: int, k: int,
               window: int, rp_mode: str = "hash", impl: str = "auto"):
    """Initial approximate KNN from the projection forest.

    One ``lax.scan`` over the stacked (n_trees, N) tree codes with the
    running (N, k) top-k as carry: the compiled program contains a single
    tree body regardless of n_trees (the old Python loop unrolled it
    n_trees times into the HLO), and peak candidate memory is the (W, 3W)
    window tile plus the (N, k) state — never an all-trees concat.
    Streaming a non-survivor out early never evicts a final neighbor
    (top-k with id-dedup is associative), so the scan is equivalent to
    the batch merge.
    """
    N = x.shape[0]
    codes = (hash_codes if rp_mode == "hash" else tree_codes)(
        x, key, n_trees, depth)

    def one_tree(carry, code):
        run_ids, run_d = carry
        return _window_fold_one_tree(x, code, k, window, run_ids, run_d,
                                     impl), None

    init = (jnp.full((N, k), -1, jnp.int32),
            jnp.full((N, k), ref_lib.INVALID_DIST, jnp.float32))
    (idx, dist), _ = jax.lax.scan(one_tree, init, codes.T)
    return idx, dist


def build_knn_graph(x: jax.Array, key, cfg, *, fault=None):
    """Full paper pipeline: forest init + neighbor exploring iterations.

    Returns (idx (N,K) int32, sqdist (N,K) f32).  With
    ``cfg.distributed`` set, routes to the sharded multi-device ring
    pipeline (`core/knn_sharded.py`) — unless ``cfg.knn_distributed``
    is False, which keeps the paper's linear forest+explore path for
    stage 1 (the ring's masked distance fold is O(N^2 d / P) compute;
    see the config docstring) while the downstream stages stay sharded.
    ``fault`` (a FaultInjector) reaches the sharded path's per-shard
    ``knn_ring_step:<s>`` sites; the single-device path has none.
    """
    if (getattr(cfg, "distributed", False)
            and getattr(cfg, "knn_distributed", True)):
        from repro.core.knn_sharded import build_knn_graph_sharded
        return build_knn_graph_sharded(x, key, cfg, fault=fault)
    from repro.core.neighbor_explore import neighbor_explore
    N = x.shape[0]
    k = min(cfg.n_neighbors, N - 1)
    depth = cfg.tree_depth or _auto_depth(N, cfg.leaf_target)
    idx, dist = forest_knn(
        x, key, n_trees=cfg.n_trees, depth=depth, k=k,
        window=cfg.window, rp_mode=cfg.rp_mode,
        impl=getattr(cfg, "knn_impl", "auto"))
    if cfg.n_explore_iters:
        idx, dist = neighbor_explore(
            x, idx, dist, iters=cfg.n_explore_iters,
            sample=cfg.explore_sample, key=jax.random.fold_in(key, 999))
    return idx, dist


@functools.partial(jax.jit, static_argnames=("tile",))
def _recall_hits(idx: jax.Array, true_idx: jax.Array, tile: int):
    n_tiles = idx.shape[0] // tile
    K = idx.shape[1]

    def one(args):
        a, t = args
        return jnp.sum((a[:, :, None] == t[:, None, :]).any(-1)
                       .astype(jnp.float32))

    return jnp.sum(jax.lax.map(
        one, (idx.reshape(n_tiles, tile, K),
              true_idx.reshape(n_tiles, tile, K))))


def knn_recall(idx: jax.Array, true_idx: jax.Array, *,
               tile: int = 4096) -> float:
    """Fraction of true K nearest neighbors recovered (paper's accuracy).

    Row-tiled: the match tensor is (tile, K, K) bool per tile instead of
    (N, K, K) — recall on an N=1M, K=50 graph peaks at ~10 MB instead of
    the 2.5 GB that OOM'd the metrics path.  Padded rows (-1 vs -2) can
    never match and the mean divides by the real N*K.
    """
    N, K = idx.shape
    t = min(tile, N)
    pad = (-N) % t
    if pad:
        idx = jnp.concatenate(
            [idx, jnp.full((pad, K), -1, idx.dtype)])
        true_idx = jnp.concatenate(
            [true_idx, jnp.full((pad, K), -2, true_idx.dtype)])
    return float(_recall_hits(idx, true_idx, t) / (N * K))
