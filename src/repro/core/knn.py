"""Approximate KNN graph construction (paper §3.1, Algo 1 — TPU-native).

The paper builds random-projection trees and repairs a cheap initial graph
with neighbor exploring.  Pointer-chasing trees don't map to TPU, so the
*forest* here is one of two MXU-friendly variants (see DESIGN.md §2):

  rp_mode="hash":  per tree, D sign-projections (one matmul) give each point
                   a D-bit bucket code; points are sorted by code and each
                   point brute-forces a contiguous ±window in the sorted
                   order (blocked distance matmuls).  Cheapest, weakest
                   splits — exactly the regime the paper's neighbor
                   exploring is designed to repair.
  rp_mode="tree":  per-node hyperplanes gathered by the point's current code
                   (level-by-level descent, vectorized over all points) —
                   closer to the paper's RP trees; hyperplanes are sampled
                   from global point pairs.

Both produce per-tree candidates merged by a dedup'd top-k.

Multi-device: `core/knn_sharded.py` builds the same graph with the point
set sharded over the mesh "data" axis — per-shard codes, ring-streamed
`pairwise_sqdist` candidate tiles with a running top-k (peak buffers
(N/P, N/P), never (N, N)), and a sharded neighbor-exploring driver.
`build_knn_graph` dispatches there when ``cfg.distributed`` is set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

INF = jnp.float32(3.4e38)


# ---------------------------------------------------------------------------
# Exact oracle
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "tile"))
def brute_force_knn(x: jax.Array, k: int, *, tile: int = 4096):
    """Exact KNN.  Returns (idx (N,k) int32, sqdist (N,k) f32).

    One dispatch: row tiles go through ``jax.lax.map`` inside the jit, so
    the oracle's timing (it is the fig2 baseline) measures distance work,
    not a Python loop's per-tile dispatch latency.  Rows are zero-padded to
    a tile multiple; padded rows never survive the final slice.
    """
    N, d = x.shape
    k = min(int(k), N - 1)
    t = min(tile, N)
    n_tiles = -(-N // t)
    xp = jnp.pad(x, ((0, n_tiles * t - N), (0, 0)))
    col = jnp.arange(N)

    def one_tile(args):
        xa, start = args
        dd = ops.pairwise_sqdist(xa, x)                   # (t, N)
        rows = start + jnp.arange(t)
        dd = jnp.where(col[None, :] == rows[:, None], INF, dd)
        nd, ni = jax.lax.top_k(-dd, k)
        return ni.astype(jnp.int32), -nd

    idx, dist = jax.lax.map(
        one_tile, (xp.reshape(n_tiles, t, d), jnp.arange(n_tiles) * t))
    return idx.reshape(n_tiles * t, k)[:N], dist.reshape(n_tiles * t, k)[:N]


# ---------------------------------------------------------------------------
# Candidate merging
# ---------------------------------------------------------------------------

def merge_candidates(ids: jax.Array, dists: jax.Array, k: int,
                     self_idx: jax.Array = None):
    """Per-row top-k over candidate (ids, dists) with duplicate suppression.

    ids: (R, C) int32; dists: (R, C) f32.  Duplicates (same id twice in a
    row) and self-edges get +inf distance.  Returns (idx (R,k), dist (R,k)).
    """
    R, C = ids.shape
    if self_idx is not None:
        dists = jnp.where(ids == self_idx[:, None], INF, dists)
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(dists, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((R, 1), bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=1)
    d_s = jnp.where(dup, INF, d_s)
    nd, ni = jax.lax.top_k(-d_s, k)
    return jnp.take_along_axis(ids_s, ni, axis=1), -nd


# ---------------------------------------------------------------------------
# Projection forest
# ---------------------------------------------------------------------------

def _auto_depth(n: int, leaf_target: int) -> int:
    return max(2, min(24, int(np.ceil(np.log2(max(n, 2) / leaf_target)))))


def hash_codes(x: jax.Array, key, n_trees: int, depth: int, *,
               proj: jax.Array = None) -> jax.Array:
    """Sign-random-projection bucket codes: (N, n_trees) int32.

    ``proj`` (d, n_trees*depth) overrides the key-derived hyperplanes —
    the sharded pipeline passes one shared matrix to every shard."""
    if proj is None:
        d = x.shape[1]
        proj = jax.random.normal(key, (d, n_trees * depth), jnp.float32)
    bits = (x.astype(jnp.float32) @ proj) > 0.0          # (N, NT*D)
    bits = bits.reshape(x.shape[0], n_trees, depth)
    weights = (1 << jnp.arange(depth, dtype=jnp.int32))
    return jnp.sum(bits.astype(jnp.int32) * weights, axis=-1)


def tree_codes(x: jax.Array, key, n_trees: int, depth: int) -> jax.Array:
    """Per-node hyperplane descent codes (paper-faithful RP-tree variant).

    Hyperplanes follow the paper's construction: equidistant to two sampled
    data points (h = x_a - x_b, offset = h.(x_a+x_b)/2); per tree level
    there are 2^level nodes, each with its own sampled pair, and every point
    gathers the hyperplane of the node its code currently addresses.
    """
    N, d = x.shape
    codes = []
    xf = x.astype(jnp.float32)
    for t in range(n_trees):
        tkey = jax.random.fold_in(key, t)
        code = jnp.zeros((N,), jnp.int32)
        for level in range(depth):
            lkey = jax.random.fold_in(tkey, level)
            n_nodes = 1 << level
            pairs = jax.random.randint(lkey, (n_nodes, 2), 0, N)
            xa, xb = xf[pairs[:, 0]], xf[pairs[:, 1]]
            h = xa - xb                                   # (n_nodes, d)
            b = jnp.sum(h * (xa + xb) * 0.5, axis=1)      # (n_nodes,)
            side = jnp.einsum("nd,nd->n", xf, h[code]) > b[code]
            code = code * 2 + side.astype(jnp.int32)
        codes.append(code)
    return jnp.stack(codes, axis=1)                       # (N, NT)


def _window_candidates_one_tree(x: jax.Array, code: jax.Array, k: int,
                                window: int):
    """Sorted-window candidates for one tree.  Returns (idx, dist) (N,k)."""
    N, d = x.shape
    W = window
    order = jnp.argsort(code)                             # (N,) sorted->orig
    Np = int(np.ceil(N / W)) * W
    pad = Np - N
    order_p = jnp.concatenate(
        [order, jnp.full((pad,), N, jnp.int32)]) if pad else order
    xs = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])[order_p]
    nb = Np // W
    blocks = xs.reshape(nb, W, d)
    ids = order_p.reshape(nb, W)

    def block_dists(j):
        a = blocks[j]                                      # (W, d)
        lo = jnp.clip(j - 1, 0, nb - 1)
        hi = jnp.clip(j + 1, 0, nb - 1)
        b = jnp.concatenate([blocks[lo], blocks[j], blocks[hi]])   # (3W, d)
        bid = jnp.concatenate([ids[lo], ids[j], ids[hi]])
        dd = ops.pairwise_sqdist(a, b)                     # (W, 3W)
        dd = jnp.where(bid[None, :] == N, INF, dd)         # padding
        kk = min(k + 1, 3 * W)
        nd, ni = jax.lax.top_k(-dd, kk)
        return bid[ni], -nd                                # (W,kk)

    cid, cd = jax.lax.map(block_dists, jnp.arange(nb))
    kk = cid.shape[-1]
    flat_ids = cid.reshape(Np, kk)[:N]
    flat_d = cd.reshape(Np, kk)[:N]
    # rows are in sorted order; scatter back to original index space
    inv = jnp.zeros((N,), jnp.int32).at[order].set(jnp.arange(N, dtype=jnp.int32))
    return flat_ids[inv], flat_d[inv]


@functools.partial(jax.jit, static_argnames=("n_trees", "depth", "k",
                                             "window", "rp_mode"))
def forest_knn(x: jax.Array, key, *, n_trees: int, depth: int, k: int,
               window: int, rp_mode: str = "hash"):
    """Initial approximate KNN from the projection forest.

    Trees stream through a running ``merge_candidates`` top-k: each tree's
    (N, k+1) window candidates merge into the running (N, k) result, so the
    peak candidate buffer is (N, 2k+1) instead of the (N, n_trees*(k+1))
    all-trees concat — ~n_trees x less memory for the same output (top-k
    with id-dedup is associative: discarding a non-top-k candidate early
    never evicts a final neighbor, and a duplicate id carries the same
    distance from every tree).
    """
    N = x.shape[0]
    codes = (hash_codes if rp_mode == "hash" else tree_codes)(
        x, key, n_trees, depth)
    self_idx = jnp.arange(N)
    run_ids = run_d = None
    for t in range(n_trees):
        cid, cd = _window_candidates_one_tree(x, codes[:, t], k, window)
        if run_ids is not None:
            cid = jnp.concatenate([run_ids, cid], axis=1)
            cd = jnp.concatenate([run_d, cd], axis=1)
        run_ids, run_d = merge_candidates(cid, cd, k, self_idx=self_idx)
    return run_ids, run_d


def build_knn_graph(x: jax.Array, key, cfg):
    """Full paper pipeline: forest init + neighbor exploring iterations.

    Returns (idx (N,K) int32, sqdist (N,K) f32).  With
    ``cfg.distributed`` set, routes to the sharded multi-device pipeline
    (`core/knn_sharded.py`).
    """
    if getattr(cfg, "distributed", False):
        from repro.core.knn_sharded import build_knn_graph_sharded
        return build_knn_graph_sharded(x, key, cfg)
    from repro.core.neighbor_explore import neighbor_explore
    N = x.shape[0]
    k = min(cfg.n_neighbors, N - 1)
    depth = cfg.tree_depth or _auto_depth(N, cfg.leaf_target)
    idx, dist = forest_knn(
        x, key, n_trees=cfg.n_trees, depth=depth, k=k,
        window=cfg.window, rp_mode=cfg.rp_mode)
    if cfg.n_explore_iters:
        idx, dist = neighbor_explore(
            x, idx, dist, iters=cfg.n_explore_iters,
            sample=cfg.explore_sample, key=jax.random.fold_in(key, 999))
    return idx, dist


def knn_recall(idx: jax.Array, true_idx: jax.Array) -> float:
    """Fraction of true K nearest neighbors recovered (paper's accuracy)."""
    N, K = idx.shape
    matches = (idx[:, :, None] == true_idx[:, None, :]).any(-1)
    return float(jnp.mean(matches.astype(jnp.float32)))
