"""Alias-method samplers: O(1) weighted edge sampling + noise-distribution
negative sampling (paper §3.2, Mikolov-style P_n(j) ∝ d_j^0.75).

Tables are built once on host (numpy, O(n)); sampling on device is two
gathers + a compare per draw, fully batched.  Edge sampling ∝ w_ij is the
paper's variance fix: sampled edges are treated as *binary*, so divergent
edge weights never enter the gradient.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def build_alias(probs: np.ndarray):
    """Vose's alias method.  probs: (n,) nonnegative, any scale.
    Returns (threshold (n,) f32, alias (n,) i32)."""
    p = np.asarray(probs, np.float64)
    n = p.shape[0]
    assert n > 0 and (p >= 0).all()
    s = p.sum()
    assert s > 0, "all-zero probabilities"
    scaled = p * (n / s)
    threshold = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int32)
    small = [i for i in range(n) if scaled[i] < 1.0]
    large = [i for i in range(n) if scaled[i] >= 1.0]
    while small and large:
        s_i = small.pop()
        l_i = large.pop()
        threshold[s_i] = scaled[s_i]
        alias[s_i] = l_i
        scaled[l_i] = scaled[l_i] - (1.0 - scaled[s_i])
        (small if scaled[l_i] < 1.0 else large).append(l_i)
    for rest in (small, large):
        for i in rest:
            threshold[i] = 1.0
    return threshold.astype(np.float32), alias


def sample_alias(key, threshold: jax.Array, alias: jax.Array, shape):
    """Batched alias draws -> int32 indices of the given shape."""
    n = threshold.shape[0]
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, shape, 0, n)
    u = jax.random.uniform(k2, shape)
    return jnp.where(u < threshold[idx], idx, alias[idx]).astype(jnp.int32)


@dataclasses.dataclass
class EdgeSampler:
    """Directed edge list (src, dst) with alias table over edge weights."""
    src: jax.Array          # (E,) int32
    dst: jax.Array          # (E,) int32
    threshold: jax.Array    # (E,) f32
    alias: jax.Array        # (E,) int32
    n_edges: int

    def sample(self, key, batch: int):
        e = sample_alias(key, self.threshold, self.alias, (batch,))
        return self.src[e], self.dst[e]


@dataclasses.dataclass
class NodeSampler:
    """Noise distribution over nodes, P_n(j) ∝ deg_j^power."""
    threshold: jax.Array
    alias: jax.Array
    n_nodes: int

    def sample(self, key, shape):
        return sample_alias(key, self.threshold, self.alias, shape)


def build_edge_sampler(knn_idx, weights) -> EdgeSampler:
    """knn_idx/weights: (N, K) directed graph -> flat edge sampler."""
    N, K = knn_idx.shape
    src = np.repeat(np.arange(N, dtype=np.int32), K)
    dst = np.asarray(knn_idx, np.int32).reshape(-1)
    w = np.asarray(weights, np.float64).reshape(-1)
    w = np.maximum(w, 0.0)
    if w.sum() <= 0:
        w = np.ones_like(w)
    thr, alias = build_alias(w)
    return EdgeSampler(jnp.asarray(src), jnp.asarray(dst),
                       jnp.asarray(thr), jnp.asarray(alias), len(src))


def build_negative_sampler(knn_idx, weights, *,
                           power: float = 0.75) -> NodeSampler:
    """Weighted degree d_j = sum_i w_ij (directed, in+out), then ^power."""
    N, K = knn_idx.shape
    w = np.asarray(weights, np.float64)
    deg = w.sum(axis=1)                                   # out-degree
    np.add.at(deg, np.asarray(knn_idx, np.int64).reshape(-1),
              w.reshape(-1))                              # + in-degree
    deg = np.maximum(deg, 1e-12) ** power
    thr, alias = build_alias(deg)
    return NodeSampler(jnp.asarray(thr), jnp.asarray(alias), N)
