"""Alias-method samplers: O(1) weighted edge sampling + noise-distribution
negative sampling (paper §3.2, Mikolov-style P_n(j) ∝ d_j^0.75).

Sampling on device is two gathers + a compare per draw, fully batched.
Edge sampling ∝ w_ij is the paper's variance fix: sampled edges are treated
as *binary*, so divergent edge weights never enter the gradient.

Table construction comes in two implementations, selected by ``impl=``
(``LargeVisConfig.sampler_impl`` at the pipeline level):

* ``"device"`` (the ``"auto"`` default) — :func:`build_alias_device`, a
  fully-jitted construction: stable-partition the scaled probabilities
  into smalls (< 1) and larges (>= 1) with cumsum ranks (no sort — the
  pairing below only needs the two groups in *some* fixed order, so the
  build is O(E) data movement plus O(E log E) binary searches), then
  resolve Vose's two-pointer pairing with prefix sums + ``searchsorted``
  — smalls alias to the first large whose cumulative surplus covers
  their cumulative deficit, and the boundary-straddling remainders flow
  between adjacent larges through a backward alias chain, which makes
  the per-slot marginals *exact* in exact arithmetic.  The cumulative
  arithmetic runs in f64 via a trace-scoped ``enable_x64`` on CPU/GPU
  (f32 prefix sums break down around E ~ 1e5 — see ``_alias_pairing``),
  falling back to f32 on TPU.  No per-edge Python iteration, no host
  round trip: stage-1 outputs stay device-resident all the way into the
  layout step.
* ``"host"`` — :func:`build_alias`, the classic numpy Vose loop.  O(E)
  but single-core Python (minutes at the paper's E = N*K = 150M); kept as
  the test oracle and debug path.

The produced (threshold, alias) tables differ between implementations —
any table with the right per-index marginals is a valid alias table — but
both are exact, and ``tests/test_sampler.py`` pins the device builder's
marginals against the Vose oracle via threshold/alias reconstruction.

:class:`EdgeSampler` / :class:`NodeSampler` are registered JAX pytrees, so
whole samplers thread through ``jit`` / ``lax.scan`` / ``shard_map`` as
single arguments (see ``core/layout_engine.py``).

Distributed mode (:func:`build_samplers_sharded`) builds **per-shard**
tables on the same 1-D "data" mesh the KNN ring and the perplexity
stages use: each shard runs :func:`_alias_pairing` over its own rows'
edges (local alias indices — a slab sliced out of a *global* table
would carry alias pointers outside the slab and be invalid), negative
degrees are completed with one ``psum`` of O(N) scatter partials, and a
tiny (P,)-entry shard-selection alias table over per-shard total masses
makes the two-level draw exactly proportional to the global
distribution: P(shard s) * P(e | s) = (T_s / T) * (w_e / T_s) = w_e / T.
:class:`ShardedEdgeSampler` / :class:`ShardedNodeSampler` expose the
same duck-typed ``.sample`` the layout engine consumes, so they flow
through every driver unchanged; at ``n_shards == 1`` they skip the
shard draw and reproduce the flat samplers' key streams bitwise.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.compat import shard_map


def build_alias(probs: np.ndarray):
    """Vose's alias method on host.  probs: (n,) nonnegative, any scale.
    Returns (threshold (n,) f32, alias (n,) i32).

    Pure-Python O(n) loop — the oracle the jitted device builder is tested
    against, and the ``impl="host"`` debug path."""
    p = np.asarray(probs, np.float64)
    n = p.shape[0]
    assert n > 0 and (p >= 0).all()
    s = p.sum()
    assert s > 0, "all-zero probabilities"
    scaled = p * (n / s)
    threshold = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int32)
    small = [i for i in range(n) if scaled[i] < 1.0]
    large = [i for i in range(n) if scaled[i] >= 1.0]
    while small and large:
        s_i = small.pop()
        l_i = large.pop()
        threshold[s_i] = scaled[s_i]
        alias[s_i] = l_i
        scaled[l_i] = scaled[l_i] - (1.0 - scaled[s_i])
        (small if scaled[l_i] < 1.0 else large).append(l_i)
    for rest in (small, large):
        for i in rest:
            threshold[i] = 1.0
    return threshold.astype(np.float32), alias


def _alias_pairing(probs: jax.Array, *, hi_dtype=jnp.float32):
    """Traced alias-table construction body.  probs: (n,) nonnegative, any
    scale (all-zero input falls back to uniform).  Returns
    (threshold (n,) f32, alias (n,) i32) with exact per-index marginals.

    Construction (fully vectorized — cumsum/searchsorted/scatter, zero
    host involvement): stable-partition the scaled probabilities so the
    smalls (s < 1, deficit d = 1-s) occupy a prefix and the larges
    (s >= 1, surplus e = s-1) a suffix.  The partition is cumsum ranks,
    NOT a sort — Vose's pairing works for the two groups in any fixed
    order, since the prefix arrays below are monotone by construction.
    The pairing becomes:

    * small i aliases the first large j whose cumulative surplus SE_j
      reaches the cumulative deficit D_i (one ``searchsorted``);
    * a small straddling a surplus boundary is charged wholly to the later
      large, so larges <= j under-collect by beta_{j+1} = SE_j - D_{last
      small with D <= SE_j}; large j+1 repays exactly that by keeping only
      threshold 1 - beta_{j+1} of its own slot and aliasing the remainder
      to large j (a backward chain over the partitioned larges).

    Telescoping the chain gives every index its exact target mass; the
    final boundary term is total-surplus - total-deficit = 0, so nothing
    is lost.  Ties, zero-surplus larges, zero probabilities, and n == 1
    all degenerate correctly (clamps only guard rounding).

    ``hi_dtype`` is the cumulative-arithmetic dtype.  The prefix sums
    reach magnitude ~n with sub-1.0 increments, and beta is a
    catastrophically-cancelling difference of two such prefixes — in f32
    the per-slot *relative* marginal error passes 100% around E ~ 1e5.
    :func:`_pairing_scope` therefore runs this in f64 wherever the
    backend supports it (CPU/GPU), keeping f32 only as the TPU fallback.
    """
    p = jnp.asarray(probs, jnp.float32).reshape(-1).astype(hi_dtype)
    n = p.shape[0]
    one = jnp.asarray(1.0, hi_dtype)
    zero = jnp.zeros((), hi_dtype)
    p = jnp.maximum(p, zero)
    total = jnp.sum(p)
    p = jnp.where(total > 0, p, jnp.ones_like(p))
    total = jnp.where(total > 0, total, jnp.asarray(n, hi_dtype))
    scaled = p * (n / total)

    # stable partition, smalls first: O(n) cumsum ranks + one scatter
    is_small = scaled < one
    m = jnp.sum(is_small.astype(jnp.int32))      # partition point / first
    rank_small = jnp.cumsum(is_small.astype(jnp.int32)) - 1       # large
    rank_large = m + jnp.cumsum((~is_small).astype(jnp.int32)) - 1
    dest = jnp.where(is_small, rank_small, rank_large)
    order = jnp.zeros(n, jnp.int32).at[dest].set(
        jnp.arange(n, dtype=jnp.int32))          # partitioned -> original
    ss = scaled[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    small = pos < m
    d = jnp.where(small, one - ss, zero)         # deficits  (small prefix)
    e = jnp.where(small, zero, ss - one)         # surpluses (large suffix)
    D = jnp.cumsum(d)
    SE = jnp.cumsum(e)

    # smalls -> first large whose cumulative surplus covers their deficit
    tgt = jnp.clip(jnp.searchsorted(SE, D, side="left").astype(jnp.int32),
                   m, n - 1)
    # larges: beta_j = straddling deficit owed to earlier larges, repaid by
    # this slot's alias pointing at the previous large
    prev_se = SE - e                             # SE_{j-1}
    hi = jnp.searchsorted(D, prev_se, side="right").astype(jnp.int32) - 1
    covered = jnp.where(hi >= 0, D[jnp.clip(hi, 0, n - 1)], zero)
    beta = jnp.clip(prev_se - covered, 0.0, 1.0)

    thr_sorted = jnp.where(small, ss, one - beta).astype(jnp.float32)
    alias_sorted = jnp.where(small, order[tgt],
                             order[jnp.clip(pos - 1, m, n - 1)])
    threshold = jnp.zeros(n, jnp.float32).at[order].set(thr_sorted)
    alias = jnp.zeros(n, jnp.int32).at[order].set(
        alias_sorted.astype(jnp.int32))
    return threshold, alias


_alias_jit = jax.jit(_alias_pairing, static_argnames=("hi_dtype",))


def _pairing_scope():
    """(context manager, dtype) for the pairing's cumulative arithmetic.

    CPU/GPU: a trace-scoped ``enable_x64`` so the prefix sums run in f64
    (exact marginals at any E) without requiring global x64 mode.  TPU
    has no native f64, so it keeps the f32 construction — a KNOWN
    LIMITATION: per-slot relative marginal error grows with E (~65% at
    E=1e5, >100% at E>=1e6; past E ~ 1e7 the beta cancellation loses all
    precision), so large-E TPU runs should build tables on the host CPU
    platform (``sampler_impl="host"``, or a CPU-backed device build) until
    a compensated-summation f32 pairing lands.  Builders enter this scope
    at the top level and trace entirely under it; it must not nest inside
    an outer non-x64 jit trace."""
    if jax.default_backend() == "tpu":
        return contextlib.nullcontext(), jnp.float32
    return jax.experimental.enable_x64(), jnp.float64


def build_alias_device(probs) -> tuple:
    """One jitted device computation: probs -> (threshold f32, alias i32).
    See :func:`_alias_pairing` for the construction and dtype policy."""
    scope, hi_dtype = _pairing_scope()
    probs = jnp.asarray(probs)
    with scope:
        return _alias_jit(probs, hi_dtype=hi_dtype)


def sample_alias(key, threshold: jax.Array, alias: jax.Array, shape):
    """Batched alias draws -> int32 indices of the given shape."""
    n = threshold.shape[0]
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, shape, 0, n)
    u = jax.random.uniform(k2, shape)
    return jnp.where(u < threshold[idx], idx, alias[idx]).astype(jnp.int32)


def _register_pytree(cls, data_fields, meta_fields):
    """Dataclass -> pytree with array leaves and static metadata.

    Uses register_pytree_node directly (register_dataclass signatures
    drift across the supported jax range)."""
    def flatten(obj):
        return (tuple(getattr(obj, f) for f in data_fields),
                tuple(getattr(obj, f) for f in meta_fields))

    def unflatten(meta, data):
        return cls(*data, *meta)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@dataclasses.dataclass
class EdgeSampler:
    """Directed edge list (src, dst) with alias table over edge weights.

    A registered pytree: ``src/dst/threshold/alias`` are leaves,
    ``n_edges`` is static metadata — pass whole samplers through
    ``jit``/``scan``/``shard_map``."""
    src: jax.Array          # (E,) int32
    dst: jax.Array          # (E,) int32
    threshold: jax.Array    # (E,) f32
    alias: jax.Array        # (E,) int32
    n_edges: int

    def sample(self, key, batch: int):
        e = sample_alias(key, self.threshold, self.alias, (batch,))
        return self.src[e], self.dst[e]


@dataclasses.dataclass
class NodeSampler:
    """Noise distribution over nodes, P_n(j) ∝ deg_j^power.  A registered
    pytree (``n_nodes`` static)."""
    threshold: jax.Array
    alias: jax.Array
    n_nodes: int

    def sample(self, key, shape):
        return sample_alias(key, self.threshold, self.alias, shape)


@dataclasses.dataclass
class ShardedEdgeSampler:
    """Per-shard edge alias tables with a shard-selection table on top.

    All per-shard leaves are stacked ``(P, E_loc)``; ``alias`` entries
    are LOCAL edge indices (each shard's table is closed over its own
    edges), ``src``/``dst`` hold GLOBAL node ids.  ``shard_threshold``/
    ``shard_alias`` is a (P,)-entry alias table over per-shard total
    edge masses, so a two-level draw is exactly ∝ the global w_ij.

    Registered pytree (``n_shards``/``n_edges`` static); duck-types
    :class:`EdgeSampler` for the layout engine.  At ``n_shards == 1``
    ``sample`` delegates to the flat sampler on table row 0 — the
    identical key stream, for bitwise trajectory parity."""
    src: jax.Array              # (P, E_loc) int32, global node ids
    dst: jax.Array              # (P, E_loc) int32
    threshold: jax.Array        # (P, E_loc) f32
    alias: jax.Array            # (P, E_loc) int32, LOCAL edge indices
    shard_threshold: jax.Array  # (P,) f32
    shard_alias: jax.Array      # (P,) int32
    n_shards: int
    n_edges: int                # total real (unpadded) directed edges

    def local(self, i: int = 0) -> EdgeSampler:
        """The flat per-shard sampler from stacked-table row ``i`` —
        what a shard_map body (leaves arriving as (1, E_loc) blocks)
        uses for stratified local sampling."""
        return EdgeSampler(self.src[i], self.dst[i], self.threshold[i],
                           self.alias[i], int(self.src.shape[1]))

    def sample(self, key, batch: int):
        if self.n_shards == 1:
            return self.local().sample(key, batch)
        k0, k1 = jax.random.split(key)
        s = sample_alias(k0, self.shard_threshold, self.shard_alias,
                         (batch,))
        e_loc = self.threshold.shape[1]
        k1a, k1b = jax.random.split(k1)
        idx = jax.random.randint(k1a, (batch,), 0, e_loc)
        u = jax.random.uniform(k1b, (batch,))
        e = jnp.where(u < self.threshold[s, idx], idx, self.alias[s, idx])
        return self.src[s, e], self.dst[s, e]


@dataclasses.dataclass
class ShardedNodeSampler:
    """Per-shard noise distribution P_n(j) ∝ deg_j^power over the
    contiguous-block row layout: local node ``l`` on shard ``s`` is
    global node ``s * n_loc + l`` (``runtime/sharding.py``).  Padded
    rows carry exactly-zero mass, so padded ids are never drawn."""
    threshold: jax.Array        # (P, n_loc) f32
    alias: jax.Array            # (P, n_loc) int32, LOCAL node indices
    shard_threshold: jax.Array  # (P,) f32
    shard_alias: jax.Array      # (P,) int32
    n_shards: int
    n_nodes: int                # real (unpadded) node count

    def sample(self, key, shape):
        if self.n_shards == 1:
            return sample_alias(key, self.threshold[0], self.alias[0],
                                shape)
        k0, k1 = jax.random.split(key)
        s = sample_alias(k0, self.shard_threshold, self.shard_alias, shape)
        n_loc = self.threshold.shape[1]
        k1a, k1b = jax.random.split(k1)
        idx = jax.random.randint(k1a, shape, 0, n_loc)
        u = jax.random.uniform(k1b, shape)
        l = jnp.where(u < self.threshold[s, idx], idx, self.alias[s, idx])
        return (s * n_loc + l).astype(jnp.int32)


_register_pytree(EdgeSampler, ("src", "dst", "threshold", "alias"),
                 ("n_edges",))
_register_pytree(NodeSampler, ("threshold", "alias"), ("n_nodes",))
_register_pytree(ShardedEdgeSampler,
                 ("src", "dst", "threshold", "alias", "shard_threshold",
                  "shard_alias"), ("n_shards", "n_edges"))
_register_pytree(ShardedNodeSampler,
                 ("threshold", "alias", "shard_threshold", "shard_alias"),
                 ("n_shards", "n_nodes"))


def _resolve_impl(impl: str) -> str:
    if impl not in ("auto", "device", "host"):
        raise ValueError(f"sampler impl must be auto|device|host: {impl!r}")
    return "device" if impl == "auto" else impl


@functools.partial(jax.jit, static_argnames=("hi_dtype",))
def _build_edge_sampler_device(knn_idx, weights, *,
                               hi_dtype=jnp.float32) -> EdgeSampler:
    N, K = knn_idx.shape
    src = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    dst = knn_idx.reshape(-1).astype(jnp.int32)
    thr, alias = _alias_pairing(weights.reshape(-1), hi_dtype=hi_dtype)
    return EdgeSampler(src, dst, thr, alias, N * K)


@functools.partial(jax.jit, static_argnames=("power", "hi_dtype"))
def _build_negative_sampler_device(knn_idx, weights, *, power: float,
                                   hi_dtype=jnp.float32) -> NodeSampler:
    N, _ = knn_idx.shape
    w = jnp.maximum(weights.astype(jnp.float32), 0.0)
    deg = jnp.sum(w, axis=1)                              # out-degree
    deg = deg.at[knn_idx.reshape(-1)].add(w.reshape(-1))  # + in-degree
    thr, alias = _alias_pairing(jnp.maximum(deg, 1e-12) ** power,
                                hi_dtype=hi_dtype)
    return NodeSampler(thr, alias, N)


def build_edge_sampler(knn_idx, weights, *, impl: str = "auto") -> EdgeSampler:
    """knn_idx/weights: (N, K) directed graph -> flat edge sampler.

    ``impl="device"`` (the ``"auto"`` default) builds the alias table
    on device in one jitted computation — the (N, K) graph never touches
    the host.  ``impl="host"`` is the numpy Vose oracle."""
    if _resolve_impl(impl) == "device":
        knn_idx, weights = jnp.asarray(knn_idx), jnp.asarray(weights)
        scope, hi_dtype = _pairing_scope()
        with scope:
            return _build_edge_sampler_device(knn_idx, weights,
                                              hi_dtype=hi_dtype)
    N, K = knn_idx.shape
    src = np.repeat(np.arange(N, dtype=np.int32), K)
    dst = np.asarray(knn_idx, np.int32).reshape(-1)
    w = np.asarray(weights, np.float64).reshape(-1)
    w = np.maximum(w, 0.0)
    if w.sum() <= 0:
        w = np.ones_like(w)
    thr, alias = build_alias(w)
    return EdgeSampler(jnp.asarray(src), jnp.asarray(dst),
                       jnp.asarray(thr), jnp.asarray(alias), len(src))


def build_negative_sampler(knn_idx, weights, *, power: float = 0.75,
                           impl: str = "auto") -> NodeSampler:
    """Weighted degree d_j = sum_i w_ij (directed, in+out), then ^power.
    ``impl`` as in :func:`build_edge_sampler`."""
    if _resolve_impl(impl) == "device":
        knn_idx, weights = jnp.asarray(knn_idx), jnp.asarray(weights)
        scope, hi_dtype = _pairing_scope()
        with scope:
            return _build_negative_sampler_device(knn_idx, weights,
                                                  power=power,
                                                  hi_dtype=hi_dtype)
    N, K = knn_idx.shape
    w = np.asarray(weights, np.float64)
    deg = w.sum(axis=1)                                   # out-degree
    np.add.at(deg, np.asarray(knn_idx, np.int64).reshape(-1),
              w.reshape(-1))                              # + in-degree
    deg = np.maximum(deg, 1e-12) ** power
    thr, alias = build_alias(deg)
    return NodeSampler(jnp.asarray(thr), jnp.asarray(alias), N)


def alias_marginals(threshold, alias) -> np.ndarray:
    """The exact per-index draw probability an alias table encodes.

    ``P(i) = (threshold_i + sum_j 1[alias_j = i] (1 - threshold_j)) / n``
    — the uniform slot draw keeps index ``i`` with its own threshold and
    collects every other slot's aliased remainder.  f64 host arithmetic:
    this is the oracle the sampler tests compare table constructions
    with, not a hot path."""
    thr = np.asarray(threshold, np.float64)
    ali = np.asarray(alias, np.int64)
    m = thr.copy()
    np.add.at(m, ali, 1.0 - thr)
    return m / thr.shape[0]


def edge_marginals(sampler) -> np.ndarray:
    """Global per-directed-edge draw probabilities, row-major ``(E,)``.

    Works for both :class:`EdgeSampler` and :class:`ShardedEdgeSampler`
    — for the sharded two-level draw the shard-selection marginal
    multiplies each shard's local table marginal, and the contiguous
    row layout makes shard-order concatenation global row-major order
    (padding rows sit at the end and are sliced off).  Samplers built
    from the same (knn_idx, weights) on ANY mesh agree up to table-
    construction rounding (exactly ``w_e / W`` in exact arithmetic) —
    the elastic-resume tests assert this across shard counts, and
    bitwise equality for same-mesh rebuilds."""
    if isinstance(sampler, ShardedEdgeSampler):
        P = sampler.n_shards
        if P == 1:
            return alias_marginals(sampler.threshold[0],
                                   sampler.alias[0])[:sampler.n_edges]
        shard_p = alias_marginals(sampler.shard_threshold,
                                  sampler.shard_alias)
        per = [shard_p[s] * alias_marginals(sampler.threshold[s],
                                            sampler.alias[s])
               for s in range(P)]
        return np.concatenate(per)[:sampler.n_edges]
    return alias_marginals(sampler.threshold,
                           sampler.alias)[:sampler.n_edges]


# ---------------------------------------------------------------------------
# Sharded build (1-D "data" mesh — same row layout as the KNN ring)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _make_sharded_builder_fn(mesh, axis: str, n_real: int, power: float,
                             hi_dtype):
    """jit'd shard_map body building one shard's edge + negative tables.

    Each shard pairs its OWN flat edge weights (local alias indices —
    valid by construction, unlike a slab cut out of a global table) and
    its own rows' degree^power masses; in-degree contributions landing
    on other shards' rows travel through one O(N) ``psum``.  Per-shard
    total masses come back stacked for the host-side (P,) shard table."""
    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[axis]

    def body(idx_loc, w_loc, ids_loc):
        n_loc, K = idx_loc.shape
        w = jnp.maximum(w_loc.astype(jnp.float32), 0.0)
        flat_w = w.reshape(-1)

        # --- edge table over this shard's own edges --------------------
        src = jnp.repeat(ids_loc.astype(jnp.int32), K)
        dst = idx_loc.reshape(-1).astype(jnp.int32)
        ethr, eali = _alias_pairing(flat_w, hi_dtype=hi_dtype)
        t_edge = jnp.sum(flat_w.astype(hi_dtype))

        # --- negative table over this shard's own rows -----------------
        # deg_j = out_j + in_j; in-degree scatters land anywhere, so each
        # shard scatters into an O(N) partial and one psum completes it
        out_deg = jnp.sum(w, axis=1)
        part = jnp.zeros((n_loc * n_shards,), jnp.float32)
        part = part.at[idx_loc.reshape(-1)].add(flat_w)
        in_deg = jax.lax.psum(part, axis)
        deg = out_deg + jax.lax.dynamic_slice_in_dim(in_deg, ids_loc[0],
                                                     n_loc)
        # exact zero for padded rows — a clamped epsilon^power would give
        # out-of-range node ids a small but nonzero draw probability
        mass = jnp.where(ids_loc < n_real,
                         jnp.maximum(deg, 1e-12) ** power, 0.0)
        nthr, nali = _alias_pairing(mass, hi_dtype=hi_dtype)
        t_node = jnp.sum(mass.astype(hi_dtype))
        return (src[None], dst[None], ethr[None], eali[None], t_edge[None],
                nthr[None], nali[None], t_node[None])

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis)),
        out_specs=(P(axis, None), P(axis, None), P(axis, None),
                   P(axis, None), P(axis), P(axis, None), P(axis, None),
                   P(axis)), check_vma=False)
    return jax.jit(fn)


def build_samplers_sharded(knn_idx, weights, *, power: float = 0.75,
                           mesh=None, axis: str = "data"):
    """(ShardedEdgeSampler, ShardedNodeSampler) built on the data mesh.

    Rows pad to a shard multiple with zero weight (padded edges/nodes
    get exactly-zero mass at every level, so they are never drawn); the
    graph never leaves the mesh — per-shard tables are built where the
    rows already live, and only the (P,) total-mass vectors reach the
    host-free top-level pairing for the shard-selection tables."""
    from repro.runtime import sharding as sh
    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(0)
    n_shards = mesh.shape[axis]
    N, K = knn_idx.shape
    idx_p = sh.pad_rows(jnp.asarray(knn_idx, jnp.int32), n_shards)
    w_p = sh.pad_rows(jnp.asarray(weights, jnp.float32), n_shards)
    ids = jnp.arange(idx_p.shape[0], dtype=jnp.int32)
    scope, hi_dtype = _pairing_scope()
    with scope:
        fn = _make_sharded_builder_fn(mesh, axis, N, float(power), hi_dtype)
        (src, dst, ethr, eali, t_edge,
         nthr, nali, t_node) = fn(idx_p, w_p, ids)
        sthr_e, sali_e = _alias_jit(t_edge, hi_dtype=hi_dtype)
        sthr_n, sali_n = _alias_jit(t_node, hi_dtype=hi_dtype)
    edge_s = ShardedEdgeSampler(src, dst, ethr, eali, sthr_e, sali_e,
                                n_shards, N * K)
    node_s = ShardedNodeSampler(nthr, nali, sthr_n, sali_n, n_shards, N)
    return edge_s, node_s
