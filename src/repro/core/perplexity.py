"""Edge weights for the KNN graph (paper Eqn 1-2, same scheme as t-SNE).

sigma_i is calibrated per node so the conditional distribution p_{.|i} over
its K neighbors has a target perplexity u: a fixed-iteration vectorized
bisection on beta_i = 1/(2 sigma_i^2) — all N rows in parallel (the paper's
sequential per-point search is embarrassingly parallel).

Symmetrization w_ij = (p_{j|i} + p_{i|j}) / 2N needs the reverse weight
p_{i|j}: for each directed edge (i, j) we look up i inside knn(j) — a tiled
(T, K, K) gather + compare.  The tile loop is a ``lax.scan`` inside ONE
module-level jit (``_symmetrize_scan``), so ``symmetrize`` compiles once
per (N, K, tile) and never re-traces per call or per tile — the earlier
form re-created a ``jax.jit`` wrapper on every call and dispatched one
device round trip per tile.

Distributed mode: both stages also come as shard_map drivers over the
1-D "data" mesh (``calibrate_p_sharded`` / ``symmetrize_sharded`` /
``edge_weights_sharded``), sharing the row layout of the sharded KNN
ring (``runtime/sharding.py::rows_per_shard``).  Calibration is
embarrassingly row-parallel (every op in ``_calibrate_rows`` is
row-local), so sharding it is a pure row split.  Symmetrization needs
the reverse lookup p_{i|j}, i.e. other shards' rows: the (N, K) graph
and p table are exchanged with ``all_gather(tiled=True)`` — the same
output-sized exchange ``neighbor_explore.sharded_explore_round``
performs — while the (T, K, K) reverse-gather temporaries stay bounded
by the row tile, never O(N*K*K).  Both sharded stages run the identical
per-row arithmetic as their single-device forms, so results are
**bitwise equal** to the single-device oracle (asserted by
``tests/test_graph_sharded.py`` and the hypothesis property test).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.runtime import sharding as sh
from repro.runtime.compat import shard_map


def _calibrate_rows(knn_sqdist: jax.Array, perplexity, iters: int):
    """Row-local bisection body shared by the single-device jit and the
    shard_map driver (each shard calls this on its own row block —
    every op below reduces over axis=1 only, so a row's result is
    independent of which rows it is blocked with)."""
    d2 = knn_sqdist.astype(jnp.float32)
    d2 = d2 - d2.min(axis=1, keepdims=True)               # stability shift
    target = jnp.log(perplexity)                          # nats

    def entropy(beta):
        logits = -beta[:, None] * d2
        logz = jax.nn.logsumexp(logits, axis=1)
        p = jnp.exp(logits - logz[:, None])
        return logz + beta * jnp.sum(p * d2, axis=1), p

    def body(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        h, _ = entropy(mid)
        too_flat = h > target                              # entropy high -> increase beta
        lo = jnp.where(too_flat, mid, lo)
        hi = jnp.where(too_flat, hi, mid)
        return (lo, hi), None

    n = d2.shape[0]
    lo = jnp.zeros((n,), jnp.float32)
    hi = jnp.full((n,), 1e5, jnp.float32) / (
        jnp.maximum(jnp.mean(d2, axis=1), 1e-8))
    (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=iters)
    _, p = entropy(0.5 * (lo + hi))
    return p


@functools.partial(jax.jit, static_argnames=("iters",))
def calibrate_p(knn_sqdist: jax.Array, perplexity: float,
                iters: int = 64) -> jax.Array:
    """Row-stochastic p_{j|i} (N, K) at the target perplexity (Eqn 1)."""
    return _calibrate_rows(knn_sqdist, perplexity, iters)


def _reverse_p_tile(knn_idx, p, rows):
    """p_{i|j} for each edge (i, j=knn[i][k]) in a tile of rows."""
    nbrs = knn_idx[rows]                                  # (T, K)
    back = knn_idx[nbrs]                                  # (T, K, K) = knn(j)
    hit = back == rows[:, None, None]                     # where knn(j) == i
    pj = p[nbrs]                                          # (T, K, K) = p_{.|j}
    return jnp.sum(jnp.where(hit, pj, 0.0), axis=-1)      # (T, K)


def _reverse_rows_scan(knn_idx, p, rows, *, tile: int):
    """Reverse weights p_{i|j} for ``rows``, scanned in tiles of ``tile``.

    Rows are padded to a whole number of tiles by repeating the last row
    index; padded outputs are sliced off.  Each real row sees the
    identical per-row gather/compare/sum regardless of the tile grouping
    or which rows it shares a call with — the bitwise-equality basis for
    the sharded driver, whose shards run this very function on their own
    row blocks against the gathered table."""
    n_rows = rows.shape[0]
    K = knn_idx.shape[1]
    n_tiles = -(-n_rows // tile)
    pad = n_tiles * tile - n_rows
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.broadcast_to(rows[-1:], (pad,))])

    def body(_, rows_t):
        return None, _reverse_p_tile(knn_idx, p, rows_t)

    _, rev = jax.lax.scan(body, None, rows.reshape(n_tiles, tile))
    return rev.reshape(n_tiles * tile, K)[:n_rows]


@functools.partial(jax.jit, static_argnames=("tile",))
def _symmetrize_scan(knn_idx: jax.Array, p: jax.Array, *,
                     tile: int) -> jax.Array:
    """One compiled computation: scan `_reverse_p_tile` over row tiles."""
    N = knn_idx.shape[0]
    rows = jnp.arange(N, dtype=jnp.int32)
    rev = _reverse_rows_scan(knn_idx, p, rows, tile=tile)
    return (p + rev) / (2.0 * N)


def symmetrize(knn_idx: jax.Array, p: jax.Array, *,
               tile: int | None = None) -> jax.Array:
    """w_ij = (p_{j|i} + p_{i|j}) / (2N) per directed edge slot (Eqn 2).

    ``tile`` (row-tile of the scanned reverse gather) defaults to the
    autotuner's choice — the reverse weights are identical for any tile
    grouping (see ``_reverse_rows_scan``), so this is purely a
    performance knob.  ``AUTOTUNE=off`` reproduces the legacy 4096."""
    if tile is None:
        from repro.runtime import autotune
        N, K = knn_idx.shape
        tile = autotune.get("symmetrize", dict(n=N, k=K),
                            autotune.legacy_default("symmetrize"))["tile"]
    return _symmetrize_scan(knn_idx, p, tile=int(min(tile, knn_idx.shape[0])))


def edge_weights(knn_idx, knn_sqdist, perplexity: float, *,
                 iters: int = 64) -> jax.Array:
    p = calibrate_p(knn_sqdist, perplexity, iters=iters)
    return symmetrize(knn_idx, p)


def perplexity_of(p: jax.Array) -> jax.Array:
    """Realized perplexity per row (for validation)."""
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0), axis=1)
    return jnp.exp(h)


# ---------------------------------------------------------------------------
# Sharded drivers (1-D "data" mesh — same row layout as the KNN ring)
# ---------------------------------------------------------------------------

def _default_mesh(mesh, cfg_shards: int = 0):
    if mesh is not None:
        return mesh
    from repro.launch.mesh import make_data_mesh
    return make_data_mesh(cfg_shards)


@functools.lru_cache(maxsize=32)
def _make_calibrate_sharded(mesh, axis: str, iters: int):
    """jit'd shard_map row-parallel calibration (cached per mesh/iters —
    shapes re-specialize inside the jit cache)."""
    from jax.sharding import PartitionSpec as P

    def body(d2_loc, perp):
        return _calibrate_rows(d2_loc, perp, iters)

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis, None), P()),
                   out_specs=P(axis, None), check_vma=False)
    return jax.jit(fn)


def calibrate_p_sharded(knn_sqdist, perplexity: float, *, iters: int = 64,
                        mesh=None, axis: str = "data",
                        fault=None) -> jax.Array:
    """Row-parallel :func:`calibrate_p` under shard_map.

    Rows pad to a shard multiple (zero rows bisect harmlessly and are
    sliced off); every surviving row is bitwise-equal to the
    single-device result because the body is row-local.  ``fault``
    fires the per-shard ``calibrate_shard:<s>`` sites before the
    dispatch (shard faults -> ``ShardFailedError``, stage
    ``"calibrate"``)."""
    mesh = _default_mesh(mesh)
    n_shards = mesh.shape[axis]
    N = knn_sqdist.shape[0]
    d2 = sh.pad_rows(jnp.asarray(knn_sqdist), n_shards)
    fn = _make_calibrate_sharded(mesh, axis, iters)
    if fault is not None:
        from repro.runtime.fault_tolerance import fire_per_shard
        fire_per_shard(fault, "calibrate_shard", n_shards, stage="calibrate")
    return fn(d2, jnp.float32(perplexity))[:N]


@functools.lru_cache(maxsize=32)
def _make_symmetrize_sharded(mesh, axis: str, n_real: int, tile: int):
    from jax.sharding import PartitionSpec as P

    def body(idx_loc, p_loc, rows_loc):
        # the (Np, K) graph + p table are output-sized — the same
        # exchange sharded_explore_round performs; the (T, K, K)
        # reverse-gather temporaries stay bounded by the row tile
        g_idx = jax.lax.all_gather(idx_loc, axis, tiled=True)
        g_p = jax.lax.all_gather(p_loc, axis, tiled=True)
        rev = _reverse_rows_scan(g_idx, g_p, rows_loc, tile=tile)
        return (p_loc + rev) / (2.0 * n_real)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None), P(axis)),
                   out_specs=P(axis, None), check_vma=False)
    return jax.jit(fn)


def symmetrize_sharded(knn_idx, p, *, tile: int = 4096, mesh=None,
                       axis: str = "data", fault=None) -> jax.Array:
    """Sharded :func:`symmetrize`: each shard computes its own rows'
    reverse weights against the all-gathered graph.

    Padded graph rows hold index 0 with zero p — no real row ever
    gathers from them (real knn entries are < N), so per-row results
    are bitwise-equal to the single-device scan.  ``fault`` fires the
    per-shard ``symmetrize_exchange:<s>`` sites before the all-gather
    dispatch (shard faults -> ``ShardFailedError``, stage
    ``"symmetrize"``)."""
    mesh = _default_mesh(mesh)
    n_shards = mesh.shape[axis]
    N = knn_idx.shape[0]
    idx_p = sh.pad_rows(jnp.asarray(knn_idx, jnp.int32), n_shards)
    p_p = sh.pad_rows(jnp.asarray(p, jnp.float32), n_shards)
    rows = jnp.arange(idx_p.shape[0], dtype=jnp.int32)
    tile = int(min(tile, sh.rows_per_shard(N, n_shards)))
    fn = _make_symmetrize_sharded(mesh, axis, N, tile)
    if fault is not None:
        from repro.runtime.fault_tolerance import fire_per_shard
        fire_per_shard(fault, "symmetrize_exchange", n_shards,
                       stage="symmetrize")
    return fn(idx_p, p_p, rows)[:N]


def edge_weights_sharded(knn_idx, knn_sqdist, perplexity: float, *,
                         iters: int = 64, mesh=None,
                         axis: str = "data", fault=None) -> jax.Array:
    """Sharded :func:`edge_weights`: calibration + symmetrization on the
    data mesh, bitwise-equal to the single-device composition.
    ``fault`` threads into both sharded stages' per-shard sites."""
    mesh = _default_mesh(mesh)
    p = calibrate_p_sharded(knn_sqdist, perplexity, iters=iters, mesh=mesh,
                            axis=axis, fault=fault)
    return symmetrize_sharded(knn_idx, p, mesh=mesh, axis=axis, fault=fault)
