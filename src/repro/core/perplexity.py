"""Edge weights for the KNN graph (paper Eqn 1-2, same scheme as t-SNE).

sigma_i is calibrated per node so the conditional distribution p_{.|i} over
its K neighbors has a target perplexity u: a fixed-iteration vectorized
bisection on beta_i = 1/(2 sigma_i^2) — all N rows in parallel (the paper's
sequential per-point search is embarrassingly parallel).

Symmetrization w_ij = (p_{j|i} + p_{i|j}) / 2N needs the reverse weight
p_{i|j}: for each directed edge (i, j) we look up i inside knn(j) — a tiled
(T, K, K) gather + compare.  The tile loop is a ``lax.scan`` inside ONE
module-level jit (``_symmetrize_scan``), so ``symmetrize`` compiles once
per (N, K, tile) and never re-traces per call or per tile — the earlier
form re-created a ``jax.jit`` wrapper on every call and dispatched one
device round trip per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("iters",))
def calibrate_p(knn_sqdist: jax.Array, perplexity: float,
                iters: int = 64) -> jax.Array:
    """Row-stochastic p_{j|i} (N, K) at the target perplexity (Eqn 1)."""
    d2 = knn_sqdist.astype(jnp.float32)
    d2 = d2 - d2.min(axis=1, keepdims=True)               # stability shift
    target = jnp.log(perplexity)                          # nats

    def entropy(beta):
        logits = -beta[:, None] * d2
        logz = jax.nn.logsumexp(logits, axis=1)
        p = jnp.exp(logits - logz[:, None])
        return logz + beta * jnp.sum(p * d2, axis=1), p

    def body(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        h, _ = entropy(mid)
        too_flat = h > target                              # entropy high -> increase beta
        lo = jnp.where(too_flat, mid, lo)
        hi = jnp.where(too_flat, hi, mid)
        return (lo, hi), None

    n = d2.shape[0]
    lo = jnp.zeros((n,), jnp.float32)
    hi = jnp.full((n,), 1e5, jnp.float32) / (
        jnp.maximum(jnp.mean(d2, axis=1), 1e-8))
    (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=iters)
    _, p = entropy(0.5 * (lo + hi))
    return p


def _reverse_p_tile(knn_idx, p, rows):
    """p_{i|j} for each edge (i, j=knn[i][k]) in a tile of rows."""
    nbrs = knn_idx[rows]                                  # (T, K)
    back = knn_idx[nbrs]                                  # (T, K, K) = knn(j)
    hit = back == rows[:, None, None]                     # where knn(j) == i
    pj = p[nbrs]                                          # (T, K, K) = p_{.|j}
    return jnp.sum(jnp.where(hit, pj, 0.0), axis=-1)      # (T, K)


@functools.partial(jax.jit, static_argnames=("tile",))
def _symmetrize_scan(knn_idx: jax.Array, p: jax.Array, *,
                     tile: int) -> jax.Array:
    """One compiled computation: scan `_reverse_p_tile` over row tiles.

    Rows are padded to a whole number of tiles with clamped (N-1) indices
    whose outputs are sliced off — every real row sees the identical
    per-row gather/compare/sum the unpadded tile would produce."""
    N, K = knn_idx.shape
    n_tiles = -(-N // tile)
    rows = jnp.minimum(jnp.arange(n_tiles * tile, dtype=jnp.int32), N - 1)

    def body(_, rows_t):
        return None, _reverse_p_tile(knn_idx, p, rows_t)

    _, rev = jax.lax.scan(body, None, rows.reshape(n_tiles, tile))
    rev = rev.reshape(n_tiles * tile, K)[:N]
    return (p + rev) / (2.0 * N)


def symmetrize(knn_idx: jax.Array, p: jax.Array, *,
               tile: int = 4096) -> jax.Array:
    """w_ij = (p_{j|i} + p_{i|j}) / (2N) per directed edge slot (Eqn 2)."""
    return _symmetrize_scan(knn_idx, p, tile=int(min(tile, knn_idx.shape[0])))


def edge_weights(knn_idx, knn_sqdist, perplexity: float, *,
                 iters: int = 64) -> jax.Array:
    p = calibrate_p(knn_sqdist, perplexity, iters=iters)
    return symmetrize(knn_idx, p)


def perplexity_of(p: jax.Array) -> jax.Array:
    """Realized perplexity per row (for validation)."""
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0), axis=1)
    return jnp.exp(h)
