"""Vantage-point tree KNN (Yianilos 1993) — the t-SNE baseline in Fig 2.

Host-side numpy implementation (a pointer-chasing metric tree is a CPU
algorithm; it exists here as the *baseline the paper beats*, not as a TPU
path — see DESIGN.md).  Build: random vantage point, median split on
distance.  Query: best-first descent with triangle-inequality pruning and a
``tau`` search radius; an ``eps`` slack turns it into the approximate
variant used for the time/recall trade-off curve.
"""
from __future__ import annotations

import heapq

import numpy as np


class VPTree:
    __slots__ = ("point", "index", "mu", "inside", "outside")

    def __init__(self, point, index, mu, inside, outside):
        self.point = point
        self.index = index
        self.mu = mu
        self.inside = inside
        self.outside = outside


def build_vptree(x: np.ndarray, idx: np.ndarray = None,
                 rng: np.random.Generator = None, leaf: int = 1):
    if rng is None:
        rng = np.random.default_rng(0)
    if idx is None:
        idx = np.arange(x.shape[0])
    if len(idx) == 0:
        return None
    vp_pos = rng.integers(len(idx))
    vp = idx[vp_pos]
    rest = np.delete(idx, vp_pos)
    if len(rest) == 0:
        return VPTree(x[vp], vp, 0.0, None, None)
    d = np.linalg.norm(x[rest] - x[vp], axis=1)
    mu = float(np.median(d))
    inside = rest[d < mu]
    outside = rest[d >= mu]
    return VPTree(x[vp], vp, mu,
                  build_vptree(x, inside, rng, leaf),
                  build_vptree(x, outside, rng, leaf))


def query_vptree(root: VPTree, q: np.ndarray, k: int,
                 eps: float = 0.0) -> np.ndarray:
    """k nearest indices to q.  eps>0 prunes more aggressively (approx)."""
    heap: list = []           # max-heap of (-dist, idx)
    tau = [np.inf]

    def search(node):
        if node is None:
            return
        d = float(np.linalg.norm(q - node.point))
        if d < tau[0]:
            if len(heap) == k:
                heapq.heappop(heap)
            heapq.heappush(heap, (-d, node.index))
            if len(heap) == k:
                tau[0] = -heap[0][0]
        shrink = 1.0 + eps
        if d < node.mu:
            if d - tau[0] / shrink < node.mu:
                search(node.inside)
            if d + tau[0] / shrink >= node.mu:
                search(node.outside)
        else:
            if d + tau[0] / shrink >= node.mu:
                search(node.outside)
            if d - tau[0] / shrink < node.mu:
                search(node.inside)

    search(root)
    out = sorted(((-nd, i) for nd, i in heap))
    return np.array([i for _, i in out], np.int32)


def vptree_knn(x: np.ndarray, k: int, eps: float = 0.0,
               n_query: int = None) -> np.ndarray:
    """(n_query, k) self-excluding KNN via one vp-tree."""
    import sys
    sys.setrecursionlimit(100000)
    x = np.asarray(x, np.float32)
    root = build_vptree(x)
    n = x.shape[0] if n_query is None else min(n_query, x.shape[0])
    out = np.zeros((n, k), np.int32)
    for i in range(n):
        nn = query_vptree(root, x[i], k + 1, eps=eps)
        nn = nn[nn != i][:k]
        out[i, :len(nn)] = nn
    return out
