"""Exact symmetric-SNE and t-SNE layout baselines (Fig 5 / Table 2 arms).

The paper's comparison uses Barnes-Hut acceleration to reach millions of
points; at this container's benchmark scale (N <= ~10k) the exact O(N^2)
gradient is both simpler and a *stronger* baseline (no tree-approximation
error), so quality comparisons are conservative.  Both run full-batch
gradient descent with momentum + early exaggeration per van der Maaten's
settings; both consume the same LargeVis-built KNN graph (paper §4.3:
"All visualization algorithms use the same KNN graphs ... as input").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _p_matrix(knn_idx, weights, n: int) -> jax.Array:
    """Dense symmetric P from the sparse weighted KNN graph."""
    w = weights / jnp.maximum(weights.sum(), 1e-12)
    P = jnp.zeros((n, n), jnp.float32)
    rows = jnp.repeat(jnp.arange(n), knn_idx.shape[1])
    P = P.at[rows, knn_idx.reshape(-1)].add(w.reshape(-1))
    P = 0.5 * (P + P.T)
    return jnp.maximum(P / jnp.maximum(P.sum(), 1e-12), 1e-12)


@functools.partial(jax.jit, static_argnames=("student_t",))
def _grad(y, P, student_t: bool):
    d2 = jnp.sum((y[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    if student_t:
        num = 1.0 / (1.0 + d2)
    else:
        num = jnp.exp(-d2)
    num = num.at[jnp.diag_indices(y.shape[0])].set(0.0)
    Q = jnp.maximum(num / jnp.maximum(num.sum(), 1e-12), 1e-12)
    PQ = P - Q
    if student_t:
        W = PQ * num
    else:
        W = PQ
    g = 4.0 * (jnp.sum(W, axis=1, keepdims=True) * y - W @ y)
    kl = jnp.sum(P * (jnp.log(P) - jnp.log(Q)))
    return g, kl


def tsne_layout(knn_idx, weights, *, n_iter: int = 1000, lr: float = 200.0,
                momentum: float = 0.8, early_exag: float = 12.0,
                exag_iters: int = 250, student_t: bool = True, key=None,
                out_dim: int = 2):
    """Returns (y (N,2), kl_history).  student_t=False => symmetric SNE."""
    n = knn_idx.shape[0]
    if key is None:
        key = jax.random.key(0)
    P = _p_matrix(knn_idx, weights, n)
    y = jax.random.normal(key, (n, out_dim)) * 1e-4
    v = jnp.zeros_like(y)
    kls = []
    for it in range(n_iter):
        Pe = P * early_exag if it < exag_iters else P
        g, kl = _grad(y, Pe, student_t)
        mom = 0.5 if it < exag_iters else momentum
        v = mom * v - lr * g
        y = y + v
        y = y - y.mean(axis=0)
        if it % 100 == 0:
            kls.append(float(kl))
    return y, kls
