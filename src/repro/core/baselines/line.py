"""LINE first-order baseline (Tang et al. 2015) learned directly in 2D.

The paper shows embedding objectives are NOT layout objectives (Fig 5:
'the performance of LINE is very bad' as a visualizer) — this baseline
exists to reproduce that negative result.  First-order proximity:
P(e_ij) = sigmoid(y_i . y_j), same edge/negative samplers as LargeVis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sampler import EdgeSampler, NodeSampler


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("n_negatives", "batch"))
def line_step(y, key, t_frac, *, edge_sampler: EdgeSampler,
              neg_sampler: NodeSampler, n_negatives: int, batch: int,
              rho0: float = 0.025, clip: float = 5.0):
    ke, kn = jax.random.split(key)
    i, j = edge_sampler.sample(ke, batch)
    negs = neg_sampler.sample(kn, (batch, n_negatives))

    def loss(y):
        yi, yj, yn = y[i], y[j], y[negs]
        pos = -jax.nn.log_sigmoid(jnp.sum(yi * yj, -1))
        neg = -jax.nn.log_sigmoid(-jnp.einsum("bd,bmd->bm", yi, yn))
        return jnp.sum(pos) + jnp.sum(neg)

    g = jax.grad(loss)(y)
    g = jnp.clip(g, -clip, clip)
    lr = rho0 * jnp.maximum(1.0 - t_frac, 1e-4)
    return y - lr * g


def line_layout(key, edge_sampler: EdgeSampler, neg_sampler: NodeSampler,
                n_nodes: int, *, out_dim: int = 2, samples_per_node: int = 1000,
                n_negatives: int = 5, batch: int = 4096, rho0: float = 0.025):
    ky, kr = jax.random.split(key)
    y = jax.random.normal(ky, (n_nodes, out_dim)) * 1e-3
    total = samples_per_node * n_nodes
    steps = max(1, total // batch)
    for t in range(steps):
        y = line_step(y, jax.random.fold_in(kr, t), jnp.float32(t / steps),
                      edge_sampler=edge_sampler, neg_sampler=neg_sampler,
                      n_negatives=n_negatives, batch=batch, rho0=rho0)
    return y
