"""NN-Descent baseline (Dong et al. 2011): neighbor exploring from a RANDOM
initial graph (no projection forest).  This is the 'neighbor exploring
alone' arm of the paper's Fig 2 comparison; LargeVis = forest init + the
same exploring machinery."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.neighbor_explore import neighbor_explore


def random_knn_init(x, k: int, key):
    """Uniform random neighbor ids + their true distances."""
    n = x.shape[0]
    idx = jax.random.randint(key, (n, k), 0, n)
    diff = (x[idx] - x[:, None, :]).astype(jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)
    return idx.astype(jnp.int32), dist


def nn_descent(x, k: int, *, iters: int = 4, key=None, sample: int = 0):
    if key is None:
        key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    idx, dist = random_knn_init(x, k, k1)
    return neighbor_explore(x, idx, dist, iters=iters, sample=sample, key=k2)
