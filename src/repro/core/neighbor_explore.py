"""Neighbor exploring (paper §3.1 step 3): "a neighbor of my neighbor is
also likely to be my neighbor."

Per iteration, each node's candidates are its neighbors' neighbors
(old_knn(old_knn(i)), Algo 1's double loop) plus its *reverse* neighbors
(nodes that list i — NN-Descent's bidirectional exploration; the paper's
C++ reference also builds reverse edges before exploring).  The per-node
max-heap becomes a batched dedup'd top-k.  Work is tiled over nodes to
bound the gather footprint; ``sample`` can cap candidate columns (0 = use
all K^2, the paper-faithful default).  Each iteration is ONE jitted
dispatch (``_explore_round``): the reverse pass and a ``lax.map`` over
row tiles live in the same program — the old driver paid n_tiles + 1
host dispatches per iteration.  Unlike the tile-structured distance
paths (brute force / windows / ring, which stream through
``kernels.ops.topk_sqdist``), the candidate fill here gathers per-row
id lists with heavy within-row duplication, so the merge stays on the
argsort-dedup ``merge_candidates``.

``sharded_explore_round`` is the multi-device tile driver: it runs INSIDE
a shard_map body (one tile of rows per shard), exchanges the KNN graph
across shards (which is how each shard learns its rows' reverse
neighbors), and fills candidate distances by streaming the point shards
around the device ring — no shard ever holds more than its own (N/P, d)
slab of points plus one in-flight remote slab.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import knn as knn_lib


def reverse_neighbors(knn_idx: jax.Array, r_cap: int) -> jax.Array:
    """(N, r_cap) reverse adjacency, padded with self-index (made inert by
    merge_candidates' self-suppression).  Slot assignment via sorted
    scatter: edges sorted by destination, rank within segment."""
    N, K = knn_idx.shape
    dst = knn_idx.reshape(-1)
    src = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    order = jnp.argsort(dst)
    dst_s, src_s = dst[order], src[order]
    seg_start = jnp.searchsorted(dst_s, jnp.arange(N))
    rank = jnp.arange(N * K) - seg_start[dst_s]
    keep = rank < r_cap
    out = jnp.full((N, r_cap), -1, jnp.int32)
    out = out.at[dst_s, jnp.clip(rank, 0, r_cap - 1)].set(
        jnp.where(keep, src_s, -1))
    # replace -1 padding with the row's own index (self -> suppressed)
    rows = jnp.arange(N, dtype=jnp.int32)[:, None]
    return jnp.where(out < 0, rows, out)


def _tile_explore(x, knn_idx, knn_dist, rev, rows, key, sample: int):
    """One tile of nodes; returns merged (idx (T,K), dist (T,K))."""
    T = rows.shape[0]
    K = knn_idx.shape[1]
    nbrs = knn_idx[rows]                                  # (T, K)
    fwd = knn_idx[nbrs].reshape(T, K * K)                 # neighbors' nbrs
    cand = jnp.concatenate([fwd, rev[rows]], axis=1)
    if sample and sample < cand.shape[1]:
        cols = jax.random.randint(key, (T, sample), 0, cand.shape[1])
        cand = jnp.take_along_axis(cand, cols, axis=1)
    xc = x[cand]                                          # (T, C, d)
    xa = x[rows][:, None, :]
    diff = (xc - xa).astype(jnp.float32)
    cd = jnp.sum(diff * diff, axis=-1)                    # (T, C)
    ids = jnp.concatenate([nbrs, cand], axis=1)
    ds = jnp.concatenate([knn_dist[rows], cd], axis=1)
    return knn_lib.merge_candidates(ids, ds, K, self_idx=rows)


def sharded_explore_round(x_loc, ids_loc, knn_idx_loc, knn_dist_loc, *,
                          axis: str, n_shards: int, n_real: int,
                          key=None, sample: int = 0, r_cap: int = 0,
                          tile: int = 0):
    """One neighbor-exploring round for this shard's tile of rows.

    Must be called inside a shard_map body over mesh axis ``axis``.

    x_loc        (n_loc, d)   this shard's point slab
    ids_loc      (n_loc,)     global ids of the slab (contiguous range)
    knn_idx_loc  (n_loc, K)   current graph rows (global ids)
    knn_dist_loc (n_loc, K)

    The graph (N*K ints — output-sized, NOT a candidate buffer) is
    all-gathered so each shard can read its rows' forward and reverse
    neighbors; candidate *coordinates* are never gathered: distances are
    filled over ``n_shards`` ring steps, each touching only the remote
    (n_loc, d) slab currently held.  Within each ring step the
    coordinate gather runs over row tiles (``lax.map``, same element
    budget as single-device ``neighbor_explore``) so the (T, C, d)
    gather temporary stays bounded by the tile — without this the step
    materialized an O(n_loc * K^2 * d) buffer, ~15 GB at the paper's
    N=1M on one shard (the blow-up ``tests/memcheck.py`` now forbids).
    Candidate *id/distance* tables stay whole-slab: (n_loc, C) working
    sets are the per-shard output-order footprint the ring design
    budgets for.  Returns merged (idx, dist) for the local rows.
    """
    n_loc, K = knn_idx_loc.shape
    r_cap = r_cap or K
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    # --- candidate ids from the exchanged graph -------------------------
    g_idx = jax.lax.all_gather(knn_idx_loc, axis, tiled=True)   # (Np, K)
    rev = reverse_neighbors(g_idx, r_cap)                       # (Np, r_cap)
    rev_loc = jax.lax.dynamic_slice_in_dim(rev, ids_loc[0], n_loc)
    fwd = g_idx[knn_idx_loc].reshape(n_loc, K * K)
    cand = jnp.concatenate([fwd, rev_loc], axis=1)              # (n_loc, C)
    if sample and sample < cand.shape[1]:
        cols = jax.random.randint(key, (n_loc, sample), 0, cand.shape[1])
        cand = jnp.take_along_axis(cand, cols, axis=1)
    cand = jnp.where(cand >= n_real, ids_loc[:, None], cand)    # pad -> self

    # --- ring pass: fill candidate distances from streamed slabs --------
    C = cand.shape[1]
    d = x_loc.shape[1]
    budget = 64 * (1 << 20)                  # ~256 MB of f32 per gather
    T = int(tile) or max(16, min(n_loc, budget // max(1, C * d)))
    n_tiles = -(-n_loc // T)
    pad = n_tiles * T - n_loc
    if pad:
        cand_p = jnp.concatenate([cand, jnp.zeros((pad, C), cand.dtype)])
        x_p = jnp.concatenate([x_loc, jnp.zeros((pad, d), x_loc.dtype)])
    else:
        cand_p, x_p = cand, x_loc
    cand_t = cand_p.reshape(n_tiles, T, C)
    x_t = x_p.reshape(n_tiles, T, d)

    def ring_step(_, carry):
        cd, rx, roff = carry

        def one(args):
            cand_b, cd_b, x_b = args
            rel = cand_b - roff
            in_rng = (rel >= 0) & (rel < n_loc)
            xc = rx[jnp.clip(rel, 0, n_loc - 1)]                # (T, C, d)
            diff = (xc - x_b[:, None, :]).astype(jnp.float32)
            dd = jnp.sum(diff * diff, axis=-1)
            return jnp.where(in_rng, dd, cd_b)

        cd = jax.lax.map(one, (cand_t, cd, x_t))
        rx = jax.lax.ppermute(rx, axis, perm)
        roff = jax.lax.ppermute(roff, axis, perm)
        return cd, rx, roff

    cd0 = jnp.full((n_tiles, T, C), knn_lib.INF, jnp.float32)
    cd, _, _ = jax.lax.fori_loop(
        0, n_shards, ring_step, (cd0, x_loc, ids_loc[0]))
    cd = cd.reshape(n_tiles * T, C)[:n_loc]

    ids = jnp.concatenate([knn_idx_loc, cand], axis=1)
    ds = jnp.concatenate([knn_dist_loc, cd], axis=1)
    return knn_lib.merge_candidates(ids, ds, K, self_idx=ids_loc)


@functools.partial(jax.jit, static_argnames=("sample", "tile", "r_cap"))
def _explore_round(x, knn_idx, knn_dist, ikey, *, sample: int, tile: int,
                   r_cap: int):
    """One full exploring iteration as ONE device dispatch.

    ``reverse_neighbors`` is hoisted inside (it reads the same graph every
    tile), and the row tiles run under ``jax.lax.map`` — the
    ``brute_force_knn`` pattern — instead of the old per-tile Python loop
    that paid ``n_tiles`` dispatches (plus one for the reverse pass) per
    iteration.  Rows pad to a tile multiple with row 0 (same key stream
    and padding as the old loop, so trajectories are unchanged); padded
    rows never survive the final slice.
    """
    N, K = knn_idx.shape
    n_tiles = -(-N // tile)
    rev = reverse_neighbors(knn_idx, r_cap)
    rows = jnp.arange(N, dtype=jnp.int32)
    rows = jnp.concatenate(
        [rows, jnp.zeros((n_tiles * tile - N,), jnp.int32)])
    tkeys = jax.vmap(lambda t: jax.random.fold_in(ikey, t))(
        jnp.arange(n_tiles))

    def one(args):
        r, tk = args
        return _tile_explore(x, knn_idx, knn_dist, rev, r, tk, sample)

    ti, td = jax.lax.map(one, (rows.reshape(n_tiles, tile), tkeys))
    return ti.reshape(-1, K)[:N], td.reshape(-1, K)[:N]


@functools.partial(jax.jit, static_argnames=("sample", "tile", "r_cap"))
def _explore_rows_round(x, knn_idx, knn_dist, rows, ikey, *, sample: int,
                        tile: int, r_cap: int):
    """One exploring iteration over a SUBSET of rows (incremental graph
    maintenance after ``transform.knn_insert``): the same per-tile body as
    ``_explore_round``, but only ``rows`` are explored and written back —
    O(len(rows)) work against the full graph's reverse adjacency.  Rows
    pad to a tile multiple by repeating the first row; padded results are
    sliced off before the scatter."""
    _, K = knn_idx.shape
    R = rows.shape[0]
    n_tiles = -(-R // tile)
    rev = reverse_neighbors(knn_idx, r_cap)
    rows_p = jnp.concatenate(
        [rows, jnp.broadcast_to(rows[:1], (n_tiles * tile - R,))])
    tkeys = jax.vmap(lambda t: jax.random.fold_in(ikey, t))(
        jnp.arange(n_tiles))

    def one(args):
        r, tk = args
        return _tile_explore(x, knn_idx, knn_dist, rev, r, tk, sample)

    ti, td = jax.lax.map(one, (rows_p.reshape(n_tiles, tile), tkeys))
    ti = ti.reshape(-1, K)[:R]
    td = td.reshape(-1, K)[:R]
    return knn_idx.at[rows].set(ti), knn_dist.at[rows].set(td)


def neighbor_explore(x, knn_idx, knn_dist, *, iters: int = 1,
                     sample: int = 0, key=None, tile: int | None = None,
                     r_cap: int = 0, rows=None):
    """Refine (knn_idx, knn_dist) for ``iters`` rounds.

    sample=0 explores the full candidate set (paper-faithful); tile bounds
    the (tile, K^2, d) gather — shrink it for large K/d.  The default
    None resolves tile through the autotuner, but ONLY when sample == 0:
    with sampling on, the per-tile ``fold_in`` key stream makes the tile
    size part of the result, so the tuner must never touch it (the
    results-preservation contract in ``runtime/autotune.py``) and the
    legacy 1024 is used.  Each iteration is one jitted dispatch
    (``_explore_round``); the graph feeds back between iterations.

    ``rows`` (optional int32 array of row indices) restricts exploring to
    those rows — the incremental-insert repair mode: candidate generation
    still reads the FULL graph (forward and reverse), but only the given
    rows are recomputed and written back.
    """
    if key is None:
        key = jax.random.key(0)
    N, K = knn_idx.shape
    r_cap = r_cap or K
    n_rows = N if rows is None else int(rows.shape[0])
    if n_rows == 0:
        return knn_idx, knn_dist
    if tile is None:
        tile = 1024
        if sample == 0:          # tile is results-neutral only un-sampled
            from repro.runtime import autotune
            tile = autotune.get(
                "neighbor_explore", dict(n=n_rows, k=K, d=x.shape[1]),
                autotune.legacy_default("neighbor_explore"))["tile"]
    # keep the per-tile gather under ~256 MB f32
    budget = 64 * (1 << 20)
    tile = max(16, min(tile, n_rows,
                       budget // max(1, (K * K + K) * x.shape[1])))
    for it in range(iters):
        if rows is None:
            knn_idx, knn_dist = _explore_round(
                x, knn_idx, knn_dist, jax.random.fold_in(key, it),
                sample=sample, tile=tile, r_cap=r_cap)
        else:
            knn_idx, knn_dist = _explore_rows_round(
                x, knn_idx, knn_dist, rows, jax.random.fold_in(key, it),
                sample=sample, tile=tile, r_cap=r_cap)
    return knn_idx, knn_dist
