"""Sharded multi-device KNN-graph construction (paper §3.1 at scale).

The single-device pipeline (`core/knn.py`) holds all N points on one
device.  Here the point set is sharded over the mesh "data" axis and the
graph is built with a fixed per-device memory footprint:

  1. **Codes** — every shard computes sign-random-projection bucket codes
     for its own slab with a shared projection matrix (one matmul).
  2. **Fused ring pass** — point slabs circulate the device ring
     (`ppermute`); at each of the P ring steps a shard folds the
     in-flight remote slab straight into its running (N/P, k) best state
     through the streaming fused distance->top-k op
     (`kernels.ops.topk_sqdist`): bucket-mismatch/self/padding masking
     and the top-k merge happen inside the fold, so the old per-step
     re-merge concat is gone and distance/bucket-match work is bounded
     by the op's (bm, bn) tiles (at most one (N/P, N/P) tile when the
     slab fits a single tile) — and certainly no (N, N) matrix or
     all-gathered candidate buffer.
  3. **Sharded neighbor exploring** — `neighbor_explore.
     sharded_explore_round` exchanges the (N, K) graph (output-sized),
     derives forward + reverse neighbor candidates per local row, and
     fills candidate distances with a second ring pass over point slabs.

Set ``LargeVisConfig(distributed=True)`` (optionally ``data_shards``) to
route `build_knn_graph` / `largevis()` through this pipeline, or call
:func:`build_knn_graph_sharded` with an explicit mesh.  On CPU, expose
host devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn as knn_lib
from repro.core.neighbor_explore import sharded_explore_round
from repro.kernels import ops
from repro.kernels.ref import INVALID_DIST
from repro.runtime import sharding as sh
from repro.runtime.compat import shard_map


@functools.lru_cache(maxsize=32)
def _make_sharded_fn(mesh, axis: str, *, n_shards: int, n_real: int, k: int,
                     n_trees: int, depth: int, iters: int, sample: int,
                     impl: str = "auto"):
    """jit'd shard_map pipeline for fixed static shapes/hyper-params."""
    from jax.sharding import PartitionSpec as P
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def body(x_loc, ids_loc, proj, seed):
        n_loc = x_loc.shape[0]
        dev = jax.lax.axis_index(axis)

        # ---- per-shard projection codes (shared hyperplanes) ----------
        if n_trees:
            codes = knn_lib.hash_codes(x_loc, None, n_trees, depth,
                                       proj=proj)
        else:                                   # exact mode: no bucketing
            codes = jnp.zeros((n_loc, 1), jnp.int32)

        # ---- ring pass: fused streaming top-k, state carried across
        # ring steps (kernels/knn_topk.py topk_sqdist: each remote slab
        # folds straight into the running (n_loc, k) best state — the
        # per-step re-merge concat is gone and distance/bucket-match
        # work is bounded by the op's (bm, bn) tiles, never a full
        # re-merged candidate buffer; with n_loc below the tile size a
        # single (n_loc, n_loc) tile is the whole step, same as before)
        def ring_step(_, carry):
            bi, bd, rx, rc, rid = carry
            rid_eff = jnp.where(rid >= n_real, -1, rid)    # padding -> mask
            bi, bd = ops.topk_sqdist(
                x_loc, rx, k, a_ids=ids_loc, b_ids=rid_eff,
                codes_a=codes if n_trees else None,
                codes_b=rc if n_trees else None,
                init_ids=bi, init_dists=bd, impl=impl)
            rx = jax.lax.ppermute(rx, axis, perm)
            rc = jax.lax.ppermute(rc, axis, perm)
            rid = jax.lax.ppermute(rid, axis, perm)
            return bi, bd, rx, rc, rid

        bi = jnp.full((n_loc, k), -1, jnp.int32)
        bd = jnp.full((n_loc, k), INVALID_DIST, jnp.float32)
        bi, bd, _, _, _ = jax.lax.fori_loop(
            0, n_shards, ring_step, (bi, bd, x_loc, codes, ids_loc))

        # ---- sharded neighbor exploring -------------------------------
        for it in range(iters):
            ikey = jax.random.fold_in(
                jax.random.fold_in(jax.random.key(seed[0]), dev), it)
            bi, bd = sharded_explore_round(
                x_loc, ids_loc, bi, bd, axis=axis, n_shards=n_shards,
                n_real=n_real, key=ikey, sample=sample)
        return bi, bd

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(), P()),
        out_specs=(P(axis, None), P(axis, None)), check_vma=False)
    return jax.jit(sharded)


def build_knn_graph_sharded(x: jax.Array, key, cfg, *, mesh=None,
                            axis: str = "data", fault=None):
    """Sharded version of `knn.build_knn_graph`: (idx (N,K), sqdist (N,K)).

    ``mesh`` defaults to a 1-D "data" mesh over ``cfg.data_shards``
    devices (0 = all available).  N need not divide the shard count —
    points are zero-padded and padded ids are suppressed by the tile
    masks before any top-k.

    ``fault``: the per-shard ``knn_ring_step:<s>`` sites fire once per
    shard before the ring dispatch; an injected shard fault surfaces as
    ``ShardFailedError`` (stage ``"knn"``) for the mesh-recovery loop.
    """
    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(getattr(cfg, "data_shards", 0))
    n_shards = mesh.shape[axis]
    N, d = x.shape
    k = min(cfg.n_neighbors, N - 1)
    depth = cfg.tree_depth or knn_lib._auto_depth(N, cfg.leaf_target)
    # shared contiguous-block row layout (runtime/sharding.py): every
    # sharded stage downstream pads rows the same way, so graph tensors
    # line up shard-for-shard without repartitioning between stages
    xp = sh.pad_rows(x.astype(jnp.float32), n_shards)
    ids = jnp.arange(xp.shape[0], dtype=jnp.int32)
    kp, ks = jax.random.split(key)
    proj = jax.random.normal(kp, (d, max(cfg.n_trees, 1) * depth),
                             jnp.float32)
    seed = jax.random.randint(ks, (1,), 0, np.int32(2**31 - 1))
    fn = _make_sharded_fn(
        mesh, axis, n_shards=n_shards, n_real=N, k=k, n_trees=cfg.n_trees,
        depth=depth, iters=cfg.n_explore_iters, sample=cfg.explore_sample,
        impl=getattr(cfg, "knn_impl", "auto"))
    if fault is not None:
        from repro.runtime.fault_tolerance import fire_per_shard
        fire_per_shard(fault, "knn_ring_step", n_shards, stage="knn")
    idx, dist = fn(xp, ids, proj, seed)
    return idx[:N], dist[:N]
