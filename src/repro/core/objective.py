"""Probabilistic layout model (paper §3.2, Eqn 3-6).

P(e_ij = 1) = f(||y_i - y_j||).  Candidate probability functions compared in
the paper's Fig. 4 — f(x) = 1/(1 + a x^2) family and f(x) = 1/(1+exp(x^2));
the long-tailed a=1 inverse-quadratic wins (crowding problem, same argument
as t-SNE's Student-t).

Gradients for the winner are hand-derived (and fused in the Pallas kernel);
the other variants go through autodiff — both paths are exercised by
benchmarks/fig4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

PROB_FNS = ("inv_quadratic", "exp_quadratic")


def log_f(d2: jax.Array, prob_fn: str, a: float) -> jax.Array:
    """log P(edge) as a function of squared distance."""
    if prob_fn == "inv_quadratic":
        return -jnp.log1p(a * d2)
    if prob_fn == "exp_quadratic":                        # f = 1/(1+e^{x^2})
        return -jax.nn.softplus(d2)
    raise ValueError(prob_fn)


def log_1mf(d2: jax.Array, prob_fn: str, a: float,
            eps: float = 0.1) -> jax.Array:
    """log(1 - P(edge)); eps guards the collision singularity."""
    if prob_fn == "inv_quadratic":
        return jnp.log(a * d2 + eps) - jnp.log1p(a * d2)
    if prob_fn == "exp_quadratic":                        # 1-f = 1/(1+e^-x^2)
        return -jax.nn.softplus(-d2)
    raise ValueError(prob_fn)


def edge_batch_loss(yi, yj, yneg, neg_mask, *, prob_fn: str = "inv_quadratic",
                    a: float = 1.0, gamma: float = 7.0) -> jax.Array:
    """Negated Eqn (6) over a sampled batch (to MINIMIZE)."""
    d2 = jnp.sum((yi - yj) ** 2, axis=-1)
    pos = -log_f(d2, prob_fn, a)
    dn2 = jnp.sum((yi[:, None, :] - yneg) ** 2, axis=-1)
    neg = -gamma * log_1mf(dn2, prob_fn, a) * neg_mask
    return jnp.sum(pos) + jnp.sum(neg)


@functools.partial(jax.jit, static_argnames=("prob_fn", "a", "gamma", "clip"))
def grads_autodiff(yi, yj, yneg, neg_mask, *, prob_fn: str, a: float = 1.0,
                   gamma: float = 7.0, clip: float = 5.0):
    """(gi, gj, gneg) via autodiff — used for non-default prob functions."""
    g = jax.grad(edge_batch_loss, argnums=(0, 1, 2))(
        yi, yj, yneg, neg_mask, prob_fn=prob_fn, a=a, gamma=gamma)
    return tuple(jnp.clip(x, -clip, clip) for x in g)
