"""The paper's primary contribution: LargeVis (KNN graph + probabilistic
layout) as a composable JAX module."""
from repro.core.largevis import largevis, build_graph, layout_graph, LargeVisResult  # noqa: F401
