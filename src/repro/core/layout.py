"""Layout optimization: batched edge-sampling SGD (paper §3.2, TPU-adapted).

The paper's Hogwild (batch-1 async updates) becomes batched synchronous
edge-sampling SGD with scatter-add — intra-batch collisions resolve
deterministically, and the paper's own sparsity argument ("conflicting
updates are rare") is why the batched dynamics match batch-1 dynamics.  For
multi-device runs, ``sync_every`` (H) gives local-SGD semantics: each shard
updates its own replica for H steps, then replicas average — the principled
TPU analogue of Hogwild staleness (DESIGN.md §2).

lr schedule: rho_t = rho0 * (1 - t/T), batch-size-corrected; per-coordinate
gradient clip as in the reference implementation.

Stepping goes through ``core/layout_engine.py``: ``run_layout`` dispatches
``cfg.steps_per_dispatch`` scanned steps per device round trip (donated y
buffer, no per-step host sync); the per-step Python loop survives only for
visual-progress callbacks and as ``steps_per_dispatch<=1`` debug mode.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout_engine
from repro.core.layout_engine import sgd_edge_step
from repro.core.sampler import (EdgeSampler, NodeSampler,
                                ShardedEdgeSampler, ShardedNodeSampler)
from repro.runtime.compat import shard_map
from repro.runtime.fault_tolerance import (DegradedModeWarning,
                                           DivergenceWarning, InjectedFault,
                                           LayoutDivergedError,
                                           PreemptionGuard,
                                           TopologyChangeWarning, Watchdog,
                                           fire_per_shard)


@functools.partial(
    jax.jit, donate_argnums=(0,),
    static_argnames=layout_engine.STATIC_ARGNAMES)
def layout_step(y, key, t_frac, **kw):
    """One jitted SGD step (see ``layout_engine.sgd_edge_step``).

    Per-step dispatch entry point — kept for the callback/visual-progress
    driver and external single-step users; bulk stepping should go through
    ``layout_engine.layout_chunk``, which runs H of these per dispatch.
    """
    return sgd_edge_step(y, key, t_frac, **kw)


@dataclasses.dataclass
class LayoutResult:
    y: jax.Array
    steps: int
    edge_samples: int
    # robustness diagnostics (PR 8): divergence rollbacks taken, the final
    # lr backoff scale, and the watchdog's straggler dispatches
    rollbacks: int = 0
    rho0_scale: float = 1.0
    stragglers: list = dataclasses.field(default_factory=list)


@jax.jit
def layout_health(y):
    """Jitted per-dispatch health probe: one reduction pass over (N, s).

    Returns ``(nonfinite_count, max_abs)`` — non-finite entries are
    excluded from the max so a single NaN cannot mask a norm blowup."""
    finite = jnp.isfinite(y)
    nonfinite = jnp.sum(~finite)
    max_abs = jnp.max(jnp.abs(jnp.where(finite, y, 0.0)))
    return nonfinite, max_abs


def _layout_stage_ckpt(key, n_nodes, cfg, edge_sampler=None, table=None):
    """StageCheckpointer for the layout stage, else None.

    The layout trajectory is a pure function of (samplers, key, cfg, N),
    so the fingerprint binds all four — the sampler via a strided sample
    of its alias threshold table, which is itself a deterministic
    function of the input data.  A directory written by a different
    run (other data, key, or hyper-params) can never resume into this
    one, even at identical N.

    ``table`` overrides the sampler-derived fingerprint data.  The
    local-SGD driver passes the *global* edge weights here: a
    :class:`~repro.core.sampler.ShardedEdgeSampler`'s threshold table is
    laid out per shard — (P, E_loc) — so fingerprinting it would bind
    the checkpoint to the mesh shape and break topology-portable resume,
    while the weights are identical on every mesh."""
    ckpt_cfg = getattr(cfg, "checkpoint", None)
    if ckpt_cfg is None:
        return None
    from repro.checkpoint.largevis_state import (StageCheckpointer,
                                                 run_fingerprint)
    if table is not None:
        table = np.asarray(table).reshape(-1, 1)
    elif edge_sampler is not None:
        table = np.asarray(edge_sampler.threshold).reshape(-1, 1)
    fp = run_fingerprint(table, key, cfg) + f"-n{n_nodes}"
    return StageCheckpointer(ckpt_cfg, fp)


def _collision_capped_batch(batch_size: int, n_nodes: int,
                            total: int = 0) -> int:
    """Batched-synchronous updates track the paper's batch-1 async dynamics
    only while intra-batch collisions are rare (§3.2's sparsity argument).
    A batch larger than ~N/2 guarantees every node collects several stale
    summed updates per step and the layout overshoots (on a 2000-node
    graph, batch 4096 drops the KNN-classifier accuracy from 0.98 to
    0.74), so cap the batch by the node count."""
    cap = max(1, n_nodes // 2)
    if total:
        cap = min(cap, max(total, 1))
    return min(batch_size, cap)


def _step_kwargs(edge_sampler: EdgeSampler, neg_sampler: NodeSampler,
                 n_nodes: int, cfg, batch: int) -> dict:
    """The sgd_edge_step keyword bundle shared by every driver below.

    Samplers ride through as pytrees — the jitted entry points see two
    structured arguments, not six unpacked table arrays."""
    return dict(
        edge_sampler=edge_sampler, neg_sampler=neg_sampler,
        n_negatives=cfg.n_negatives, n_nodes=n_nodes, prob_fn=cfg.prob_fn,
        a=cfg.prob_a, gamma=cfg.gamma, clip=cfg.grad_clip, rho0=cfg.rho0,
        batch=batch, fused_step=bool(getattr(cfg, "fused_step", True)))


# ---------------------------------------------------------------------------
# Local-SGD multi-device mode (the TPU analogue of the paper's Hogwild)
# ---------------------------------------------------------------------------

def make_local_sgd_fns(mesh, cfg, n_nodes: int, *, batch: int):
    """Returns the jitted H-local-steps-then-sync round function.

    Each device holds its own full replica of Y (leading replica axis,
    sharded over "data"), samples its own edge stream (RNG folded with the
    device index), and applies ``sync_every`` (H) local updates between
    syncs.  The sync is a **psum of deltas** (``y0 + psum(y - y0)``), not
    a mean: the paper's async SGD applies every sampled edge's update at
    full ``lr`` (stale reads tolerated — "conflicting updates are rare on
    sparse graphs"), and summing the replica drifts is exactly that; a
    pmean would scale every per-sample step by 1/P, silently under-
    stepping the schedule P-fold (measured: 2000-node fixture at P=8
    drops from ~0.95 to ~0.75 KNN-classifier accuracy).  The flip side is
    that the collision argument now bounds the *global* concurrent batch
    ``batch * P`` — the driver caps it at ~N/2.  H=1 degenerates to
    synchronous data-parallel; at P=1 psum == pmean == identity, so
    single-device trajectories are unchanged bitwise.

    The H local steps are one ``layout_engine.scan_layout_steps`` scan per
    shard_map body (formerly a hand-rolled ``fori_loop`` over the jitted
    per-step fn — same dynamics, one compiled loop instead of H inlined
    step bodies).

    Samplers may be the flat :class:`EdgeSampler`/:class:`NodeSampler`
    (tables replicated, every device draws global indices) or the
    sharded pair from ``sampler.build_samplers_sharded``: the stacked
    per-shard edge tables enter sharded over "data" (each device holds
    ONLY its own shard's table — the reference implementation's
    per-thread sampling range, i.e. stratified edge sampling), while the
    negative tables stay replicated (O(N) total) so collisions against
    any node mask correctly.  At one device the two modes produce the
    identical trajectory bitwise (same tables, same key stream).
    """
    from jax.sharding import PartitionSpec as P
    dp_spec = P("data", None, None)
    rep = P()
    H = max(1, cfg.sync_every)

    def _edge_in_spec(edge_sampler):
        """Spec pytree for the edge sampler argument: sharded stacked
        tables get their leading (P,) axis over "data" with the tiny
        shard-selection table replicated; flat samplers replicate."""
        if isinstance(edge_sampler, ShardedEdgeSampler):
            if edge_sampler.n_shards != mesh.shape["data"]:
                raise ValueError(
                    f"sampler built for {edge_sampler.n_shards} shards, "
                    f"mesh has {mesh.shape['data']}")
            t = P("data", None)
            return ShardedEdgeSampler(t, t, t, t, rep, rep,
                                      edge_sampler.n_shards,
                                      edge_sampler.n_edges)
        return rep

    def local_steps(y_rep, seed, t_frac0, dt_frac, edge_sampler,
                    neg_sampler):
        """H local steps on each replica (shard_map over 'data').

        Flat sampler pytrees enter replicated — a single ``P()`` spec
        per sampler covers every leaf (jax prefix-pytree semantics);
        sharded edge samplers enter with their stacked tables split over
        the mesh (see ``_edge_in_spec``)."""

        def body(y_loc, seed, t_frac0, dt_frac, edge_sampler, neg_sampler):
            dev = jax.lax.axis_index("data")
            # a sharded edge sampler arrives as this device's (1, E_loc)
            # block: sample the local shard's edges (stratified)
            es = (edge_sampler.local()
                  if isinstance(edge_sampler, ShardedEdgeSampler)
                  else edge_sampler)
            base_key = jax.random.fold_in(jax.random.key(seed[0]), dev)
            step_ids = jnp.arange(H, dtype=jnp.int32)
            t_fracs = t_frac0 + dt_frac * step_ids.astype(jnp.float32)
            y = layout_engine.scan_layout_steps(
                y_loc[0], base_key, step_ids, t_fracs,
                edge_sampler=es, neg_sampler=neg_sampler,
                n_negatives=cfg.n_negatives, n_nodes=n_nodes,
                prob_fn=cfg.prob_fn, a=cfg.prob_a, gamma=cfg.gamma,
                clip=cfg.grad_clip, rho0=cfg.rho0, batch=batch,
                fused_step=bool(getattr(cfg, "fused_step", True)))
            # Hogwild-sum sync: the round-start state is this body's own
            # input (replicas enter a round identical), so the delta
            # combine costs no extra dispatch or y0 copy.  Skipped
            # entirely at P=1: `y0 + (y - y0)` is NOT bitwise `y`
            # (rounding), and the single-device trajectory must stay
            # bit-identical to the flat drivers
            if mesh.shape["data"] == 1:
                return y[None]
            return (y_loc[0] + jax.lax.psum(y - y_loc[0], "data"))[None]

        return shard_map(
            body, mesh=mesh,
            in_specs=(dp_spec, rep, rep, rep, _edge_in_spec(edge_sampler),
                      rep),
            out_specs=dp_spec, check_vma=False,
        )(y_rep, seed, t_frac0, dt_frac, edge_sampler, neg_sampler)

    return jax.jit(local_steps, donate_argnums=(0,))


def run_layout_local_sgd(key, edge_sampler: EdgeSampler,
                         neg_sampler: NodeSampler, n_nodes: int, cfg,
                         mesh, *, fault=None, weights=None) -> LayoutResult:
    """Multi-device local-SGD layout driver (paper's async SGD, TPU form).

    Checkpointing (``cfg.checkpoint``) is at **round** granularity: after
    the psum-of-deltas sync every replica holds the identical embedding,
    so persisting ``y_rep[0]`` at a round boundary and re-broadcasting on
    resume reconstructs the exact distributed state.  The round seeds are
    pre-derived in one batch from ``kr``, so a resumed run replays the
    same per-round key stream — killed+resumed is bitwise-equal to
    uninterrupted, exactly as on the single-device path.

    Elastic resume: pass ``weights`` (the global edge weights) so the
    fingerprint is topology-invariant (see ``_layout_stage_ckpt``); each
    save carries a topology tag and the global edge-sample count.  A
    checkpoint written on the SAME shard count resumes bitwise; one
    written on a DIFFERENT shard count resumes from the last committed
    round boundary with the completed sample count remapped onto the new
    mesh's round structure, announced exactly once with
    :class:`TopologyChangeWarning` (the per-replica key streams are
    P-dependent by construction, so a cross-topology trajectory cannot
    be bitwise-continued — the embedding state is, the schedule restarts
    at the boundary).

    ``fault`` fires ``layout_round``/``layout_saved`` (kill matrix) plus
    the per-shard ``local_sgd_round:<s>`` sites after every round —
    injected shard exceptions surface as ``ShardFailedError`` (stage
    ``"layout"``) for the mesh-recovery loop, and callable specs may
    inflate one shard's observed round time: a per-shard
    :class:`Watchdog` tracks each shard's round times and a straggling
    shard is flagged *by index* in ``result.stragglers`` entries
    ``(shard, round, dt, median)`` with one summary RuntimeWarning.

    A process-wide active :class:`PreemptionGuard` (armed by
    ``largevis()`` when checkpointing is on) gets its save_fn pointed at
    the newest completed round each round, so SIGTERM/SIGINT commits a
    resumable stage checkpoint before the process dies."""
    n_dev = mesh.shape["data"]
    stage_ckpt = _layout_stage_ckpt(key, n_nodes, cfg, edge_sampler,
                                    table=weights)
    ckpt_cfg = getattr(cfg, "checkpoint", None)
    ky, kr = jax.random.split(key)
    y0 = (jax.random.normal(ky, (n_nodes, cfg.out_dim), jnp.float32)
          * cfg.init_scale)

    # the replicas' batches apply concurrently between syncs (Hogwild-sum
    # combine), so the collision cap bounds the GLOBAL concurrent batch
    # batch * n_dev at ~N/2, split evenly per replica (at n_dev=1 this is
    # exactly the single-device cap)
    batch = max(1, _collision_capped_batch(cfg.batch_size * n_dev,
                                           n_nodes) // n_dev)
    total = int(cfg.samples_per_node) * n_nodes
    steps = max(1, total // (batch * n_dev))
    H = max(1, cfg.sync_every)
    n_rounds = max(1, steps // H)

    topo = {"distributed": True, "data_shards": int(n_dev),
            "n_rows": int(n_nodes)}
    start_round = 0
    if stage_ckpt is not None:
        loaded = stage_ckpt.load("layout")
        if loaded is not None:
            tree, saved_round, extra = loaded
            y0 = jnp.asarray(tree["y"], jnp.float32)
            saved_topo = (extra or {}).get("topology") or {}
            saved_shards = int(saved_topo.get("data_shards", n_dev))
            if saved_shards == n_dev:
                start_round = int(saved_round)   # bitwise continuation
            else:
                # same embedding state, new round structure: place the
                # resume point at the boundary covering the samples the
                # old mesh had already committed
                samples_done = int((extra or {}).get(
                    "samples_done", int(saved_round) * H * batch * n_dev))
                start_round = samples_done // (H * batch * n_dev)
                warnings.warn(TopologyChangeWarning(
                    "layout", saved_shards, n_dev, start_round),
                    stacklevel=2)
    start_round = min(int(start_round), n_rounds)
    y_rep = jnp.broadcast_to(y0, (n_dev,) + y0.shape)
    from jax.sharding import NamedSharding, PartitionSpec as P
    y_rep = jax.device_put(y_rep, NamedSharding(mesh, P("data", None, None)))

    local_steps = make_local_sgd_fns(mesh, cfg, n_nodes, batch=batch)
    dt = 1.0 / max(steps, 1)
    # one batched draw + one device->host transfer for ALL round seeds:
    # deriving each round's seed with int(...) inside the loop forced a
    # synchronous device round trip every H steps, serializing the rounds
    seeds = np.asarray(jax.random.randint(kr, (n_rounds,), 0, 2**31 - 1,
                                          dtype=jnp.int32))

    def _extras(rounds_done: int) -> dict:
        return {"topology": topo,
                "samples_done": rounds_done * H * batch * n_dev}

    guard = PreemptionGuard.active() if stage_ckpt is not None else None
    preempt_state = None
    if guard is not None:
        # the snapshot is a fresh device buffer (slice), never donated —
        # save() host-gathers at signal time, so rounds stay async
        preempt_state = {"y": y0, "round": start_round}

        def _preempt_save():
            stage_ckpt.save("layout", {"y": preempt_state["y"]},
                            step=preempt_state["round"],
                            keep=max(1, ckpt_cfg.keep),
                            extra=_extras(preempt_state["round"]))

        guard.set_save_fn(_preempt_save)

    # per-shard round-time watchdogs: on a single-controller mesh every
    # shard observes the host-measured round time, so only an injected
    # (or runtime-reported) inflation differentiates them — which is
    # exactly what the straggler chaos tests feed through the callable
    # per-shard fault specs
    monitored = fault is not None
    watchdogs = [Watchdog() for _ in range(n_dev)] if monitored else []
    stragglers: list = []
    try:
        for r in range(start_round, n_rounds):
            t0 = time.time()
            y_rep = local_steps(
                y_rep, jnp.asarray(seeds[r:r + 1]), jnp.float32(r * H * dt),
                jnp.float32(dt), edge_sampler, neg_sampler)
            if monitored:
                jax.block_until_ready(y_rep)
                fault.fire("layout_round")
                round_dt = time.time() - t0
                dts = fire_per_shard(fault, "local_sgd_round", n_dev,
                                     stage="layout",
                                     payloads=[round_dt] * n_dev)
                for s, wd in enumerate(watchdogs):
                    if dts[s] is not None and wd.observe(r, float(dts[s])):
                        _, dtv, med = wd.stragglers[-1]
                        stragglers.append((s, r, dtv, med))
            if guard is not None:
                preempt_state["y"] = y_rep[0]
                preempt_state["round"] = r + 1
            if stage_ckpt is not None and (
                    (r + 1) % max(1, ckpt_cfg.every_chunks) == 0
                    or r + 1 >= n_rounds):
                stage_ckpt.save("layout", {"y": y_rep[0]}, step=r + 1,
                                keep=max(1, ckpt_cfg.keep),
                                extra=_extras(r + 1))
                if fault is not None:
                    fault.fire("layout_saved")
    finally:
        if guard is not None:
            guard.set_save_fn(None)
    if stragglers:
        worst = max(stragglers, key=lambda t: t[2])
        warnings.warn(
            f"local-SGD: shard {worst[0]} straggling — round {worst[1]} "
            f"took {worst[2]:.3f}s vs median {worst[3]:.3f}s "
            f"({len(stragglers)} flagged round(s); see "
            f"LayoutResult.stragglers)", RuntimeWarning, stacklevel=2)
    done = n_rounds - start_round
    return LayoutResult(y=y_rep[0], steps=done * H,
                        edge_samples=done * H * batch * n_dev,
                        stragglers=stragglers)


def run_layout(key, edge_sampler: EdgeSampler, neg_sampler: NodeSampler,
               n_nodes: int, cfg, *,
               callback: Optional[Callable] = None,
               y0=None, start_step: int = 0,
               on_chunk: Optional[Callable] = None,
               fault=None) -> LayoutResult:
    """Drive the layout for T = samples_per_node * N edge samples.

    Default path: ``layout_engine.layout_chunk`` — H =
    ``cfg.steps_per_dispatch`` scanned steps per device dispatch with a
    donated y buffer.  A ``callback`` (visual progress) or
    ``steps_per_dispatch <= 1`` requests the per-step Python loop, which
    produces the identical trajectory one host round trip per step.

    Resume: pass ``y0`` (e.g. a checkpointed layout) and ``start_step``;
    the schedule (key stream, t/T lr positions) continues exactly where
    step ``start_step`` would have run.  ``on_chunk(t, steps, y)`` fires
    after every dispatch on the scanned path with ``y`` synced — the
    checkpoint/watchdog/progress hook for chunked drivers.

    Robustness (scanned path; see README "Robustness"):

    * ``cfg.checkpoint`` — the layout self-checkpoints every
      ``every_chunks`` dispatches (atomic, keep-last-k, fingerprinted to
      this (key, cfg, N)); with no explicit ``y0`` it auto-resumes from
      the newest valid checkpoint, continuing the exact (key, lr) stream
      — a killed+resumed run is bitwise-equal to an uninterrupted one.
    * ``cfg.health`` — a jitted probe checks the embedding every
      ``check_every_chunks`` dispatches; divergence (non-finite entries
      or |y| past ``max_abs``) rolls back to the last healthy chunk with
      the lr scaled by ``lr_backoff`` (``DivergenceWarning``), raising
      ``LayoutDivergedError`` after ``max_rollbacks`` attempts.
    * degraded mode — a backend failure dispatching the first fused
      chunk demotes ``fused -> split`` for the run with one
      ``DegradedModeWarning`` instead of crashing the fit.
    * a :class:`~repro.runtime.fault_tolerance.Watchdog` times every
      blocked dispatch and surfaces outliers in ``result.stragglers``
      (chunks are only blocked-on when a hook/health/fault already
      forces the sync — a checkpoint-only run keeps the async pipeline:
      saves go through an off-thread
      :class:`~repro.checkpoint.largevis_state.AsyncStageWriter` fed
      on-device ``jnp.copy`` snapshots, and the watchdog times the
      interval between snapshot completions instead).
    * ``fault`` — a FaultInjector fired at ``layout_chunk`` (post-chunk
      payload = y) and ``layout_saved`` (post-checkpoint-commit) for the
      kill/chaos test matrices.
    """
    health = getattr(cfg, "health", None)
    stage_ckpt = _layout_stage_ckpt(key, n_nodes, cfg, edge_sampler)
    rho0_scale, rollbacks = 1.0, 0
    if stage_ckpt is not None and y0 is None and start_step == 0:
        loaded = stage_ckpt.load("layout")
        if loaded is not None:
            tree, saved_step, extra = loaded
            y0, start_step = tree["y"], saved_step
            rho0_scale = float(extra.get("rho0_scale", 1.0))
            rollbacks = int(extra.get("rollbacks", 0))

    ky, kr = jax.random.split(key)
    if y0 is None:
        y = (jax.random.normal(ky, (n_nodes, cfg.out_dim), jnp.float32)
             * cfg.init_scale)
    else:
        y = jnp.asarray(y0, jnp.float32)
    total = int(cfg.samples_per_node) * n_nodes
    batch = _collision_capped_batch(cfg.batch_size, n_nodes, total)
    steps = max(1, total // batch)
    start = min(int(start_step), steps)
    kwargs = _step_kwargs(edge_sampler, neg_sampler, n_nodes, cfg, batch)

    # 0 = unset: ask the autotuner for a tuned scan-chunk length (the
    # "layout_chunk" cell — results-neutral, see layout_engine.dispatch_steps)
    H = layout_engine.dispatch_steps(
        int(getattr(cfg, "steps_per_dispatch", 0)),
        n_nodes=n_nodes, batch=batch)
    watchdog = None
    if callback is None and H > 1:
        # block on every chunk only when something already needs the sync;
        # a checkpoint-only run keeps the async pipeline — saves go to an
        # off-thread writer fed on-device snapshots, so durability costs a
        # device memcpy per cadence instead of a pipeline stall per chunk
        monitored = (on_chunk is not None or health is not None
                     or fault is not None)
        watchdog = (Watchdog() if monitored or stage_ckpt is not None
                    else None)
        writer = None
        if stage_ckpt is not None and not monitored:
            from repro.checkpoint.largevis_state import AsyncStageWriter
            writer = AsyncStageWriter(stage_ckpt, watchdog=watchdog)
        ckpt_cfg = getattr(cfg, "checkpoint", None)
        last_good = (np.asarray(y), start) if health is not None else None
        t, chunk_i, first_chunk = start, 0, True
        # preemption: point the process-wide active guard (armed by
        # largevis() when checkpointing is on) at the newest completed
        # chunk — the snapshot is an on-device jnp.copy (no host sync,
        # never donated), host-gathered only if a signal actually lands
        guard = PreemptionGuard.active() if stage_ckpt is not None else None
        preempt_state = None
        if guard is not None:
            preempt_state = {"y": jnp.copy(y), "step": start,
                             "extra": {"rho0_scale": rho0_scale,
                                       "rollbacks": rollbacks}}

            def _preempt_save():
                stage_ckpt.save("layout", {"y": preempt_state["y"]},
                                step=preempt_state["step"],
                                keep=max(1, ckpt_cfg.keep),
                                extra=preempt_state["extra"])

            guard.set_save_fn(_preempt_save)
        try:
            while t < steps:
                h = min(H, steps - t)
                step_ids = jnp.arange(t, t + h, dtype=jnp.int32)
                # host-side t/steps (f64 rounded to f32) — bit-identical to
                # the Python loop's jnp.float32(t / steps) schedule
                t_fracs = jnp.asarray(np.arange(t, t + h) / steps,
                                      jnp.float32)
                kwargs["rho0"] = cfg.rho0 * rho0_scale  # traced: no recompile
                t0 = time.time()
                if first_chunk and kwargs["fused_step"]:
                    # degraded-mode guard: donation invalidates y at
                    # dispatch, so snapshot once to make the retry safe
                    y_backup = np.asarray(y)
                    try:
                        y = layout_engine.layout_chunk(y, kr, step_ids,
                                                       t_fracs, **kwargs)
                    except InjectedFault:
                        raise
                    except Exception as e:      # backend/compile failure
                        warnings.warn(DegradedModeWarning(
                            "layout_step", "fused", "split", e),
                            stacklevel=2)
                        kwargs["fused_step"] = False
                        y = layout_engine.layout_chunk(
                            jnp.asarray(y_backup), kr, step_ids, t_fracs,
                            **kwargs)
                else:
                    y = layout_engine.layout_chunk(y, kr, step_ids, t_fracs,
                                                   **kwargs)
                first_chunk = False
                t += h
                chunk_i += 1
                if monitored:
                    jax.block_until_ready(y)
                    watchdog.observe(t, time.time() - t0)
                if fault is not None:
                    y = fault.fire("layout_chunk", y)
                if health is not None and (
                        chunk_i % max(1, health.check_every_chunks) == 0
                        or t >= steps):
                    nf, mx = layout_health(y)
                    nf, mx = int(nf), float(mx)
                    if nf or mx > health.max_abs:
                        rollbacks += 1
                        if rollbacks > health.max_rollbacks:
                            raise LayoutDivergedError(
                                f"layout still diverging after "
                                f"{health.max_rollbacks} rollbacks "
                                f"(step {t}: nonfinite={nf}, "
                                f"max|y|={mx:.3g})")
                        rho0_scale *= health.lr_backoff
                        warnings.warn(DivergenceWarning(
                            t, last_good[1], nf, mx, rho0_scale),
                            stacklevel=2)
                        y, t = jnp.asarray(last_good[0]), last_good[1]
                        continue
                    last_good = (np.asarray(y), t)
                if stage_ckpt is not None and (
                        chunk_i % max(1, ckpt_cfg.every_chunks) == 0
                        or t >= steps):
                    extra = {"rho0_scale": rho0_scale,
                             "rollbacks": rollbacks}
                    keep = max(1, ckpt_cfg.keep)
                    if writer is not None:
                        writer.submit("layout", {"y": jnp.copy(y)}, step=t,
                                      keep=keep, extra=extra)
                    else:
                        stage_ckpt.save("layout", {"y": y}, step=t,
                                        keep=keep, extra=extra)
                        if fault is not None:
                            fault.fire("layout_saved")
                if guard is not None:
                    preempt_state["y"] = jnp.copy(y)
                    preempt_state["step"] = t
                    preempt_state["extra"] = {"rho0_scale": rho0_scale,
                                              "rollbacks": rollbacks}
                if on_chunk is not None:
                    on_chunk(t, steps, y)
        finally:
            if guard is not None:
                guard.set_save_fn(None)
            if writer is not None:
                writer.close()
    else:
        for t in range(start, steps):
            y = layout_step(y, jax.random.fold_in(kr, t),
                            jnp.float32(t / steps), **kwargs)
            if callback is not None and (t % max(1, steps // 20) == 0):
                callback(t, steps, y)
    stragglers = list(watchdog.stragglers) if watchdog is not None else []
    # surface stragglers only when the outlier is macroscopic — 3x a
    # sub-millisecond median is host jitter, not a sick device
    if stragglers and max(s[1] for s in stragglers) > 0.1:
        warnings.warn(
            f"layout: {len(stragglers)} straggler dispatch(es) — worst "
            f"{max(s[1] for s in stragglers):.3f}s vs median "
            f"{stragglers[-1][2]:.3f}s (see LayoutResult.stragglers)",
            RuntimeWarning, stacklevel=2)
    done = steps - start
    return LayoutResult(y=y, steps=done, edge_samples=done * batch,
                        rollbacks=rollbacks, rho0_scale=rho0_scale,
                        stragglers=stragglers)
