"""Layout optimization: batched edge-sampling SGD (paper §3.2, TPU-adapted).

The paper's Hogwild (batch-1 async updates) becomes batched synchronous
edge-sampling SGD with scatter-add — intra-batch collisions resolve
deterministically, and the paper's own sparsity argument ("conflicting
updates are rare") is why the batched dynamics match batch-1 dynamics.  For
multi-device runs, ``sync_every`` (H) gives local-SGD semantics: each shard
updates its own replica for H steps, then replicas average — the principled
TPU analogue of Hogwild staleness (DESIGN.md §2).

lr schedule: rho_t = rho0 * (1 - t/T), batch-size-corrected; per-coordinate
gradient clip as in the reference implementation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import objective
from repro.core.sampler import EdgeSampler, NodeSampler, sample_alias
from repro.kernels import ops
from repro.runtime.compat import shard_map


@functools.partial(
    jax.jit, donate_argnums=(0,),
    static_argnames=("n_negatives", "prob_fn", "a", "gamma", "clip",
                     "n_nodes", "batch"))
def layout_step(y, key, t_frac, *, edge_src, edge_dst, edge_thr, edge_alias,
                neg_thr, neg_alias, n_negatives: int, n_nodes: int,
                prob_fn: str = "inv_quadratic", a: float = 1.0,
                gamma: float = 7.0, clip: float = 5.0, rho0: float = 1.0,
                batch: int = 4096):
    """One SGD step over a freshly sampled edge batch.  t_frac = t/T."""
    ke, kn, kb = jax.random.split(key, 3)
    e = sample_alias(ke, edge_thr, edge_alias, (batch,))
    i, j = edge_src[e], edge_dst[e]
    negs = sample_alias(kn, neg_thr, neg_alias, (batch, n_negatives))
    # mask collisions: negative == source or target of the positive edge
    neg_mask = ((negs != i[:, None]) & (negs != j[:, None])).astype(
        jnp.float32)

    yi, yj, yneg = y[i], y[j], y[negs]
    if prob_fn == "inv_quadratic":
        gi, gj, gneg = ops.largevis_grads(yi, yj, yneg, neg_mask, gamma=gamma,
                                          a=a, clip=clip)
    else:
        gi, gj, gneg = objective.grads_autodiff(yi, yj, yneg, neg_mask,
                                                prob_fn=prob_fn, a=a,
                                                gamma=gamma, clip=clip)
    lr = rho0 * jnp.maximum(1.0 - t_frac, 1e-4)
    # single fused scatter-add (3 separate .at[].add calls triple the
    # y read/write traffic — §Perf hillclimb 3 iter 2)
    s = y.shape[1]
    idx = jnp.concatenate([i, j, negs.reshape(-1)])
    upd = jnp.concatenate([gi, gj, gneg.reshape(-1, s)], axis=0)
    return y.at[idx].add(-lr * upd)


@dataclasses.dataclass
class LayoutResult:
    y: jax.Array
    steps: int
    edge_samples: int


def _collision_capped_batch(batch_size: int, n_nodes: int,
                            total: int = 0) -> int:
    """Batched-synchronous updates track the paper's batch-1 async dynamics
    only while intra-batch collisions are rare (§3.2's sparsity argument).
    A batch larger than ~N/2 guarantees every node collects several stale
    summed updates per step and the layout overshoots (on a 2000-node
    graph, batch 4096 drops the KNN-classifier accuracy from 0.98 to
    0.74), so cap the batch by the node count."""
    cap = max(1, n_nodes // 2)
    if total:
        cap = min(cap, max(total, 1))
    return min(batch_size, cap)


# ---------------------------------------------------------------------------
# Local-SGD multi-device mode (the TPU analogue of the paper's Hogwild)
# ---------------------------------------------------------------------------

def make_local_sgd_fns(mesh, cfg, n_nodes: int, *, batch: int):
    """Returns (local_steps_fn, sync_fn) over replicated-per-device layouts.

    Each device holds its own full replica of Y (leading replica axis,
    sharded over "data"), samples its own edge stream (RNG folded with the
    device index), and applies ``sync_every`` (H) local updates between
    psum-averages — the paper's "conflicting updates are rare on sparse
    graphs" argument, made explicit: replicas drift for H steps and the
    drift is averaged away.  H=1 degenerates to synchronous data-parallel.
    """
    from jax.sharding import PartitionSpec as P
    n_dev = mesh.shape["data"]
    dp_spec = P("data", None, None)
    rep = P()

    def local_steps(y_rep, seed, t_frac0, dt_frac, edge_src, edge_dst,
                    edge_thr, edge_alias, neg_thr, neg_alias):
        """H local steps on each replica (shard_map over 'data')."""

        def body(y_loc, seed, t_frac0, dt_frac, edge_src, edge_dst,
                 edge_thr, edge_alias, neg_thr, neg_alias):
            dev = jax.lax.axis_index("data")
            y = y_loc[0]

            def one(i, y):
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(seed[0]), dev), i)
                return layout_step(
                    y, key, t_frac0 + dt_frac * i.astype(jnp.float32),
                    edge_src=edge_src, edge_dst=edge_dst, edge_thr=edge_thr,
                    edge_alias=edge_alias, neg_thr=neg_thr,
                    neg_alias=neg_alias, n_negatives=cfg.n_negatives,
                    n_nodes=n_nodes, prob_fn=cfg.prob_fn, a=cfg.prob_a,
                    gamma=cfg.gamma, clip=cfg.grad_clip, rho0=cfg.rho0,
                    batch=batch)

            y = jax.lax.fori_loop(0, cfg.sync_every, one, y)
            return y[None]

        return shard_map(
            body, mesh=mesh,
            in_specs=(dp_spec, rep, rep, rep, rep, rep, rep, rep, rep, rep),
            out_specs=dp_spec, check_vma=False,
        )(y_rep, seed, t_frac0, dt_frac, edge_src, edge_dst, edge_thr,
          edge_alias, neg_thr, neg_alias)

    def sync(y_rep):
        """psum-average the replicas (the every-H synchronization)."""

        def body(y_loc):
            return jax.lax.pmean(y_loc, "data")

        return shard_map(body, mesh=mesh, in_specs=dp_spec,
                         out_specs=dp_spec, check_vma=False)(y_rep)

    return jax.jit(local_steps), jax.jit(sync)


def run_layout_local_sgd(key, edge_sampler: EdgeSampler,
                         neg_sampler: NodeSampler, n_nodes: int, cfg,
                         mesh) -> LayoutResult:
    """Multi-device local-SGD layout driver (paper's async SGD, TPU form)."""
    n_dev = mesh.shape["data"]
    ky, kr = jax.random.split(key)
    y0 = (jax.random.normal(ky, (n_nodes, cfg.out_dim), jnp.float32)
          * cfg.init_scale)
    y_rep = jnp.broadcast_to(y0, (n_dev,) + y0.shape)
    from jax.sharding import NamedSharding, PartitionSpec as P
    y_rep = jax.device_put(y_rep, NamedSharding(mesh, P("data", None, None)))

    # every device applies a full batch per local step, so the per-replica
    # collision cap applies to each device's batch independently
    batch = _collision_capped_batch(cfg.batch_size, n_nodes)
    total = int(cfg.samples_per_node) * n_nodes
    steps = max(1, total // (batch * n_dev))
    H = max(1, cfg.sync_every)
    n_rounds = max(1, steps // H)
    local_steps, sync = make_local_sgd_fns(mesh, cfg, n_nodes, batch=batch)
    dt = 1.0 / max(steps, 1)
    for r in range(n_rounds):
        seed = jnp.asarray([int(jax.random.randint(
            jax.random.fold_in(kr, r), (), 0, 2**31 - 1))], jnp.int32)
        y_rep = local_steps(
            y_rep, seed, jnp.float32(r * H * dt), jnp.float32(dt),
            edge_sampler.src, edge_sampler.dst, edge_sampler.threshold,
            edge_sampler.alias, neg_sampler.threshold, neg_sampler.alias)
        y_rep = sync(y_rep)
    return LayoutResult(y=y_rep[0], steps=n_rounds * H,
                        edge_samples=n_rounds * H * batch * n_dev)


def run_layout(key, edge_sampler: EdgeSampler, neg_sampler: NodeSampler,
               n_nodes: int, cfg, *,
               callback: Optional[Callable] = None) -> LayoutResult:
    """Drive layout_step for T = samples_per_node * N edge samples."""
    ky, kr = jax.random.split(key)
    y = (jax.random.normal(ky, (n_nodes, cfg.out_dim), jnp.float32)
         * cfg.init_scale)
    total = int(cfg.samples_per_node) * n_nodes
    batch = _collision_capped_batch(cfg.batch_size, n_nodes, total)
    steps = max(1, total // batch)
    kwargs = dict(
        edge_src=edge_sampler.src, edge_dst=edge_sampler.dst,
        edge_thr=edge_sampler.threshold, edge_alias=edge_sampler.alias,
        neg_thr=neg_sampler.threshold, neg_alias=neg_sampler.alias,
        n_negatives=cfg.n_negatives, n_nodes=n_nodes, prob_fn=cfg.prob_fn,
        a=cfg.prob_a, gamma=cfg.gamma, clip=cfg.grad_clip, rho0=cfg.rho0,
        batch=batch)
    for t in range(steps):
        y = layout_step(y, jax.random.fold_in(kr, t),
                        jnp.float32(t / steps), **kwargs)
        if callback is not None and (t % max(1, steps // 20) == 0):
            callback(t, steps, y)
    return LayoutResult(y=y, steps=steps, edge_samples=steps * batch)
