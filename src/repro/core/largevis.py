"""LargeVis top-level API: data matrix in, 2D/3D layout out.

    from repro import LargeVis                 # the estimator front door
    model = LargeVis(n_neighbors=50).fit(x)
    coords = model.embedding_                  # (N, 2)

    from repro.core.largevis import largevis   # functional form
    result = largevis(x, key=jax.random.key(0))
    coords = result.y          # (N, 2)

``largevis()`` is the functional core the :class:`repro.LargeVis`
estimator wraps — both run the identical pipeline with the identical key
stream, so their outputs are bitwise-equal (pinned in tests/test_api.py).

Pipeline = the paper's two stages: (1) approximate KNN graph (projection
forest + neighbor exploring + perplexity-calibrated weights), (2)
probabilistic layout via edge-sampling SGD.

``LargeVisConfig(distributed=True, data_shards=P)`` routes stage 1
through the sharded multi-device pipeline (`core/knn_sharded.py`) — the
point set is sharded over a 1-D "data" mesh and the graph is built with
ring-streamed distance tiles (see README, "Multi-device on CPU").

Stage 2 steps through the scan-fused layout engine
(`core/layout_engine.py`): ``cfg.steps_per_dispatch`` SGD steps per
device dispatch with a donated coordinate buffer.  Passing a
``callback`` selects the per-step Python loop (one dispatch per step)
so progress can be observed mid-layout.

The stage-1 -> stage-2 hand-off is device-resident: with
``cfg.sampler_impl`` ``"device"``/``"auto"`` the alias tables are built
by a jitted sort/prefix-sum construction (`core/sampler.py`) directly
from the device graph, and the samplers flow into every layout driver
as JAX pytrees — no host materialization of ``idx``/``weights`` between
the stages, which is what keeps the boundary O(E log E) on device
instead of minutes of single-core Vose at the paper's E = 150M.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax

from repro.configs.largevis_default import LargeVisConfig
from repro.core import knn as knn_lib
from repro.core import layout as layout_lib
from repro.core import perplexity as perp_lib
from repro.core import sampler as sampler_lib
from repro.runtime.fault_tolerance import DegradedModeWarning, InjectedFault


@dataclasses.dataclass
class LargeVisResult:
    """Fitted-model carrier: everything the online operations need.

    Field contract (what ``transform`` reads vs what ``insert`` rewrites):

    * ``y``/``knn_idx``/``knn_dist``/``weights`` — the fitted embedding
      and graph.  ``transform`` treats ALL of them as **frozen**: a
      projection never mutates the carrier, and the corpus rows of the
      concat embedding it optimizes are bit-identical to ``y`` (the
      kernel's ``n_frozen`` masking — asserted in tests).  ``insert``
      **rewrites** them: rows are appended and existing rows may adopt
      new neighbors (graph + weights) — but never move in ``y``.
    * ``x`` — the corpus points (needed by ``transform``/``insert`` for
      query neighborhoods; ``None`` when built by the pre-PR-7 shim path
      that never captured inputs).
    * ``edge_sampler``/``neg_sampler`` — the alias-table pytrees from the
      stage boundary; ``transform`` draws negatives from ``neg_sampler``;
      ``insert`` rebuilds both.  ``None`` under ``distributed`` sharded
      layouts (per-shard tables stay on their mesh).
    * ``cfg``/``key`` — the exact config and top-level PRNG key of the
      fit, so any stage can be re-derived; frozen forever.
    * ``timings``/``edge_samples`` — diagnostics; ``insert`` leaves them
      describing the original fit.
    """
    y: jax.Array                 # (N, s) layout
    knn_idx: jax.Array           # (N, K)
    knn_dist: jax.Array          # (N, K) squared distances
    weights: jax.Array           # (N, K) symmetrized edge weights
    timings: dict
    edge_samples: int
    x: jax.Array | None = None           # (N, d) corpus points
    edge_sampler: object | None = None   # sampler.EdgeSampler pytree
    neg_sampler: object | None = None    # sampler.NodeSampler pytree
    cfg: LargeVisConfig | None = None
    key: jax.Array | None = None         # top-level fit key (pre-split)


def _apply_autotune_mode(cfg: LargeVisConfig) -> None:
    """Honor ``cfg.routing.autotune`` for this process.

    ``"auto"`` restores env control (the AUTOTUNE variable, default
    ``cache``); anything else pins the mode.  ``set_mode`` clears the jit
    caches only on an actual change, so repeated fits with the same
    setting pay nothing."""
    m = getattr(getattr(cfg, "routing", None), "autotune", "auto")
    from repro.runtime import autotune
    autotune.set_mode(None if m in ("auto", None) else m)


def _data_mesh(cfg: LargeVisConfig):
    """The 1-D "data" mesh every distributed stage shares."""
    from repro.launch.mesh import make_data_mesh
    return make_data_mesh(cfg.data_shards)


def _stage_ckpt(x, key, cfg: LargeVisConfig):
    """StageCheckpointer for the graph-prep stages, else None.

    Unlike the layout's, this fingerprint includes a strided sample of
    the DATA — resuming a prep stage against different points would
    silently hand stage 2 another dataset's graph."""
    if getattr(cfg, "checkpoint", None) is None:
        return None
    from repro.checkpoint.largevis_state import (StageCheckpointer,
                                                 run_fingerprint)
    return StageCheckpointer(cfg.checkpoint, run_fingerprint(x, key, cfg))


def build_graph(x, key, *, cfg: LargeVisConfig | None = None, fault=None):
    """Stage 1: KNN graph + calibrated weights.

    ``cfg`` is keyword-only as of PR 7 (``cfg=None`` means a fresh
    default — never the shared ``DEFAULT`` singleton).

    With ``cfg.distributed`` every sub-stage runs on the same 1-D
    "data" mesh: the ring-streamed KNN build, then row-parallel
    perplexity calibration and all-gather symmetrization
    (`core/perplexity.py` sharded drivers) — the graph never leaves the
    mesh between KNN and weights, and the sharded weights are
    bitwise-equal to the single-device path.

    With ``cfg.checkpoint`` each sub-stage result (``graph``: the KNN
    index/distances; ``weights``: the calibrated+symmetrized edge
    weights) is persisted atomically at its boundary and restored on a
    rerun — a kill anywhere in stage 1 resumes at the last completed
    sub-stage with bitwise-equal outputs (the graph is deterministic in
    ``(x, key, cfg)``, which is exactly what the fingerprint binds).
    Checkpoints are **topology-portable**: arrays are stored global with
    the writing mesh as a metadata tag, the fingerprint excludes the
    mesh shape, and restores re-shard onto the current mesh
    (``StageCheckpointer.restore``) — a run checkpointed on P devices
    resumes on any P', and the sharded graph-prep stages are themselves
    bitwise P-invariant, so the resumed outputs match a single-device
    run exactly (tests/test_elastic.py).
    ``fault`` fires at sites ``stage:graph`` / ``stage:weights`` after
    each boundary commits (the kill-matrix hook), plus the per-shard
    sites ``knn_ring_step:<s>`` / ``calibrate_shard:<s>`` /
    ``symmetrize_exchange:<s>`` inside the sharded stages — an injected
    shard fault surfaces as
    :class:`~repro.runtime.fault_tolerance.ShardFailedError` for the
    mesh-recovery loop in :func:`largevis`."""
    cfg = cfg if cfg is not None else LargeVisConfig()
    _apply_autotune_mode(cfg)
    ckpt = _stage_ckpt(x, key, cfg)
    mesh = _data_mesh(cfg) if cfg.distributed else None
    idx = dist = w = None
    topo = None
    if ckpt is not None:
        from repro.checkpoint.largevis_state import topology_tag
        topo = {"topology": topology_tag(cfg, x.shape[0])}
        jnp = jax.numpy
        cached = ckpt.restore("graph", mesh=mesh)
        if cached is not None:
            idx = jnp.asarray(cached[0]["idx"])
            dist = jnp.asarray(cached[0]["dist"])
        cached = ckpt.restore("weights", mesh=mesh)
        if cached is not None and idx is not None:
            w = jnp.asarray(cached[0]["w"])
    t0 = time.time()
    if idx is None:
        idx, dist = knn_lib.build_knn_graph(x, key, cfg, fault=fault)
        # block (no transfer) so knn_s/weights_s split the stages honestly —
        # async dispatch would otherwise smear KNN compute into weights_s
        jax.block_until_ready((idx, dist))
        if ckpt is not None:
            ckpt.save("graph", {"idx": idx, "dist": dist}, extra=topo)
        if fault is not None:
            fault.fire("stage:graph")
    t1 = time.time()
    if w is None:
        if cfg.distributed:
            w = perp_lib.edge_weights_sharded(idx, dist, cfg.perplexity,
                                              iters=cfg.perplexity_iters,
                                              mesh=mesh, fault=fault)
        else:
            w = perp_lib.edge_weights(idx, dist, cfg.perplexity,
                                      iters=cfg.perplexity_iters)
        jax.block_until_ready(w)
        if ckpt is not None:
            ckpt.save("weights", {"w": w}, extra=topo)
        if fault is not None:
            fault.fire("stage:weights")
    t2 = time.time()
    return idx, dist, w, {"knn_s": t1 - t0, "weights_s": t2 - t1}


def layout_graph(knn_idx, weights, key, *, cfg: LargeVisConfig | None = None,
                 callback=None, return_samplers: bool = False, fault=None):
    """Stage 2: probabilistic layout of a weighted KNN graph.

    ``cfg`` is keyword-only as of PR 7.  With ``return_samplers=True`` the
    return value grows to ``(res, (edge_sampler, neg_sampler), timings)``
    so fitted-model callers (``largevis()`` -> :class:`LargeVisResult`)
    can carry the stage-boundary pytrees without rebuilding them;
    sharded (``distributed``) samplers stay on their mesh and are
    surfaced as ``None``.

    ``cfg.sampler_impl`` selects the alias-table builder at the stage
    boundary: ``"device"`` (what ``"auto"`` resolves to) builds the tables
    in one jitted computation straight from the (possibly sharded) device
    graph — stage-1 outputs never round-trip through the host; ``"host"``
    is the numpy Vose oracle.  The ``sampler_s`` timing isolates table
    construction from the layout itself (tables are blocked on, so async
    dispatch cannot smear build time into ``layout_s``).

    With ``cfg.distributed`` the alias tables are built *per shard* on
    the data mesh (`sampler.build_samplers_sharded`: each shard owns the
    alias table over its own edges plus a tiny replicated
    shard-selection table) and the layout runs through the local-SGD
    driver with the edge tables left sharded — samplers stay
    device-resident pytrees end to end, exactly like the single-device
    boundary.

    Robustness: with ``cfg.checkpoint`` the single-device alias tables
    are persisted at the stage boundary (``samplers``) and the layout
    self-checkpoints per chunk (see ``run_layout``); a failed device
    sampler build demotes to the host Vose oracle with one
    ``DegradedModeWarning``.  The distributed path skips the sampler
    checkpoint (per-shard tables stay on their mesh; the build is
    deterministic and cheap to redo) and checkpoints the layout at round
    granularity.  ``fault`` fires ``stage:samplers`` after the boundary
    commits and threads into the layout driver."""
    cfg = cfg if cfg is not None else LargeVisConfig()
    _apply_autotune_mode(cfg)
    ckpt = None if cfg.distributed else _stage_ckpt(weights, key, cfg)
    edge_s = neg_s = None
    if ckpt is not None:
        from repro.checkpoint import largevis_state as lvs
        cached = ckpt.load("samplers")
        if cached is not None:
            tree, _, extra = cached
            edge_s, neg_s = lvs._samplers_from_tree(
                tree, extra["sampler_static"])
    t0 = time.time()
    if cfg.distributed:
        edge_s, neg_s = sampler_lib.build_samplers_sharded(
            knn_idx, weights, power=cfg.neg_power, mesh=_data_mesh(cfg))
    elif edge_s is None:
        try:
            edge_s = sampler_lib.build_edge_sampler(knn_idx, weights,
                                                    impl=cfg.sampler_impl)
            neg_s = sampler_lib.build_negative_sampler(knn_idx, weights,
                                                       power=cfg.neg_power,
                                                       impl=cfg.sampler_impl)
        except InjectedFault:
            raise
        except Exception as e:
            # degraded mode: a backend failure in the jitted device build
            # falls back to the numpy Vose oracle instead of crashing
            if cfg.sampler_impl == "host":
                raise
            warnings.warn(DegradedModeWarning(
                "sampler_build", cfg.sampler_impl, "host", e), stacklevel=2)
            edge_s = sampler_lib.build_edge_sampler(knn_idx, weights,
                                                    impl="host")
            neg_s = sampler_lib.build_negative_sampler(knn_idx, weights,
                                                       power=cfg.neg_power,
                                                       impl="host")
        jax.block_until_ready((edge_s.threshold, neg_s.threshold))
        if ckpt is not None:
            from repro.checkpoint import largevis_state as lvs
            tree, static = lvs._samplers_to_tree(edge_s, neg_s)
            ckpt.save("samplers", tree, extra={"sampler_static": static})
        if fault is not None:
            fault.fire("stage:samplers")
    jax.block_until_ready((edge_s.threshold, neg_s.threshold))
    t1 = time.time()
    if cfg.distributed:
        res = layout_lib.run_layout_local_sgd(key, edge_s, neg_s,
                                              knn_idx.shape[0], cfg,
                                              _data_mesh(cfg), fault=fault,
                                              weights=weights)
    else:
        res = layout_lib.run_layout(key, edge_s, neg_s, knn_idx.shape[0],
                                    cfg, callback=callback, fault=fault)
    t2 = time.time()
    timings = {"sampler_s": t1 - t0, "layout_s": t2 - t1}
    if return_samplers:
        samplers = (None, None) if cfg.distributed else (edge_s, neg_s)
        return res, samplers, timings
    return res, timings


def largevis(x, key=None, *, cfg: LargeVisConfig | None = None,
             callback=None, fault=None) -> LargeVisResult:
    """Run the full pipeline; the functional core of :class:`repro.LargeVis`.

    ``cfg`` is keyword-only as of PR 7.  The result is a full fitted-model
    carrier (corpus points, samplers, cfg, key), so ``repro.core.transform``
    and the estimator's online operations can run against it directly.

    Crash safety (PR 8): set ``cfg.checkpoint`` and rerun the *same call*
    after a crash — each completed stage (``graph``, ``weights``,
    ``samplers``, per-chunk ``layout``) restores from disk and the final
    embedding is bitwise-equal to an uninterrupted run (tests/test_resume.py
    kills at every boundary).  ``fault`` takes a
    :class:`~repro.runtime.fault_tolerance.FaultInjector` for those tests.

    Elasticity (PR 10): stage checkpoints are topology-portable, and a
    shard lost mid-run (``ShardFailedError`` from a per-shard fault
    site, or a real device drop surfaced the same way) does not kill
    the job — one :class:`DegradedModeWarning` is emitted, the mesh is
    rebuilt with half the shards (``data_shards: P -> max(1, P//2)``)
    and the pipeline re-enters from the last committed stage via the
    re-shard restore path.  Only an unrecoverable failure (already at
    one shard) propagates.  When checkpointing is enabled a
    :class:`~repro.runtime.fault_tolerance.PreemptionGuard` is armed
    for the duration of the fit: SIGTERM/SIGINT runs a synchronous save
    of the newest layout state before the process exits by the signal.
    """
    cfg = cfg if cfg is not None else LargeVisConfig()
    _apply_autotune_mode(cfg)
    if key is None:
        key = jax.random.key(cfg.seed)
    from repro.runtime.fault_tolerance import (PreemptionGuard,
                                               ShardFailedError)
    guard = None
    if getattr(cfg, "checkpoint", None) is not None \
            and PreemptionGuard.active() is None:
        import signal as _signal
        guard = PreemptionGuard(signals=(_signal.SIGTERM, _signal.SIGINT),
                                exit_after_save=True).activate()
    try:
        while True:
            try:
                return _largevis_once(x, key, cfg=cfg, callback=callback,
                                      fault=fault)
            except ShardFailedError as e:
                if not cfg.distributed:
                    raise
                n_shards = int(_data_mesh(cfg).shape["data"])
                if n_shards <= 1:
                    raise       # nothing left to shed — a real failure
                new_shards = max(1, n_shards // 2)
                warnings.warn(DegradedModeWarning(
                    e.stage, f"mesh[{n_shards}]", f"mesh[{new_shards}]", e),
                    stacklevel=2)
                # injector hit counts persist across the retry, so the
                # same injected fault cannot re-fire on the smaller mesh
                cfg = dataclasses.replace(cfg, data_shards=new_shards)
    finally:
        if guard is not None:
            guard.restore_handlers()


def _largevis_once(x, key, *, cfg, callback, fault):
    """One pipeline pass on cfg's current mesh (see :func:`largevis`)."""
    kg, kl = jax.random.split(key)
    idx, dist, w, t_graph = build_graph(x, kg, cfg=cfg, fault=fault)
    res, (edge_s, neg_s), t_layout = layout_graph(
        idx, w, kl, cfg=cfg, callback=callback, return_samplers=True,
        fault=fault)
    return LargeVisResult(y=res.y, knn_idx=idx, knn_dist=dist, weights=w,
                          timings={**t_graph, **t_layout},
                          edge_samples=res.edge_samples,
                          x=jax.numpy.asarray(x), edge_sampler=edge_s,
                          neg_sampler=neg_s, cfg=cfg, key=key)
