"""LargeVis top-level API: data matrix in, 2D/3D layout out.

    from repro.core.largevis import largevis
    result = largevis(x, key=jax.random.key(0))
    coords = result.y          # (N, 2)

Pipeline = the paper's two stages: (1) approximate KNN graph (projection
forest + neighbor exploring + perplexity-calibrated weights), (2)
probabilistic layout via edge-sampling SGD.

``LargeVisConfig(distributed=True, data_shards=P)`` routes stage 1
through the sharded multi-device pipeline (`core/knn_sharded.py`) — the
point set is sharded over a 1-D "data" mesh and the graph is built with
ring-streamed distance tiles (see README, "Multi-device on CPU").

Stage 2 steps through the scan-fused layout engine
(`core/layout_engine.py`): ``cfg.steps_per_dispatch`` SGD steps per
device dispatch with a donated coordinate buffer.  Passing a
``callback`` selects the per-step Python loop (one dispatch per step)
so progress can be observed mid-layout.

The stage-1 -> stage-2 hand-off is device-resident: with
``cfg.sampler_impl`` ``"device"``/``"auto"`` the alias tables are built
by a jitted sort/prefix-sum construction (`core/sampler.py`) directly
from the device graph, and the samplers flow into every layout driver
as JAX pytrees — no host materialization of ``idx``/``weights`` between
the stages, which is what keeps the boundary O(E log E) on device
instead of minutes of single-core Vose at the paper's E = 150M.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs.largevis_default import DEFAULT, LargeVisConfig
from repro.core import knn as knn_lib
from repro.core import layout as layout_lib
from repro.core import perplexity as perp_lib
from repro.core import sampler as sampler_lib


@dataclasses.dataclass
class LargeVisResult:
    y: jax.Array                 # (N, s) layout
    knn_idx: jax.Array           # (N, K)
    knn_dist: jax.Array          # (N, K) squared distances
    weights: jax.Array           # (N, K) symmetrized edge weights
    timings: dict
    edge_samples: int


def _data_mesh(cfg: LargeVisConfig):
    """The 1-D "data" mesh every distributed stage shares."""
    from repro.launch.mesh import make_data_mesh
    return make_data_mesh(cfg.data_shards)


def build_graph(x, key, cfg: LargeVisConfig = DEFAULT):
    """Stage 1: KNN graph + calibrated weights.

    With ``cfg.distributed`` every sub-stage runs on the same 1-D
    "data" mesh: the ring-streamed KNN build, then row-parallel
    perplexity calibration and all-gather symmetrization
    (`core/perplexity.py` sharded drivers) — the graph never leaves the
    mesh between KNN and weights, and the sharded weights are
    bitwise-equal to the single-device path."""
    t0 = time.time()
    idx, dist = knn_lib.build_knn_graph(x, key, cfg)
    # block (no transfer) so knn_s/weights_s split the stages honestly —
    # async dispatch would otherwise smear KNN compute into weights_s
    jax.block_until_ready((idx, dist))
    t1 = time.time()
    if cfg.distributed:
        w = perp_lib.edge_weights_sharded(idx, dist, cfg.perplexity,
                                          iters=cfg.perplexity_iters,
                                          mesh=_data_mesh(cfg))
    else:
        w = perp_lib.edge_weights(idx, dist, cfg.perplexity,
                                  iters=cfg.perplexity_iters)
    jax.block_until_ready(w)
    t2 = time.time()
    return idx, dist, w, {"knn_s": t1 - t0, "weights_s": t2 - t1}


def layout_graph(knn_idx, weights, key, cfg: LargeVisConfig = DEFAULT,
                 callback=None):
    """Stage 2: probabilistic layout of a weighted KNN graph.

    ``cfg.sampler_impl`` selects the alias-table builder at the stage
    boundary: ``"device"`` (what ``"auto"`` resolves to) builds the tables
    in one jitted computation straight from the (possibly sharded) device
    graph — stage-1 outputs never round-trip through the host; ``"host"``
    is the numpy Vose oracle.  The ``sampler_s`` timing isolates table
    construction from the layout itself (tables are blocked on, so async
    dispatch cannot smear build time into ``layout_s``).

    With ``cfg.distributed`` the alias tables are built *per shard* on
    the data mesh (`sampler.build_samplers_sharded`: each shard owns the
    alias table over its own edges plus a tiny replicated
    shard-selection table) and the layout runs through the local-SGD
    driver with the edge tables left sharded — samplers stay
    device-resident pytrees end to end, exactly like the single-device
    boundary."""
    t0 = time.time()
    if cfg.distributed:
        edge_s, neg_s = sampler_lib.build_samplers_sharded(
            knn_idx, weights, power=cfg.neg_power, mesh=_data_mesh(cfg))
    else:
        edge_s = sampler_lib.build_edge_sampler(knn_idx, weights,
                                                impl=cfg.sampler_impl)
        neg_s = sampler_lib.build_negative_sampler(knn_idx, weights,
                                                   power=cfg.neg_power,
                                                   impl=cfg.sampler_impl)
    jax.block_until_ready((edge_s.threshold, neg_s.threshold))
    t1 = time.time()
    if cfg.distributed:
        res = layout_lib.run_layout_local_sgd(key, edge_s, neg_s,
                                              knn_idx.shape[0], cfg,
                                              _data_mesh(cfg))
    else:
        res = layout_lib.run_layout(key, edge_s, neg_s, knn_idx.shape[0],
                                    cfg, callback=callback)
    t2 = time.time()
    return res, {"sampler_s": t1 - t0, "layout_s": t2 - t1}


def largevis(x, key=None, cfg: LargeVisConfig = DEFAULT,
             callback=None) -> LargeVisResult:
    if key is None:
        key = jax.random.key(cfg.seed)
    kg, kl = jax.random.split(key)
    idx, dist, w, t_graph = build_graph(x, kg, cfg)
    res, t_layout = layout_graph(idx, w, kl, cfg, callback=callback)
    return LargeVisResult(y=res.y, knn_idx=idx, knn_dist=dist, weights=w,
                          timings={**t_graph, **t_layout},
                          edge_samples=res.edge_samples)
