"""Out-of-sample projection + incremental graph maintenance.

The paper's pipeline is batch-only: the layout exists for exactly the
points the KNN graph was built over.  This module adds the two *online*
operations on top of a fitted model, reusing the batch machinery:

* :func:`project` — embed Q held-out queries into a FROZEN fitted layout.
  One ``ops.topk_sqdist(queries, corpus, k)`` call finds each query's
  corpus neighborhood; the existing row-local perplexity bisection
  (``perplexity.calibrate_p``) turns the neighbor distances into a
  per-query distribution p_{.|q} (Eqn 1 applied to the query row); each
  query initializes at the p-weighted mean of its neighbors' fitted
  coordinates and then runs a short scan of the SAME fused edge step the
  batch layout uses (``layout_engine.apply_edge_batch``) over the concat
  embedding [corpus; queries] with ``n_frozen = N`` — corpus rows
  contribute attractive/repulsive forces but their updates are masked to
  -0.0 inside the kernel, so the fitted embedding stays BIT-identical
  (asserted in tests/test_transform.py).  Positive edges are drawn
  q -> neighbor ∝ p_{.|q} (the alias-sampler analogue for a row-local
  distribution is one ``categorical``), negatives from the fitted noise
  sampler.

* :func:`knn_insert` — grow the (N, K) KNN graph by Q new points without
  a rebuild.  New rows get one streaming top-k against the corpus merged
  (``knn.merge_candidates``) with a query-vs-query top-k; existing rows
  adopt new points through a reverse-candidate scatter (the
  ``neighbor_explore.reverse_neighbors`` sorted-scatter pattern, carrying
  distances along); then ``neighbor_explore(rows=touched)`` repairs only
  the affected rows through the standard exploring machinery.  Recall
  against a fresh build is pinned in tests/test_transform.py.

Both entry points are wrapped by the :class:`repro.LargeVis` estimator
(``transform`` / ``insert``); the continuous-batching projection server
(``launch/serve_projection.py``) drives :func:`sample_query_edges` +
``apply_edge_batch`` directly with per-slot learning rates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.largevis_default import LargeVisConfig
from repro.core import knn as knn_lib
from repro.core import neighbor_explore as explore_lib
from repro.core import perplexity as perp_lib
from repro.core.layout_engine import apply_edge_batch
from repro.core.sampler import NodeSampler
from repro.kernels import ops


def uniform_node_sampler(n: int) -> NodeSampler:
    """Uniform noise distribution as a degenerate alias table (threshold 1
    everywhere -> every draw keeps its uniform bin).  The fallback when a
    fitted negative sampler is not available."""
    return NodeSampler(threshold=jnp.ones((n,), jnp.float32),
                       alias=jnp.arange(n, dtype=jnp.int32), n_nodes=n)


def query_neighbors(x_new, x, k: int, *, impl: str = "auto"):
    """Each query's k nearest corpus points: ids (Q, k), sqdists (Q, k).

    One streaming fused distance->top-k call — no (Q, N) distance matrix
    at any Q/N (see ``kernels.ops.topk_sqdist``)."""
    return ops.topk_sqdist(jnp.asarray(x_new), jnp.asarray(x), k, impl=impl)


@jax.jit
def _weighted_mean_init(p, nn_idx, y):
    """Init each query at the p-weighted mean of its neighbors' coords."""
    return jnp.einsum("qk,qks->qs", p, y[nn_idx])


def sample_query_edges(key, p_log, nn_idx, neg_sampler, n_negatives: int):
    """One positive + M negatives per query row.

    Positive: neighbor column ∝ exp(p_log) per row (the row-local analogue
    of the batch pipeline's alias edge sampling).  Negatives: the fitted
    noise distribution; collisions with the positive are masked exactly as
    in ``layout_engine.sgd_edge_step``.  Returns (j, negs, neg_mask)."""
    kj, kn = jax.random.split(key)
    cols = jax.random.categorical(kj, p_log, axis=-1)            # (Q,)
    j = jnp.take_along_axis(nn_idx, cols[:, None], axis=1)[:, 0]
    negs = neg_sampler.sample(kn, (p_log.shape[0], n_negatives))
    neg_mask = (negs != j[:, None]).astype(jnp.float32)
    return j, negs, neg_mask


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("n_negatives", "steps", "rho0",
                                    "prob_fn", "a", "gamma", "clip",
                                    "fused_step"))
def _project_scan(y_full, base_key, p_log, nn_idx, neg_sampler, *,
                  n_negatives: int, steps: int, rho0: float,
                  prob_fn: str, a: float, gamma: float, clip: float,
                  fused_step: bool):
    """``steps`` frozen-corpus SGD steps over [corpus; queries].

    ``y_full`` is donated (one (N+Q, s) buffer for the whole scan); rows
    below ``N+Q - Q`` are frozen via the kernel's ``n_frozen`` masking.
    The (key, lr) stream mirrors ``scan_layout_steps``: step k folds k
    into ``base_key`` and sits at schedule position k/steps."""
    n_frozen = y_full.shape[0] - p_log.shape[0]
    q = p_log.shape[0]
    i = n_frozen + jnp.arange(q, dtype=jnp.int32)
    step_ids = jnp.arange(steps, dtype=jnp.int32)
    t_fracs = step_ids.astype(jnp.float32) / steps

    def one(y, sx):
        sid, tf = sx
        key = jax.random.fold_in(base_key, sid)
        j, negs, neg_mask = sample_query_edges(
            key, p_log, nn_idx, neg_sampler, n_negatives)
        lr = rho0 * jnp.maximum(1.0 - tf, 1e-4)
        y = apply_edge_batch(
            y, i, j, negs, neg_mask, lr, prob_fn=prob_fn, a=a, gamma=gamma,
            clip=clip, fused_step=fused_step, n_frozen=n_frozen)
        return y, None

    y_full, _ = jax.lax.scan(one, y_full, (step_ids, t_fracs))
    return y_full


def project(x_new, *, x, y, key=None, cfg: LargeVisConfig | None = None,
            neg_sampler=None, nn_idx=None, nn_dist=None):
    """Project queries into a fitted layout; the corpus never moves.

    x_new (Q, d) queries; x (N, d) fitted corpus points; y (N, s) fitted
    layout.  ``neg_sampler`` is the fitted noise :class:`NodeSampler`
    (uniform fallback when absent); ``nn_idx``/``nn_dist`` skip the
    corpus top-k when the caller already has the query neighborhoods
    (the serving engine batches that call across admits).

    Returns ``(y_new (Q, s), aux)`` with ``aux = {nn_idx, nn_dist, p}``
    — the query neighborhoods feed :func:`knn_insert` and the estimator's
    ``insert``.
    """
    cfg = cfg if cfg is not None else LargeVisConfig()
    if key is None:
        key = jax.random.key(cfg.seed)
    x_new = jnp.asarray(x_new)
    n = x.shape[0]
    if x_new.shape[0] == 0:
        return jnp.zeros((0, y.shape[1]), y.dtype), {
            "nn_idx": jnp.zeros((0, min(cfg.n_neighbors, n)), jnp.int32),
            "nn_dist": jnp.zeros((0, min(cfg.n_neighbors, n)), jnp.float32),
            "p": jnp.zeros((0, min(cfg.n_neighbors, n)), jnp.float32)}
    k = min(cfg.n_neighbors, n)
    if nn_idx is None:
        nn_idx, nn_dist = query_neighbors(x_new, x, k)
    p = perp_lib.calibrate_p(nn_dist, min(cfg.perplexity, float(k)),
                             iters=cfg.perplexity_iters)
    y0 = _weighted_mean_init(p, nn_idx, jnp.asarray(y))
    y_full = jnp.concatenate([jnp.asarray(y, jnp.float32),
                              y0.astype(jnp.float32)])
    if neg_sampler is None:
        neg_sampler = uniform_node_sampler(n)
    rho0 = cfg.transform_rho0 or cfg.rho0
    y_full = _project_scan(
        y_full, key, jnp.log(p), nn_idx, neg_sampler,
        n_negatives=cfg.n_negatives, steps=int(cfg.transform_steps),
        rho0=float(rho0), prob_fn=cfg.prob_fn, a=cfg.prob_a,
        gamma=cfg.gamma, clip=cfg.grad_clip, fused_step=bool(cfg.fused_step))
    return y_full[n:], {"nn_idx": nn_idx, "nn_dist": nn_dist, "p": p}


# ---------------------------------------------------------------------------
# Incremental KNN graph maintenance
# ---------------------------------------------------------------------------

def _reverse_candidates(dst, src, dist, n: int, r_cap: int):
    """Scatter directed candidate edges (src -> dst) into per-``dst`` slots.

    The ``neighbor_explore.reverse_neighbors`` sorted-scatter (sort by
    destination, rank within segment, cap at ``r_cap``), extended to carry
    the candidate distance along.  Unfilled slots hold the row's own index
    at INF distance — inert under ``merge_candidates``."""
    e = dst.shape[0]
    order = jnp.argsort(dst)
    dst_s, src_s, d_s = dst[order], src[order], dist[order]
    seg_start = jnp.searchsorted(dst_s, jnp.arange(n))
    rank = jnp.arange(e) - seg_start[dst_s]
    keep = rank < r_cap
    slot = jnp.clip(rank, 0, r_cap - 1)
    ids = jnp.full((n, r_cap), -1, jnp.int32)
    ids = ids.at[dst_s, slot].set(jnp.where(keep, src_s, -1))
    ds = jnp.full((n, r_cap), knn_lib.INF, jnp.float32)
    ds = ds.at[dst_s, slot].set(jnp.where(keep, d_s, knn_lib.INF))
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    return jnp.where(ids < 0, rows, ids), ds


@functools.partial(jax.jit, static_argnames=("k",))
def _insert_merge(x, knn_idx, knn_dist, x_new, qc_idx, qc_dist, *, k: int):
    """Pure merge step of :func:`knn_insert`: build the (N+Q, k) graph.

    Query rows: corpus top-k merged with a query-vs-query top-k (global
    ids N..N+Q-1).  Corpus rows: existing lists merged with the reverse
    candidates induced by the queries' corpus neighborhoods."""
    n, q = x.shape[0], x_new.shape[0]
    self_q = n + jnp.arange(q, dtype=jnp.int32)

    # --- query rows -----------------------------------------------------
    kq = min(k, q)
    qq_idx, qq_dist = ops.topk_sqdist(x_new, x_new, kq)
    q_ids = jnp.concatenate([qc_idx, n + qq_idx], axis=1)
    q_ds = jnp.concatenate([qc_dist, qq_dist], axis=1)
    q_idx, q_dist = knn_lib.merge_candidates(q_ids, q_ds, k, self_idx=self_q)

    # --- corpus rows: adopt new points via reverse candidates -----------
    rev_ids, rev_ds = _reverse_candidates(
        qc_idx.reshape(-1),
        jnp.repeat(self_q, qc_idx.shape[1]),
        qc_dist.reshape(-1), n, r_cap=min(k, max(q, 1)))
    c_ids = jnp.concatenate([knn_idx, rev_ids], axis=1)
    c_ds = jnp.concatenate([knn_dist, rev_ds], axis=1)
    c_idx, c_dist = knn_lib.merge_candidates(
        c_ids, c_ds, k, self_idx=jnp.arange(n, dtype=jnp.int32))

    changed = jnp.any((c_idx != knn_idx) | (c_dist != knn_dist), axis=1)
    return (jnp.concatenate([c_idx, q_idx]),
            jnp.concatenate([c_dist, q_dist]), changed)


def knn_insert(x, knn_idx, knn_dist, x_new, *, key=None,
               cfg: LargeVisConfig | None = None, explore_iters: int = 1,
               qc_idx=None, qc_dist=None):
    """Insert Q new points into an (N, K) KNN graph without a rebuild.

    Returns ``(x_all (N+Q, d), knn_idx (N+Q, K), knn_dist (N+Q, K))``.

    Three phases: (1) one streaming top-k gives each new point its corpus
    neighborhood (reused from :func:`project` via ``qc_idx``/``qc_dist``
    when available); (2) a jitted merge splices the new rows in and lets
    corpus rows adopt closer new points through a reverse-candidate
    scatter; (3) ``explore_iters`` rounds of neighbor exploring over ONLY
    the touched rows (new rows + corpus rows whose lists changed) repair
    second-order effects — "a neighbor of my (new) neighbor" — through
    the same machinery the batch build uses, at O(touched) not O(N).
    """
    cfg = cfg if cfg is not None else LargeVisConfig()
    if key is None:
        key = jax.random.key(cfg.seed)
    x = jnp.asarray(x)
    x_new = jnp.asarray(x_new, x.dtype)
    n, k = knn_idx.shape
    if x_new.shape[0] == 0:
        return x, knn_idx, knn_dist
    if qc_idx is None:
        qc_idx, qc_dist = query_neighbors(x_new, x, k)
    x_all = jnp.concatenate([x, x_new])
    idx_all, dist_all, changed = _insert_merge(
        x, knn_idx, knn_dist, x_new, qc_idx, qc_dist, k=k)
    if explore_iters:
        touched = np.concatenate([
            np.nonzero(np.asarray(changed))[0],
            np.arange(n, n + x_new.shape[0])]).astype(np.int32)
        idx_all, dist_all = explore_lib.neighbor_explore(
            x_all, idx_all, dist_all, iters=explore_iters,
            sample=cfg.explore_sample, key=key, rows=jnp.asarray(touched))
    return x_all, idx_all, dist_all
