"""Sharded, atomic, rotating checkpoints (tensorstore-free: npz shards).

Layout:  <dir>/step_<N>/
            meta.json              tree structure + shapes + step + version
            shard_<i>.npz          flattened leaves (host-gathered)
            _COMMITTED             written LAST -> crash-safe atomicity

Fault-tolerance contract (tested in tests/test_fault_tolerance.py and
tests/test_checkpoint.py):
  * save is atomic: a checkpoint without _COMMITTED is ignored on restore
    (a process killed mid-save can never corrupt training);
  * restore() -> bit-identical state -> bit-identical training continuation;
  * corruption detection: every shard file's CRC32 is recorded in
    meta.json; a committed-but-damaged checkpoint (bit rot, truncated
    write that still renamed, manual tampering) fails verification and
    ``restore()`` falls back to the newest *older* checkpoint that loads
    cleanly instead of crashing or silently returning garbage;
  * versioned schema: meta.json carries ``version`` (the on-disk format)
    and a free-form ``schema`` tag (what the tree *is* — e.g.
    ``largevis-result-v1``); readers reject formats newer than they
    understand and schema tags they did not expect;
  * elastic restore: leaves are saved UNSHARDED (host-gathered), so a run
    checkpointed on P devices restores onto P' devices with any sharding
    (the loader re-shards with jax.device_put against the new mesh).
"""
from __future__ import annotations

import io
import json
import os
import pathlib
import shutil
import time
import warnings
import zlib
from typing import Optional

import jax
import numpy as np

# on-disk format version.  v1 (pre-PR-8) has no "version"/"crc" fields and
# is still readable (CRC verification is skipped for it); v2 adds them.
FORMAT_VERSION = 2


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint failed verification (CRC/shape/parse)."""


class CheckpointIncompatibleError(RuntimeError):
    """A committed, uncorrupted checkpoint that this process cannot use
    (e.g. its topology tag names more shards than there are rows to
    re-shard after a mesh shrink at tiny N).  In the ``step=None``
    fallback walk it is skipped like corruption — an older compatible
    checkpoint wins over a hard failure inside the re-shard path."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _crc(path: pathlib.Path) -> int:
    crc = 0
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(block, crc)
    return crc


def save(ckpt_dir, step: int, tree, *, keep: int = 3,
         shard_mb: int = 512, schema: str = "pytree",
         extra_meta: Optional[dict] = None) -> pathlib.Path:
    """Write one checkpoint; returns its path.

    ``schema`` tags what the tree is (validated by loaders that expect a
    specific layout); ``extra_meta`` is an arbitrary JSON-able dict stored
    in meta.json (returned by ``restore(..., return_meta=True)``)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    tmp = ckpt_dir / f"_tmp_step_{step}_{os.getpid()}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    # host-gather (works for sharded or replicated arrays)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    meta = {"version": FORMAT_VERSION, "schema": schema,
            "step": step, "treedef": jax.tree_util.tree_structure(
                tree).serialize_using_proto().hex(),
            "n_leaves": len(host), "time": time.time(),
            "shapes": [list(h.shape) for h in host],
            "dtypes": [str(h.dtype) for h in host]}
    if extra_meta:
        meta["extra"] = extra_meta

    def _write_shard(idx: int, leaves_dict: dict) -> tuple[str, int]:
        # build the npz in memory so the CRC comes from the exact bytes
        # about to hit disk (one write syscall, no read-back pass)
        buf = io.BytesIO()
        np.savez(buf, **leaves_dict)
        data = buf.getbuffer()
        (tmp / f"shard_{idx}.npz").write_bytes(data)
        return f"shard_{idx}.npz", zlib.crc32(data)

    budget = shard_mb * (1 << 20)
    shard, size, shard_idx, index, shard_crc = {}, 0, 0, [], {}
    for i, h in enumerate(host):
        shard[f"leaf_{i}"] = h
        size += h.nbytes
        index.append(shard_idx)
        if size >= budget:
            name, crc = _write_shard(shard_idx, shard)
            shard_crc[name] = crc
            shard, size = {}, 0
            shard_idx += 1
    if shard:
        name, crc = _write_shard(shard_idx, shard)
        shard_crc[name] = crc
    meta["leaf_shard"] = index
    meta["shard_crc"] = shard_crc  # per-shard CRC32 (bit rot guard)
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / "_COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic on same filesystem
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def all_steps(ckpt_dir) -> list:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "_COMMITTED").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_step(path: pathlib.Path, *, expect_schema: Optional[str] = None):
    """Load + verify one committed checkpoint directory.

    Raises :class:`CheckpointCorruptError` on any damage (unparseable
    meta, missing/truncated/bit-rotted shards, leaf mismatch) and
    ``ValueError`` on format/schema incompatibility."""
    try:
        meta = json.loads((path / "meta.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"{path}: unreadable meta.json: {e}")
    version = int(meta.get("version", 1))
    if version > FORMAT_VERSION:
        raise ValueError(
            f"{path}: checkpoint format v{version} is newer than this "
            f"reader (v{FORMAT_VERSION}) — upgrade the code, not the data")
    if expect_schema is not None:
        schema = meta.get("schema", "pytree")
        if schema != expect_schema:
            raise ValueError(
                f"{path}: schema {schema!r} != expected {expect_schema!r}")
    for name, want_crc in meta.get("shard_crc", {}).items():
        p = path / name
        if not p.exists():
            raise CheckpointCorruptError(f"{path}: missing shard {name}")
        if _crc(p) != want_crc:
            raise CheckpointCorruptError(f"{path}: CRC mismatch in {name}")
    td_cls = type(jax.tree_util.tree_structure(0))
    treedef = td_cls.deserialize_using_proto(
        jax.tree_util.default_registry, bytes.fromhex(meta["treedef"]))
    shards = {}
    leaves = []
    try:
        for i, sh_idx in enumerate(meta["leaf_shard"]):
            if sh_idx not in shards:
                shards[sh_idx] = np.load(path / f"shard_{sh_idx}.npz")
            leaves.append(shards[sh_idx][f"leaf_{i}"])
    except Exception as e:              # truncated npz, missing key, ...
        raise CheckpointCorruptError(f"{path}: unreadable shards: {e}")
    if len(leaves) != meta["n_leaves"]:
        raise CheckpointCorruptError(
            f"{path}: {len(leaves)} leaves != recorded {meta['n_leaves']}")
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def restore(ckpt_dir, step: Optional[int] = None, *, shardings=None,
            like=None, expect_schema: Optional[str] = None,
            return_meta: bool = False, validate=None):
    """Load a checkpoint.

    ``step=None`` loads the NEWEST committed checkpoint that passes
    verification — a committed-but-corrupt directory (CRC mismatch,
    truncated shard) is skipped with a warning and the previous one is
    tried, so one damaged save never loses the run.  An explicit ``step``
    raises on damage instead of falling back.

    shardings: optional pytree of NamedShardings to re-shard onto (elastic
    restore onto a different mesh/device count).  like: optional pytree
    for structure validation.  ``return_meta=True`` appends the meta dict
    to the return tuple.  ``validate``: optional ``fn(meta) -> None``
    applied to each candidate's metadata before it is accepted; raising
    ``ValueError``/:class:`CheckpointIncompatibleError` rejects the
    candidate — skipped (with a warning) in the fallback walk, raised
    for an explicit ``step``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        candidates = sorted(all_steps(ckpt_dir), reverse=True)
        if not candidates:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    else:
        candidates = [step]
    tree = meta = None
    last_err: Optional[Exception] = None
    for s in candidates:
        path = ckpt_dir / f"step_{s}"
        assert (path / "_COMMITTED").exists(), f"uncommitted checkpoint {path}"
        try:
            tree, meta = _load_step(path, expect_schema=expect_schema)
            if validate is not None:
                try:
                    validate(meta)
                except (ValueError, CheckpointIncompatibleError) as e:
                    raise CheckpointIncompatibleError(f"{path}: {e}") from e
            break
        except (CheckpointCorruptError, CheckpointIncompatibleError) as e:
            if step is not None:
                raise
            kind = ("incompatible"
                    if isinstance(e, CheckpointIncompatibleError)
                    else "corrupt")
            warnings.warn(f"skipping {kind} checkpoint: {e}",
                          RuntimeWarning, stacklevel=2)
            last_err = e
            tree = meta = None
    if tree is None:
        raise CheckpointCorruptError(
            f"every committed checkpoint in {ckpt_dir} failed verification "
            f"(last error: {last_err})")
    if like is not None:
        jax.tree_util.tree_structure(like)  # raises on mismatch when mapped
        tree = jax.tree.map(lambda want, got: got.astype(want.dtype), like,
                            tree)
    if shardings is not None:
        tree = jax.tree.map(
            lambda arr, s: jax.device_put(arr, s), tree, shardings)
    if return_meta:
        return tree, meta["step"], meta
    return tree, meta["step"]
