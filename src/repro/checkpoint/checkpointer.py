"""Sharded, atomic, rotating checkpoints (tensorstore-free: npz shards).

Layout:  <dir>/step_<N>/
            meta.json              tree structure + shapes + step
            shard_<i>.npz          flattened leaves (host-gathered)
            _COMMITTED             written LAST -> crash-safe atomicity

Fault-tolerance contract (tested in tests/test_fault_tolerance.py):
  * save is atomic: a checkpoint without _COMMITTED is ignored on restore
    (a process killed mid-save can never corrupt training);
  * restore() -> bit-identical state -> bit-identical training continuation;
  * elastic restore: leaves are saved UNSHARDED (host-gathered), so a run
    checkpointed on P devices restores onto P' devices with any sharding
    (the loader re-shards with jax.device_put against the new mesh).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree, *, keep: int = 3,
         shard_mb: int = 512) -> pathlib.Path:
    """Write one checkpoint; returns its path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    tmp = ckpt_dir / f"_tmp_step_{step}_{os.getpid()}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    # host-gather (works for sharded or replicated arrays)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    meta = {"step": step, "treedef": jax.tree_util.tree_structure(
        tree).serialize_using_proto().hex(),
        "n_leaves": len(host), "time": time.time(),
        "shapes": [list(h.shape) for h in host],
        "dtypes": [str(h.dtype) for h in host]}

    budget = shard_mb * (1 << 20)
    shard, size, shard_idx, index = {}, 0, 0, []
    for i, h in enumerate(host):
        shard[f"leaf_{i}"] = h
        size += h.nbytes
        index.append(shard_idx)
        if size >= budget:
            np.savez(tmp / f"shard_{shard_idx}.npz", **shard)
            shard, size = {}, 0
            shard_idx += 1
    if shard:
        np.savez(tmp / f"shard_{shard_idx}.npz", **shard)
    meta["leaf_shard"] = index
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / "_COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic on same filesystem
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def all_steps(ckpt_dir) -> list:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "_COMMITTED").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, step: Optional[int] = None, *, shardings=None,
            like=None):
    """Load a checkpoint.  shardings: optional pytree of NamedShardings to
    re-shard onto (elastic restore onto a different mesh/device count).
    like: optional pytree for structure validation."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = ckpt_dir / f"step_{step}"
    assert (path / "_COMMITTED").exists(), f"uncommitted checkpoint {path}"
    meta = json.loads((path / "meta.json").read_text())
    td_cls = type(jax.tree_util.tree_structure(0))
    treedef = td_cls.deserialize_using_proto(
        jax.tree_util.default_registry, bytes.fromhex(meta["treedef"]))
    shards = {}
    leaves = []
    for i, sh_idx in enumerate(meta["leaf_shard"]):
        if sh_idx not in shards:
            shards[sh_idx] = np.load(path / f"shard_{sh_idx}.npz")
        leaves.append(shards[sh_idx][f"leaf_{i}"])
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if like is not None:
        jax.tree_util.tree_structure(like)  # raises on mismatch when mapped
        tree = jax.tree.map(lambda want, got: got.astype(want.dtype), like,
                            tree)
    if shardings is not None:
        tree = jax.tree.map(
            lambda arr, s: jax.device_put(arr, s), tree, shardings)
    return tree, meta["step"]
