"""Checkpoint manager: periodic saves, auto-resume, preemption awareness."""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Optional

from repro.checkpoint import checkpointer as ckpt


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    save_every: int = 100
    keep: int = 3
    _last_save_time: float = dataclasses.field(default=0.0, init=False)

    def maybe_save(self, step: int, tree) -> Optional[pathlib.Path]:
        if step % self.save_every != 0:
            return None
        t0 = time.time()
        path = ckpt.save(self.directory, step, tree, keep=self.keep)
        self._last_save_time = time.time() - t0
        return path

    def save_now(self, step: int, tree) -> pathlib.Path:
        return ckpt.save(self.directory, step, tree, keep=self.keep)

    def resume(self, *, shardings=None, like=None):
        """(tree, step) of the latest committed checkpoint, else (None, 0)."""
        step = ckpt.latest_step(self.directory)
        if step is None:
            return None, 0
        tree, step = ckpt.restore(self.directory, step, shardings=shardings,
                                  like=like)
        return tree, step
