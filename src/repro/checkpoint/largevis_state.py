"""LargeVis-native checkpoint schemas over the generic checkpointer.

Two consumers:

* **Model persistence** — :func:`save_result` / :func:`load_result`
  serialize a fitted :class:`~repro.core.largevis.LargeVisResult`
  (embedding, graph, sampler pytrees, cfg, key) as a versioned,
  CRC-verified, atomically-committed checkpoint (schema
  ``largevis-result-v1``) instead of a raw pickle.  ``LargeVis.save`` /
  ``LargeVis.load`` wrap these.

* **Crash recovery** — :class:`StageCheckpointer` persists each pipeline
  stage boundary (``graph`` -> ``weights`` -> ``samplers`` -> ``layout``)
  under ``CheckpointConfig.directory``, one subdirectory per stage, each
  using the atomic write-then-commit protocol.  Every stage records a
  **fingerprint** of (data sample, key, cfg); a resume against a
  directory written by a different run is detected and ignored with a
  warning instead of silently mixing states.

Config serialization keeps only JSON-able values: the routing /
checkpoint / health sub-configs nest as dicts, ``dtype`` round-trips by
name, and the deprecated flat alias knobs are dropped on load (they are
derived from ``routing``, and reconstructing through ``routing`` avoids
re-triggering their DeprecationWarnings).
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
import warnings
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ck
from repro.configs.largevis_default import (CheckpointConfig, HealthConfig,
                                            LargeVisConfig, RoutingConfig)

RESULT_SCHEMA = "largevis-result-v1"

# flat alias fields always hold routing-derived values after __post_init__;
# they are dropped from serialized cfgs and reconstructed via `routing`
_ALIAS_FIELDS = ("knn_impl", "sampler_impl", "fused_step", "knn_distributed")


# ---------------------------------------------------------------------------
# Config (de)serialization
# ---------------------------------------------------------------------------

def cfg_to_dict(cfg: LargeVisConfig) -> dict:
    """JSON-able dict of a LargeVisConfig (drops derived alias fields)."""
    d = dataclasses.asdict(cfg)
    for f in _ALIAS_FIELDS:
        d.pop(f, None)
    d["dtype"] = np.dtype(cfg.dtype).name
    return d


def cfg_from_dict(d: dict) -> LargeVisConfig:
    d = dict(d)
    d["routing"] = RoutingConfig(**d.get("routing") or {})
    for key, cls in (("checkpoint", CheckpointConfig),
                     ("health", HealthConfig)):
        v = d.get(key)
        d[key] = cls(**v) if v else None
    d["dtype"] = jnp.dtype(d.get("dtype", "float32")).type
    known = {f.name for f in dataclasses.fields(LargeVisConfig)}
    d = {k: v for k, v in d.items() if k in known and k not in _ALIAS_FIELDS}
    return LargeVisConfig(**d)


# Config fields that describe WHERE a run executes, not WHAT it computes.
# They are excluded from ``run_fingerprint`` (so a checkpoint written on
# one mesh resumes on any other) and recorded separately in every stage
# checkpoint's metadata as the topology tag (see :func:`topology_tag`).
_TOPOLOGY_FIELDS = ("distributed", "data_shards")


def run_fingerprint(x, key, cfg: LargeVisConfig) -> str:
    """Short identity of a (data, key, cfg) run for resume validation.

    The data component is a strided row sample (shape/dtype + CRC32 of
    ~64 rows), cheap at any N; the cfg component excludes ``checkpoint``
    itself (so cadence/keep/dir changes never invalidate a resume) and
    the topology fields (so the fingerprint is **topology-invariant**: a
    P=8 run and a single-device run of the same (data, key, algorithm)
    fingerprint identically, which is what makes stage checkpoints
    portable across mesh shapes — the sharded graph-prep stages are
    bitwise-equal across P, pinned in tests/test_elastic.py).  The mesh
    shape travels in the checkpoint's topology tag instead."""
    cfg_d = cfg_to_dict(cfg)
    cfg_d.pop("checkpoint", None)
    for f in _TOPOLOGY_FIELDS:
        cfg_d.pop(f, None)
    h = zlib.crc32(json.dumps(cfg_d, sort_keys=True).encode())
    if key is not None:
        h = zlib.crc32(np.asarray(jax.random.key_data(key)).tobytes(), h)
    if x is not None:
        xs = np.asarray(x[:: max(1, x.shape[0] // 64)])
        h = zlib.crc32(
            f"{tuple(np.shape(x))}:{np.asarray(x).dtype}".encode(), h)
        h = zlib.crc32(np.ascontiguousarray(xs).tobytes(), h)
    return f"{h:08x}"


def topology_tag(cfg: LargeVisConfig, n_rows: int) -> dict:
    """The topology half of the old full-cfg fingerprint, as plain data.

    Stored under ``extra["topology"]`` of every stage checkpoint: which
    mesh wrote it (``data_shards`` resolved to the actual device count,
    never the 0="all" sentinel) and how many real rows the global arrays
    hold.  Restores compare it to their own mesh — a mismatch is NOT an
    error (arrays are stored global and re-shard onto any mesh); it only
    decides whether a layout resume must announce a
    ``TopologyChangeWarning`` and lets the fallback walk skip degenerate
    tags (more shards than rows)."""
    shards = 1
    if getattr(cfg, "distributed", False):
        from repro.launch.mesh import make_data_mesh
        shards = int(make_data_mesh(cfg.data_shards).shape["data"])
    return {"distributed": bool(getattr(cfg, "distributed", False)),
            "data_shards": shards, "n_rows": int(n_rows)}


# ---------------------------------------------------------------------------
# Sampler pytrees <-> plain array dicts
# ---------------------------------------------------------------------------

def _samplers_to_tree(edge_s, neg_s):
    """(tree, static) for the flat EdgeSampler/NodeSampler pair (or None)."""
    if edge_s is None or neg_s is None:
        return None, None
    tree = {"edge": {"src": edge_s.src, "dst": edge_s.dst,
                     "threshold": edge_s.threshold, "alias": edge_s.alias},
            "neg": {"threshold": neg_s.threshold, "alias": neg_s.alias}}
    static = {"n_edges": int(edge_s.n_edges), "n_nodes": int(neg_s.n_nodes)}
    return tree, static


def _samplers_from_tree(tree, static):
    from repro.core.sampler import EdgeSampler, NodeSampler
    e, g = tree["edge"], tree["neg"]
    as_dev = jnp.asarray
    edge_s = EdgeSampler(as_dev(e["src"]), as_dev(e["dst"]),
                         as_dev(e["threshold"]), as_dev(e["alias"]),
                         n_edges=int(static["n_edges"]))
    neg_s = NodeSampler(as_dev(g["threshold"]), as_dev(g["alias"]),
                        n_nodes=int(static["n_nodes"]))
    return edge_s, neg_s


# ---------------------------------------------------------------------------
# Fitted-model persistence (LargeVis.save / LargeVis.load)
# ---------------------------------------------------------------------------

def save_result(path, result) -> None:
    """Persist a fitted LargeVisResult at ``path`` (a directory).

    Atomic + CRC-verified via the generic checkpointer; the PRNG key is
    stored as raw ``key_data`` (typed keys are not plain arrays)."""
    tree = {"y": result.y, "knn_idx": result.knn_idx,
            "knn_dist": result.knn_dist, "weights": result.weights}
    if result.x is not None:
        tree["x"] = result.x
    if result.key is not None:
        tree["key_data"] = jax.random.key_data(result.key)
    s_tree, s_static = _samplers_to_tree(result.edge_sampler,
                                         result.neg_sampler)
    if s_tree is not None:
        tree["samplers"] = s_tree
    extra = {"edge_samples": int(result.edge_samples),
             "timings": {k: float(v) for k, v in result.timings.items()},
             "sampler_static": s_static,
             "cfg": cfg_to_dict(result.cfg) if result.cfg else None}
    ck.save(path, 0, tree, keep=1, schema=RESULT_SCHEMA, extra_meta=extra)


def load_result(path):
    """Load a fitted model saved by :func:`save_result`."""
    from repro.core.largevis import LargeVisResult
    tree, _, meta = ck.restore(path, 0, expect_schema=RESULT_SCHEMA,
                               return_meta=True)
    extra = meta.get("extra", {})
    edge_s = neg_s = None
    if "samplers" in tree:
        edge_s, neg_s = _samplers_from_tree(tree["samplers"],
                                            extra["sampler_static"])
    key = None
    if "key_data" in tree:
        key = jax.random.wrap_key_data(jnp.asarray(tree["key_data"]))
    cfg = cfg_from_dict(extra["cfg"]) if extra.get("cfg") else None
    as_dev = jnp.asarray
    return LargeVisResult(
        y=as_dev(tree["y"]), knn_idx=as_dev(tree["knn_idx"]),
        knn_dist=as_dev(tree["knn_dist"]), weights=as_dev(tree["weights"]),
        timings=extra.get("timings", {}),
        edge_samples=int(extra.get("edge_samples", 0)),
        x=as_dev(tree["x"]) if "x" in tree else None,
        edge_sampler=edge_s, neg_sampler=neg_s, cfg=cfg, key=key)


# ---------------------------------------------------------------------------
# Pipeline stage checkpoints (crash recovery)
# ---------------------------------------------------------------------------

def _topology_compatible(meta: dict) -> None:
    """Reject (ValueError) a stage checkpoint whose topology tag is
    degenerate: more shards named than real rows to re-shard.  Such a
    tag can only come from a mesh-shrink sequence at tiny N (every
    shard's block was pure padding past row ``n_rows``); re-sharding it
    forward would hand ``rows_per_shard`` an all-padding layout, so the
    fallback walk skips to an older, compatible checkpoint instead."""
    tag = (meta.get("extra") or {}).get("topology")
    if tag is None:
        return                       # pre-elastic checkpoint: global, fine
    shards, n_rows = int(tag.get("data_shards", 1)), int(tag.get("n_rows", 0))
    if n_rows and shards > n_rows:
        raise ValueError(
            f"topology tag names {shards} shards for {n_rows} rows — "
            f"cannot re-shard")


class StageCheckpointer:
    """Atomic per-stage persistence under ``CheckpointConfig.directory``.

    One subdirectory per stage (``graph``/``weights``/``samplers`` at
    step 0; ``layout`` at its global step with keep-last-k rotation).
    ``load`` returns ``None`` — never raises — when the stage is absent,
    corrupt, or fingerprinted by a different run, so the pipeline falls
    back to recomputing the stage.

    Elastic restore: trees are persisted host-gathered, i.e. **global**
    (the generic checkpointer gathers sharded leaves), with the writing
    mesh recorded as a topology tag (``extra["topology"]``) — never
    baked into the fingerprint.  :meth:`restore` re-shards the global
    row arrays onto whatever mesh the *resuming* process has
    (``runtime/sharding.shard_rows`` — contiguous blocks of
    ``rows_per_shard`` rows), so a checkpoint written on P devices
    resumes on any P'."""

    def __init__(self, ckpt_cfg: CheckpointConfig, fingerprint: str):
        self.cfg = ckpt_cfg
        self.fingerprint = fingerprint

    def _dir(self, stage: str):
        import pathlib
        return pathlib.Path(self.cfg.directory) / stage

    def save(self, stage: str, tree, *, step: int = 0, keep: int = 1,
             extra: Optional[dict] = None):
        ck.save(self._dir(stage), step, tree, keep=keep,
                schema=f"largevis-stage-{stage}",
                extra_meta={"fingerprint": self.fingerprint,
                            **(extra or {})})

    def load(self, stage: str):
        """(tree, step, extra) of the newest valid checkpoint, else None."""
        if not self.cfg.resume:
            return None
        try:
            tree, step, meta = ck.restore(
                self._dir(stage), expect_schema=f"largevis-stage-{stage}",
                return_meta=True, validate=_topology_compatible)
        except FileNotFoundError:
            return None
        except (ck.CheckpointCorruptError, ValueError) as e:
            warnings.warn(
                f"checkpoint stage {stage!r} unusable ({e}); recomputing",
                RuntimeWarning, stacklevel=2)
            return None
        extra = meta.get("extra", {})
        if extra.get("fingerprint") != self.fingerprint:
            warnings.warn(
                f"checkpoint stage {stage!r} was written by a different "
                f"run (fingerprint mismatch); recomputing",
                RuntimeWarning, stacklevel=2)
            return None
        return tree, step, extra

    def restore(self, stage: str, *, mesh=None, axis: str = "data"):
        """:meth:`load`, then re-shard onto ``mesh`` (the elastic path).

        Returns ``(tree, step, extra)`` or ``None``.  With a mesh of
        more than one device, every array leaf whose leading dim equals
        the topology tag's ``n_rows`` (i.e. every row-layout array —
        scalars and oddly-shaped extras pass through untouched) is
        placed via ``sharding.shard_rows``: dim 0 over ``axis`` in the
        ``rows_per_shard`` contiguous-block layout, shape untouched.
        The writing mesh's shard count is irrelevant — the stored
        arrays are global — which is the whole point: any-P to any-P
        resume through one code path."""
        loaded = self.load(stage)
        if loaded is None or mesh is None:
            return loaded
        tree, step, extra = loaded
        if int(mesh.shape[axis]) <= 1:
            return loaded
        from repro.runtime import sharding as sh
        tag = (extra or {}).get("topology") or {}
        n_rows = int(tag.get("n_rows", 0))

        def place(leaf):
            arr = np.asarray(leaf)
            if arr.ndim >= 1 and n_rows and arr.shape[0] == n_rows:
                return sh.shard_rows(arr, mesh, axis)
            return jnp.asarray(arr)

        return jax.tree.map(place, tree), step, extra


class AsyncStageWriter:
    """Off-thread stage-checkpoint writer for unmonitored chunked runs.

    The dispatch loop hands over an on-device snapshot of the state
    (``jnp.copy`` — immutable, so it survives the donation of the live
    buffer, and the copy itself dispatches asynchronously) and keeps
    enqueueing chunks; this thread blocks on the snapshot's completion,
    host-gathers it, and runs the atomic save protocol off the critical
    path.  Saves commit in submission order (single thread, FIFO queue)
    and :meth:`close` drains the queue before returning, so the final
    stage boundary is durable when the driver returns.  The bounded
    queue back-pressures the submitter if disk falls behind, keeping at
    most ``depth`` snapshots alive.  A save failure is re-raised on the
    next ``submit``/``close`` — a run may not silently claim durability.

    An optional :class:`~repro.runtime.fault_tolerance.Watchdog` is fed
    the wall time between successive snapshot *completions* — under a
    saturated device queue that tracks per-cadence compute time, giving
    straggler detection without blocking the dispatch loop (the first
    interval is skipped: it would measure compile, not stepping).
    """

    def __init__(self, ckpt: StageCheckpointer, watchdog=None,
                 depth: int = 2):
        self._ckpt = ckpt
        self._watchdog = watchdog
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[Exception] = None
        self._t_last: Optional[float] = None
        self._thread = threading.Thread(
            target=self._run, name="stage-ckpt-writer", daemon=True)
        self._thread.start()

    def submit(self, stage: str, tree, *, step: int = 0, keep: int = 1,
               extra: Optional[dict] = None):
        if self._err is not None:
            raise self._err
        self._q.put((stage, tree, step, keep, extra))

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            if self._err is not None:
                continue                    # drain without deadlocking put()
            stage, tree, step, keep, extra = item
            try:
                jax.block_until_ready(tree)
                now = time.time()
                if self._watchdog is not None and self._t_last is not None:
                    self._watchdog.observe(step, now - self._t_last)
                self._t_last = now
                self._ckpt.save(stage, tree, step=step, keep=keep,
                                extra=extra)
            except Exception as e:          # noqa: BLE001 — reraised on submit
                self._err = e

    def close(self):
        """Drain pending saves and join; raises any deferred write error."""
        self._q.put(None)
        self._thread.join()
        if self._err is not None:
            raise self._err
