"""gemma3-12b [hf:google/gemma-3-*-pt family] — dense, 5:1 local:global."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    qk_norm=True,
    rope_theta=1_000_000.0,   # global layers; locals use 10k (handled in rotary)
    sliding_window=1024,      # local layers
    mlp_type="geglu",
    # one period: 5 sliding-window locals then 1 global (5:1)
    block_pattern=("local", "local", "local", "local", "local", "global"),
    embed_scale=True,
    tie_embeddings=True,
    subquadratic=True,        # decode dominated by windowed local layers
    notes="5:1 local:global, 128k context, QK-norm, GeGLU, 262k vocab",
)
