"""xlstm-125m [arXiv:2405.04517] — alternating mLSTM/sLSTM blocks, no FFN.

d_ff=0: xLSTM blocks carry their own up/down projections (mLSTM runs at 2x
expansion, sLSTM at model width with a gated feed-through).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    rope_theta=0.0,
    mlp_type="none",
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    subquadratic=True,
    notes="recurrent (linear-time) blocks; associative-scan implementation",
)
