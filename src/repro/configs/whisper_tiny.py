"""whisper-tiny [arXiv:2212.04356] — enc-dec audio; conv frontend is a STUB.

The spec assigns the transformer BACKBONE only: ``input_specs`` supplies
precomputed frame embeddings (the conv frontend output) as an input.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,               # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    rope_theta=0.0,           # learned absolute positions, no RoPE
    mlp_type="gelu",
    block_pattern=("attn",),
    is_encoder_decoder=True,
    max_position=32_768,      # learned positions sized for the decode cells
    n_enc_layers=4,
    enc_positions=1500,       # 30 s audio -> 1500 frames after conv stub
    frontend="audio_stub",
    norm_eps=1e-5,
    subquadratic=False,
    notes="enc-dec; audio conv frontend stubbed with precomputed frames",
)
