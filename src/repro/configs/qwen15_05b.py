"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B] — dense, QKV bias, tied embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,           # GQA kv=16 (== MHA)
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    attn_bias=True,          # QKV bias
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    block_pattern=("attn",),
    tie_embeddings=True,
    subquadratic=False,
    notes="QKV bias; tied embeddings; full attention",
)
