"""chameleon-34b [arXiv:2405.09818] — early-fusion VLM backbone; VQ stub.

Image tokens are VQ codes living in the shared 65536 vocab; the VQ tokenizer
frontend is a STUB (tokens arrive pre-quantized).  Backbone is a dense
transformer with QK-norm (chameleon's training-stability fix).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    mlp_type="swiglu",
    block_pattern=("attn",),
    frontend="vq_stub",
    subquadratic=False,
    notes="early fusion, VQ image tokens share the vocab; QK-norm",
)
