"""phi3-medium-14b [arXiv:2404.14219] — dense GQA, RoPE, SwiGLU."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17_920,
    vocab_size=100_352,
    rope_theta=10_000.0,
    mlp_type="swiglu",
    block_pattern=("attn",),
    subquadratic=False,
    notes="GQA kv=10, SwiGLU, full attention",
)
