"""jamba-v0.1-52b [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE 16e top-2.

Period-8 super-block: attention at position 3, Mamba elsewhere; MoE replaces
the MLP on every other layer (odd layer indices).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    rope_theta=0.0,            # Jamba uses no positional encoding in attn
    mlp_type="swiglu",
    n_experts=16,
    topk_experts=2,
    moe_every=2,               # MoE on every 2nd layer
    block_pattern=(
        "mamba", "mamba", "mamba", "attn",
        "mamba", "mamba", "mamba", "mamba",
    ),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    subquadratic=True,
    notes="1:7 attn:mamba interleave; MoE every 2 layers; no RoPE",
)
