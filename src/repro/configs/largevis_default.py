"""LargeVis default hyper-parameters — the paper's own configuration (§4.3).

These are the defaults the paper reports as *stable across datasets*:
perplexity 50, K=150 neighbors, M=5 negatives, gamma=7, rho0=1.0,
f(x) = 1/(1+x^2), T proportional to N.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LargeVisConfig:
    # --- KNN graph construction (paper §3.1, Algo 1) ---
    n_neighbors: int = 150          # K
    n_trees: int = 8                # NT random projection "trees" (tables)
    n_explore_iters: int = 1        # Iter; paper: 1-3 suffices
    tree_depth: int = 0             # 0 -> auto from N and leaf target
    leaf_target: int = 64           # target points per bucket
    window: int = 64                # sorted-window candidate half-width
    explore_sample: int = 0         # 0 -> auto (candidates per explore iter)
    rp_mode: str = "hash"           # "hash" (matmul, TPU-native) | "tree"
    knn_impl: str = "auto"          # streaming distance->top-k routing
    #   (kernels/ops.py::topk_sqdist): "fused"/"pallas" = the Pallas
    #   kernel, "ref" = the streaming jnp oracle, "auto" = kernel on TPU
    #   / oracle elsewhere (bit-identical at equal tiles)
    perplexity: float = 50.0        # u in Eqn (1)
    perplexity_iters: int = 64      # bisection steps for sigma_i
    # --- distributed pipeline (knn_sharded.py / perplexity.py /
    #     sampler.py sharded drivers + local-SGD layout) ---
    distributed: bool = False       # run every stage on the 1-D "data" mesh
    data_shards: int = 0            # devices in the 1-D mesh (0 = all)
    knn_distributed: bool = True    # stage-1 routing under distributed=True:
    #   True = the ring pass (core/knn_sharded.py) — fixed per-device
    #   memory, but its masked distance fold costs O(N^2 d / P) FLOPs
    #   per device (the bucket codes mask candidates, they don't skip
    #   tiles), which wants a device count that scales with N;
    #   False = the paper's linear RP-forest + neighbor-exploring KNN
    #   (single-device compute, O(N) — the fig6 scaling configuration),
    #   with calibration/symmetrization/samplers/layout still sharded
    # --- layout (paper §3.2) ---
    out_dim: int = 2                # s
    n_negatives: int = 5            # M
    gamma: float = 7.0
    rho0: float = 1.0               # initial lr; rho_t = rho0 * (1 - t/T)
    samples_per_node: int = 10_000  # T = samples_per_node * N edge samples
    prob_fn: str = "inv_quadratic"  # f(x)=1/(1+a x^2); see objective.py
    prob_a: float = 1.0
    grad_clip: float = 5.0          # reference-impl per-coordinate clip
    batch_size: int = 4096          # edge samples per device step (TPU adapt)
    steps_per_dispatch: int = 100   # scan-fused steps per device dispatch
    #   (core/layout_engine.py); <=1 falls back to the per-step Python loop
    #   (debug / visual-progress mode — ~dispatch-bound at small N)
    fused_step: bool = True         # fully-fused edge-step kernel
    #   (kernels/largevis_step.py: gather+grad+scatter in one pass, y
    #   updated in place); False = split gather/grad/scatter path (debug;
    #   autodiff prob_fns and VMEM-oversized embeddings split automatically)
    sync_every: int = 1             # H: local-SGD sync period (1 = sync SGD)
    sampler_impl: str = "auto"      # alias-table builder at the stage
    #   boundary: "device" = jitted sort/prefix-sum construction, tables
    #   built on device straight from the (possibly sharded) graph;
    #   "host" = numpy Vose loop (the test oracle / debug path);
    #   "auto" -> "device" (core/sampler.py)
    init_scale: float = 1e-4        # initial layout ~ N(0, init_scale)
    neg_power: float = 0.75         # P_n(j) ∝ d_j^0.75
    dtype: Any = jnp.float32
    seed: int = 0


DEFAULT = LargeVisConfig()
