"""LargeVis default hyper-parameters — the paper's own configuration (§4.3).

These are the defaults the paper reports as *stable across datasets*:
perplexity 50, K=150 neighbors, M=5 negatives, gamma=7, rho0=1.0,
f(x) = 1/(1+x^2), T proportional to N.

Implementation routing lives in one namespace, ``LargeVisConfig.routing``
(:class:`RoutingConfig`) — which kernel/builder backs each stage.  The
pre-PR-7 flat knobs (``knn_impl``, ``sampler_impl``, ``fused_step``,
``knn_distributed``) keep working as deprecated aliases: passing one
emits a ``DeprecationWarning`` and folds the value into ``routing``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Stage-checkpointed crash recovery for ``largevis()`` / ``fit()``.

    When set on ``LargeVisConfig.checkpoint``, every stage boundary of the
    pipeline — the KNN graph, the calibrated+symmetrized weights, the
    sampler pytrees, and the layout ``(y, step)`` every
    ``every_chunks`` dispatches — is persisted atomically (the
    ``checkpoint/`` machinery's write-then-rename-then-commit protocol)
    under ``directory``.  A killed fit re-run with the same ``(x, key,
    cfg)`` resumes from the last committed stage/chunk and produces a
    **bitwise-identical** final embedding (pinned in tests/test_resume.py;
    a config/key/data fingerprint guards against resuming someone else's
    directory — mismatches start fresh with a warning).
    """
    directory: str
    # layout save cadence, in steps_per_dispatch chunks.  A crash replays
    # at most every_chunks*steps_per_dispatch steps; the default trades a
    # few seconds of replay for keeping save overhead well under 5% even
    # when writer and compute share one core (every_chunks=1 — a save per
    # dispatch — is the chaos-test stress cadence, not a sane default)
    every_chunks: int = 4
    keep: int = 2             # keep-last-k layout checkpoints
    resume: bool = True       # False: checkpoint but never auto-resume


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Numerical-health guard + divergence rollback for the layout stage.

    When set on ``LargeVisConfig.health``, every ``check_every_chunks``
    dispatches a jitted probe reduces the embedding to (non-finite count,
    max |coordinate|).  A non-finite entry or a coordinate beyond
    ``max_abs`` is a divergence: the driver rolls the layout back to the
    last healthy chunk, scales the learning rate by ``lr_backoff``, and
    re-runs from there (one structured ``DivergenceWarning``).  More than
    ``max_rollbacks`` rollbacks raises ``LayoutDivergedError``.  The probe
    syncs the device once per check, so default runs (``health=None``)
    keep the fully-async dispatch pipeline.
    """
    check_every_chunks: int = 1
    max_abs: float = 1e6          # embedding-norm blowup bound
    lr_backoff: float = 0.5       # rho0 multiplier per rollback
    max_rollbacks: int = 3


@dataclasses.dataclass(frozen=True)
class RoutingConfig:
    """Implementation routing for every pipeline stage.

    Every knob accepts ``"auto"``; the full resolution table:

    ==============  ========================  ================================
    knob            values                    ``"auto"`` resolves to
    ==============  ========================  ================================
    ``knn``         auto | fused|pallas|ref   streaming distance->top-k
                                              (``kernels.ops.topk_sqdist``):
                                              Pallas kernel on TPU, the
                                              bit-identical streaming jnp
                                              oracle elsewhere
    ``sampler``     auto | device | host      alias-table builder at the
                                              graph->layout boundary:
                                              ``device`` (jitted prefix-sum
                                              construction); ``host`` is the
                                              numpy Vose oracle/debug path
    ``layout_step`` auto | fused | split      SGD edge-step body: ``fused``
                                              (one-pass gather+grad+scatter
                                              kernel, in-place y) wherever
                                              ``ops.fused_step_supported``;
                                              ``split`` is the gather/grad/
                                              scatter debug path (also taken
                                              automatically for autodiff
                                              prob_fns / VMEM-oversized y)
    ``knn_stage``   auto | ring | forest      stage-1 KNN under
                                              ``distributed=True``: ``ring``
                                              = the sharded distance ring
                                              (fixed memory, O(N^2 d/P)
                                              compute); ``forest`` = the
                                              paper's linear RP-forest +
                                              neighbor-exploring build
                                              (the fig6 scaling config)
    ``autotune``    auto | off|cache|sweep    kernel tile autotuner mode
                                              (``runtime.autotune``):
                                              ``auto`` leaves the AUTOTUNE
                                              env (default ``cache``) in
                                              charge; ``off`` pins every
                                              tile to the legacy hard-coded
                                              config (bitwise CI anchor);
                                              ``sweep`` measures cache
                                              misses and persists winners
    ==============  ========================  ================================
    """
    knn: str = "auto"
    sampler: str = "auto"
    layout_step: str = "auto"
    knn_stage: str = "auto"
    autotune: str = "auto"


class _ResolvedStr(str):
    """Marks a flat alias value that was derived from ``routing`` (not
    user-passed), so ``dataclasses.replace(cfg, routing=...)`` round trips
    know routing is authoritative and stay silent."""


class _ResolvedFlag(int):
    """Bool-valued counterpart of :class:`_ResolvedStr` (``bool`` is not
    subclassable; an int subclass keeps truthiness, ``==`` and hashing)."""


def _mark_resolved(v):
    return _ResolvedStr(v) if isinstance(v, str) else _ResolvedFlag(v)


# (deprecated flat field, routing key, routing-value -> flat-value,
#  flat-value -> routing-value)
_ALIASES = (
    ("knn_impl", "knn", lambda v: v, lambda o: o),
    ("sampler_impl", "sampler", lambda v: v, lambda o: o),
    ("fused_step", "layout_step", lambda v: v != "split",
     lambda o: "fused" if o else "split"),
    ("knn_distributed", "knn_stage", lambda v: v != "forest",
     lambda o: "ring" if o else "forest"),
)


@dataclasses.dataclass(frozen=True)
class LargeVisConfig:
    # --- KNN graph construction (paper §3.1, Algo 1) ---
    n_neighbors: int = 150          # K
    n_trees: int = 8                # NT random projection "trees" (tables)
    n_explore_iters: int = 1        # Iter; paper: 1-3 suffices
    tree_depth: int = 0             # 0 -> auto from N and leaf target
    leaf_target: int = 64           # target points per bucket
    window: int = 64                # sorted-window candidate half-width
    explore_sample: int = 0         # 0 -> auto (candidates per explore iter)
    rp_mode: str = "hash"           # "hash" (matmul, TPU-native) | "tree"
    perplexity: float = 50.0        # u in Eqn (1)
    perplexity_iters: int = 64      # bisection steps for sigma_i
    # --- distributed pipeline (knn_sharded.py / perplexity.py /
    #     sampler.py sharded drivers + local-SGD layout) ---
    distributed: bool = False       # run every stage on the 1-D "data" mesh
    data_shards: int = 0            # devices in the 1-D mesh (0 = all)
    # --- layout (paper §3.2) ---
    out_dim: int = 2                # s
    n_negatives: int = 5            # M
    gamma: float = 7.0
    rho0: float = 1.0               # initial lr; rho_t = rho0 * (1 - t/T)
    samples_per_node: int = 10_000  # T = samples_per_node * N edge samples
    prob_fn: str = "inv_quadratic"  # f(x)=1/(1+a x^2); see objective.py
    prob_a: float = 1.0
    grad_clip: float = 5.0          # reference-impl per-coordinate clip
    batch_size: int = 4096          # edge samples per device step (TPU adapt)
    steps_per_dispatch: int = 100   # scan-fused steps per device dispatch
    #   (core/layout_engine.py); <=1 falls back to the per-step Python loop
    #   (debug / visual-progress mode — ~dispatch-bound at small N)
    sync_every: int = 1             # H: local-SGD sync period (1 = sync SGD)
    init_scale: float = 1e-4        # initial layout ~ N(0, init_scale)
    neg_power: float = 0.75         # P_n(j) ∝ d_j^0.75
    # --- out-of-sample transform (core/transform.py) ---
    transform_steps: int = 48       # frozen-corpus SGD steps per query batch
    transform_rho0: float = 0.0     # initial transform lr (0 -> rho0)
    # --- robustness (crash recovery + numerical health; PR 8) ---
    checkpoint: Optional[CheckpointConfig] = None   # stage-checkpointed
    #   resume (None = no persistence, the historical behaviour)
    health: Optional[HealthConfig] = None           # divergence guard +
    #   rollback on the layout path (None = no per-chunk device sync)
    # --- implementation routing (one namespace; see RoutingConfig) ---
    routing: RoutingConfig = dataclasses.field(default_factory=RoutingConfig)
    # Deprecated flat aliases (pre-PR-7 names).  Passing one warns and
    # folds the value into ``routing``; after construction they always
    # hold the concrete routing-derived values, so legacy readers (and
    # ``dataclasses.replace`` round trips) keep working.
    knn_impl: Optional[str] = None            # -> routing.knn
    sampler_impl: Optional[str] = None        # -> routing.sampler
    fused_step: Optional[bool] = None         # -> routing.layout_step
    knn_distributed: Optional[bool] = None    # -> routing.knn_stage
    dtype: Any = jnp.float32
    seed: int = 0

    def __post_init__(self):
        routing = self.routing
        if routing is None:
            routing = RoutingConfig()
        for flat, key, from_routing, to_routing in _ALIASES:
            flat_val = getattr(self, flat)
            if flat_val is None:
                continue
            if from_routing(getattr(routing, key)) == flat_val:
                continue            # consistent (e.g. a replace() round trip)
            if isinstance(flat_val, (_ResolvedStr, _ResolvedFlag)):
                continue            # stale routing-derived value from a
                #                     replace(cfg, routing=...) — routing wins
            # an UNMARKED conflicting value was passed by the user in THIS
            # construction (including dataclasses.replace(cfg, fused_step=..)
            # on a config whose routing was folded earlier) — it wins, with
            # the deprecation warning; routing wins silently only over its
            # own stale derived values (the marked branch above)
            warnings.warn(
                f"LargeVisConfig({flat}=...) is deprecated; use "
                f"routing=RoutingConfig({key}={to_routing(flat_val)!r})",
                DeprecationWarning, stacklevel=3)
            routing = dataclasses.replace(
                routing, **{key: to_routing(flat_val)})
        object.__setattr__(self, "routing", routing)
        for flat, key, from_routing, _ in _ALIASES:
            object.__setattr__(
                self, flat,
                _mark_resolved(from_routing(getattr(routing, key))))


DEFAULT = LargeVisConfig()
