"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` instance in its own module
(``src/repro/configs/<id>.py``).  ``ShapeConfig`` describes the four assigned
input shapes.  ``input_specs`` builds the ShapeDtypeStruct stand-ins consumed
by the multi-pod dry-run (no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A single LM-family architecture.

    ``block_pattern`` is one *period* of the layer stack; the full stack is
    ``block_pattern * (n_layers // len(block_pattern))``.  Homogeneous archs
    use a length-1 pattern and scan over all layers; heterogeneous archs
    (gemma3 5:1, jamba 1:7, xlstm m/s) scan over super-blocks with the
    period unrolled inside the scan body.
    """

    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention ---
    attn_bias: bool = False          # qwen1.5: bias on QKV projections
    qk_norm: bool = False            # chameleon / gemma3
    rope_theta: float = 10_000.0
    max_position: int = 1 << 20
    sliding_window: int = 0          # 0 = full attention (mixtral: 4096)
    # gemma3-style local:global mix; entries of block_pattern control it.

    # --- mlp ---
    mlp_type: str = "swiglu"         # swiglu | geglu | gelu

    # --- moe ---
    n_experts: int = 0
    topk_experts: int = 0
    moe_every: int = 1               # jamba: MoE on every 2nd layer

    # --- layer pattern (one period) ---
    block_pattern: tuple = ("attn",)

    # --- ssm (mamba / xlstm) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_positions: int = 1500        # whisper: 30s audio -> 1500 frames

    # --- frontend stubs ---
    frontend: str = "none"           # none | audio_stub | vq_stub

    # --- misc ---
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # long_500k applicability: sub-quadratic decode path exists?
    subquadratic: bool = False
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period {len(self.block_pattern)}")
        return self.n_layers // len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        moe_mlp = mlp * self.n_experts + d * self.n_experts
        mamba_inner = d * self.ssm_expand
        mamba = (d * mamba_inner * 2            # in_proj (x, z)
                 + mamba_inner * self.ssm_conv  # conv
                 + mamba_inner * (self.ssm_state * 2 + 1)  # B,C,dt proj-ish
                 + mamba_inner * self.ssm_state            # A
                 + mamba_inner * d)             # out_proj
        xl = 4 * d * d                          # rough mlstm/slstm block
        total = 0
        for li in range(self.n_layers):
            kind = self.block_pattern[li % len(self.block_pattern)]
            use_moe = (self.n_experts > 0 and li % self.moe_every ==
                       (self.moe_every - 1) and kind != "mamba_dense")
            if kind in ("attn", "local", "global"):
                total += attn + (moe_mlp if use_moe else mlp)
            elif kind == "mamba":
                total += mamba + (moe_mlp if use_moe else mlp)
            elif kind in ("mlstm", "slstm"):
                total += xl
            total += 2 * d                      # norms
        total += self.vocab_size * d            # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d        # lm head
        if self.is_encoder_decoder:
            total += self.n_enc_layers * (attn + mlp + 2 * d)
            total += self.n_enc_layers * attn   # cross-attn in decoder
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.n_experts == 0:
            return self.param_count()
        hd = self.resolved_head_dim
        d = self.d_model
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        dense_total = self.param_count()
        # subtract inactive experts on MoE layers
        n_moe_layers = sum(
            1 for li in range(self.n_layers)
            if li % self.moe_every == (self.moe_every - 1)
            and self.block_pattern[li % len(self.block_pattern)] != "none")
        inactive = n_moe_layers * mlp * (self.n_experts - self.topk_experts)
        return int(dense_total - inactive)

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same family/pattern, tiny dims."""
        period = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=period * min(2, self.n_periods),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            n_experts=min(4, self.n_experts),
            topk_experts=min(2, self.topk_experts) if self.topk_experts else 0,
            ssm_state=8,
            ssm_expand=2,
            n_enc_layers=min(2, self.n_enc_layers),
            enc_positions=16,
            max_position=4096,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype=jnp.float32,
        )


# ---------------------------------------------------------------------------
# Shape config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def cell_applicable(arch: "ArchConfig", shape: ShapeConfig) -> tuple:
    """(applicable, reason) for an (arch, shape) cell.

    long_500k requires a sub-quadratic decode path (SSM / hybrid / windowed);
    pure full-attention archs skip it (recorded in the roofline table).
    """
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "long_500k skipped: pure full-attention arch"
    if shape.kind == "decode" and arch.is_encoder_decoder and shape.seq_len > arch.max_position:
        return False, f"decode seq {shape.seq_len} exceeds enc-dec max_position"
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                kv_repeat: int = 1, kv_quant: bool = False) -> dict:
    """Dry-run inputs for one (arch, shape) cell.

    train:   tokens + labels, full sequence.
    prefill: tokens, full sequence (returns logits of last position + cache).
    decode:  one new token per sequence + a filled KV cache of seq_len.
    Modality frontends are stubs: the audio/vq encoders are replaced by
    precomputed frame/patch embeddings supplied as inputs.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = sds((B, S), i32)
        specs["labels"] = sds((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = sds((B, S), i32)
    else:  # decode
        specs["tokens"] = sds((B, 1), i32)
        specs["cache"] = kv_cache_specs(arch, B, S, kv_repeat, kv_quant)
        specs["position"] = sds((B,), i32)
    if arch.is_encoder_decoder:
        # audio stub: precomputed frame embeddings from the conv frontend
        specs["encoder_frames"] = sds(
            (B, arch.enc_positions, arch.d_model), arch.dtype)
    return specs


def kv_cache_specs(arch: ArchConfig, batch: int, seq_len: int,
                   kv_repeat: int = 1, kv_quant: bool = False) -> dict:
    """ShapeDtypeStruct pytree for a filled decode cache.

    Derived from the model's own prefill function via eval_shape (no
    allocation), so the dry-run cache layout can never drift from the
    implementation.  Decode-cell semantics: the cache was allocated at
    seq_len, holds seq_len-1 tokens, and the new token lands at the last
    slot (position = seq_len - 1).
    """
    from repro.models.factory import cache_specs  # local import, no cycle
    return cache_specs(arch, batch, seq_len, kv_repeat, kv_quant)
