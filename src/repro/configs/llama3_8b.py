"""llama3-8b [arXiv:2407.21783] — dense GQA, 128k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    mlp_type="swiglu",
    block_pattern=("attn",),
    subquadratic=False,
    notes="GQA kv=8, SwiGLU, full attention",
)
