"""mixtral-8x7b [arXiv:2401.04088] — MoE 8 experts top-2, sliding-window attn."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    mlp_type="swiglu",
    n_experts=8,
    topk_experts=2,
    moe_every=1,              # every layer is MoE
    block_pattern=("local",), # SWA on all layers
    subquadratic=True,        # SWA bounds decode attention cost
    notes="8e top-2 MoE every layer; SWA 4096",
)
