"""dbrx-132b [hf:databricks/dbrx-base] — fine-grained MoE 16 experts top-4."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    rope_theta=500_000.0,
    mlp_type="swiglu",
    n_experts=16,
    topk_experts=4,
    moe_every=1,
    block_pattern=("attn",),
    subquadratic=False,
    notes="16e top-4 fine-grained MoE; full attention",
)
