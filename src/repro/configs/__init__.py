"""Config registry: ``get_config("llama3-8b")`` / ``--arch llama3-8b``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    ShapeConfig,
    SHAPES,
    cell_applicable,
    input_specs,
    kv_cache_specs,
)
from repro.configs.largevis_default import LargeVisConfig, DEFAULT as LARGEVIS_DEFAULT  # noqa: F401

_ARCH_MODULES = {
    "qwen1.5-0.5b": "qwen15_05b",
    "gemma3-12b": "gemma3_12b",
    "llama3-8b": "llama3_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "whisper-tiny": "whisper_tiny",
    "mixtral-8x7b": "mixtral_8x7b",
    "dbrx-132b": "dbrx_132b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "chameleon-34b": "chameleon_34b",
    "xlstm-125m": "xlstm_125m",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {name: get_config(name) for name in _ARCH_MODULES}
