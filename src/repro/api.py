"""The public estimator API: ``fit`` / ``transform`` / ``fit_transform`` /
``insert`` over the LargeVis pipeline.

    from repro import LargeVis

    model = LargeVis(n_neighbors=50, samples_per_node=2000).fit(x)
    coords = model.embedding_                    # (N, 2) fitted layout
    y_new = model.transform(x_held_out)          # frozen-corpus projection
    y_new = model.insert(x_more)                 # grow the model online

:class:`LargeVis` wraps the functional core (``core.largevis.largevis``)
without re-deriving anything: ``fit`` runs the identical pipeline with the
identical key stream, so ``LargeVis(cfg=c).fit(x, key).embedding_`` is
bitwise-equal to ``largevis(x, key, cfg=c).y`` (pinned in
tests/test_api.py).  The fitted state is a single
:class:`~repro.core.largevis.LargeVisResult` carrier at ``.result_`` —
see its docstring for the frozen-field contract (``transform`` never
mutates the carrier; ``insert`` appends rows and rewrites the graph but
never moves fitted coordinates).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.largevis_default import LargeVisConfig
from repro.core import perplexity as perp_lib
from repro.core import sampler as sampler_lib
from repro.core import transform as transform_lib
from repro.core.largevis import LargeVisResult, largevis

# domain separators for keys derived from the fit key when the caller
# does not pass one (fold_in keeps streams disjoint from layout steps,
# which fold small integers into per-chunk subkeys of the SPLIT key)
_TRANSFORM_TAG = 0x7472_616E          # "tran"
_INSERT_TAG = 0x696E_7372             # "insr"


class NotFittedError(RuntimeError):
    """``transform``/``insert`` called before ``fit``."""


def _check_input(x, name: str, *, expect_dim: int | None = None,
                 allow_empty: bool = False):
    """Validate a points matrix at the public-API boundary.

    Rejects (with a specific ``ValueError``) the failure modes that
    otherwise surface as cryptic shape errors or silent NaN layouts deep
    inside jitted stages: empty input, wrong rank, a feature-dimension
    mismatch against the fitted corpus, and non-finite rows."""
    import numpy as np
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(
            f"{name}: expected a 2-D (n_points, n_features) array, "
            f"got shape {tuple(x.shape)}")
    if x.shape[0] == 0 and not allow_empty:
        raise ValueError(f"{name}: empty input (0 points)")
    if x.shape[1] == 0:
        raise ValueError(f"{name}: 0 features")
    if expect_dim is not None and x.shape[1] != expect_dim:
        raise ValueError(
            f"{name}: {x.shape[1]} features, but the fitted corpus has "
            f"{expect_dim} — transform/insert must match the fit dims")
    if x.shape[0] and jnp.issubdtype(x.dtype, jnp.floating):
        finite = np.asarray(jnp.all(jnp.isfinite(x), axis=1))
        if not finite.all():
            bad = np.flatnonzero(~finite)
            raise ValueError(
                f"{name}: {bad.size} row(s) contain NaN/Inf "
                f"(first offenders: {bad[:5].tolist()}); clean or drop "
                f"them before calling")
    return x


class LargeVis:
    """LargeVis visualization estimator (paper: Tang et al., WWW 2016).

    Parameters are the fields of :class:`LargeVisConfig`; pass a full
    ``cfg=`` and/or individual fields as keyword overrides::

        LargeVis(n_neighbors=15)
        LargeVis(cfg=my_cfg, samples_per_node=500)

    After ``fit``: ``embedding_`` is the (N, out_dim) layout and
    ``result_`` the full fitted-model carrier.  The estimator object
    pickles (model persistence round trip is pinned in tests).
    """

    def __init__(self, cfg: LargeVisConfig | None = None, **overrides):
        if cfg is None:
            cfg = LargeVisConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        self.result_: LargeVisResult | None = None

    # -- fitting ---------------------------------------------------------

    def fit(self, x, key=None, *, callback=None) -> "LargeVis":
        """Run the two-stage pipeline on ``x`` (N, d); returns ``self``."""
        x = _check_input(x, "fit(x)")
        self.result_ = largevis(x, key, cfg=self.cfg, callback=callback)
        return self

    def fit_transform(self, x, key=None, *, callback=None):
        """``fit(x)`` and return the (N, out_dim) embedding."""
        return self.fit(x, key, callback=callback).embedding_

    @property
    def embedding_(self):
        return self._fitted().y

    def _fitted(self) -> LargeVisResult:
        if self.result_ is None:
            raise NotFittedError(
                "this LargeVis instance is not fitted yet; call fit() "
                "or fit_transform() first")
        return self.result_

    # -- persistence -----------------------------------------------------

    def save(self, path) -> None:
        """Persist the fitted model at ``path`` (a directory).

        Versioned, CRC-verified, atomically-committed on-disk format
        (schema ``largevis-result-v1`` over the generic checkpointer) —
        a kill mid-save can never clobber a previous good save, and a
        bit-rotted file is detected at load instead of silently
        producing a corrupt model.  Not a pickle: no code execution at
        load, stable across refactors of this class."""
        from repro.checkpoint.largevis_state import save_result
        save_result(path, self._fitted())

    @classmethod
    def load(cls, path) -> "LargeVis":
        """Restore a model saved by :meth:`save`; inverse round trip."""
        from repro.checkpoint.largevis_state import load_result
        result = load_result(path)
        model = cls(cfg=result.cfg) if result.cfg is not None else cls()
        model.result_ = result
        return model

    # -- online operations ----------------------------------------------

    def transform(self, x_new, key=None):
        """Project queries into the FROZEN fitted layout -> (Q, out_dim).

        The fitted model is read-only here: corpus coordinates enter the
        projection's force computation but stay bit-identical, and the
        carrier is not mutated.  See ``core.transform.project``.
        """
        r = self._fitted()
        x_new = _check_input(x_new, "transform(x_new)",
                             expect_dim=int(r.x.shape[1]))
        if key is None:
            key = jax.random.fold_in(r.key, _TRANSFORM_TAG)
        y_new, _ = transform_lib.project(
            x_new, x=r.x, y=r.y, key=key, cfg=r.cfg or self.cfg,
            neg_sampler=r.neg_sampler)
        return y_new

    def insert(self, x_new, key=None):
        """Grow the fitted model by ``x_new`` -> their (Q, out_dim) coords.

        Incremental, no refit: the KNN graph is updated through the
        neighbor-exploring machinery (``core.transform.knn_insert``), the
        new points are projected with the existing corpus frozen, edge
        weights are re-calibrated on the updated graph, and the samplers
        are rebuilt — after which the inserted points are full corpus
        members for future ``transform``/``insert`` calls.  Existing
        rows of ``embedding_`` do not move.
        """
        r = self._fitted()
        cfg = r.cfg or self.cfg
        x_new = _check_input(x_new, "insert(x_new)",
                             expect_dim=int(r.x.shape[1]), allow_empty=True)
        if key is None:
            key = jax.random.fold_in(r.key, _INSERT_TAG)
        kp, kg = jax.random.split(key)
        x_new = jnp.asarray(x_new, r.x.dtype)
        if x_new.shape[0] == 0:
            return jnp.zeros((0, r.y.shape[1]), r.y.dtype)
        y_new, aux = transform_lib.project(
            x_new, x=r.x, y=r.y, key=kp, cfg=cfg, neg_sampler=r.neg_sampler)
        k = r.knn_idx.shape[1]
        qc_idx, qc_dist = aux["nn_idx"], aux["nn_dist"]
        if qc_idx.shape[1] != k:        # cfg.n_neighbors drifted from fit
            qc_idx, qc_dist = None, None
        x_all, idx_all, dist_all = transform_lib.knn_insert(
            r.x, r.knn_idx, r.knn_dist, x_new, key=kg, cfg=cfg,
            qc_idx=qc_idx, qc_dist=qc_dist)
        w_all = perp_lib.edge_weights(idx_all, dist_all, cfg.perplexity,
                                      iters=cfg.perplexity_iters)
        r.x = x_all
        r.y = jnp.concatenate([jnp.asarray(r.y, jnp.float32), y_new])
        r.knn_idx, r.knn_dist, r.weights = idx_all, dist_all, w_all
        r.edge_sampler = sampler_lib.build_edge_sampler(
            idx_all, w_all, impl=cfg.sampler_impl)
        r.neg_sampler = sampler_lib.build_negative_sampler(
            idx_all, w_all, power=cfg.neg_power, impl=cfg.sampler_impl)
        return y_new
