"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce at 1000+ node scale).

Per-leaf, per-block stochastic int8 quantization: the DP all-reduce then
moves 4x fewer bytes (grads are f32).  Error feedback keeps the residual
locally and re-adds it next step — convergence-neutral in expectation
(Karimireddy et al. 2019).  Composable: wrap any grad tree before the
optimizer; tests assert the quantization error bound and EF drift cancel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 2048


def _quantize_leaf(g, key):
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    units = flat / scale
    noise = jax.random.uniform(key, units.shape) - 0.5
    q = jnp.clip(jnp.round(units + noise), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress(grads, key):
    """grads -> (quantized tree of (q, scale), same structure)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [_quantize_leaf(g, k) for g, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def decompress(qtree, like):
    def one(qs, g):
        q, scale = qs
        return _dequantize_leaf(q, scale, g.shape)
    return jax.tree.map(one, qtree, like,
                        is_leaf=lambda x: isinstance(x, tuple))


def compressed_grads_with_ef(grads, ef_state, key):
    """(decompressed grads for the optimizer, new error-feedback state).

    The all-reduce would operate on the int8 payload; on a single host this
    function is semantically identical (quantize -> [all-reduce] ->
    dequantize) and is what the distributed step wraps around psum.
    """
    if ef_state is None:
        ef_state = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(lambda g, e: g + e, grads, ef_state)
    q = compress(corrected, key)
    deq = decompress(q, corrected)
    new_ef = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return deq, new_ef


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(f32)."""
    def leaf_bytes(g):
        n = g.size
        blocks = (n + BLOCK - 1) // BLOCK
        return n * 1 + blocks * 4, n * 4
    comp, full = 0, 0
    for g in jax.tree.leaves(grads):
        c, f = leaf_bytes(g)
        comp += c
        full += f
    return comp / max(full, 1)
