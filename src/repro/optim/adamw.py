"""Minimal AdamW (pytree-native, sharding-transparent).

Optimizer state lives in two pytrees (m, v) with the SAME structure as the
params, so every sharding rule that applies to a param leaf applies verbatim
to its optimizer moments — the property the dry-run relies on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100


def adamw_init(params):
    return {"m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gn + 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}
