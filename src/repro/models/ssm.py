"""Mamba (S6) selective state-space layer.

Diagonal linear recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t with
input-dependent (selective) dt/B/C.  Implemented as a *chunked* scan: within
a chunk the recurrence is an associative scan (log-depth, fully parallel);
chunks are chained with a lax.scan carrying the state — bounded memory at
500k sequence lengths and a compact HLO.  Registers CostBook corrections for
the chunk loop.  Decode is a single-token state update against the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import costbook
from repro.models.layers import dense_init


def init_mamba(key, cfg) -> dict:
    d = cfg.d_model
    inner = d * cfg.ssm_expand
    state = cfg.ssm_state
    dt_rank = max(8, int(np.ceil(d / 16)))
    ks = jax.random.split(key, 8)
    # S4-style A init: -(1..state) per channel
    a = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None, :],
                 (inner, 1))
    dt_bias = jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1)
        jax.random.uniform(ks[6], (inner,), minval=1e-3, maxval=1e-1)))
    return {
        "w_in": dense_init(ks[0], (d, 2 * inner)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, inner), scale=0.2),
        "conv_b": jnp.zeros((inner,), jnp.float32),
        "w_b": dense_init(ks[2], (inner, state)),
        "w_c": dense_init(ks[3], (inner, state)),
        "w_dt_down": dense_init(ks[4], (inner, dt_rank)),
        "w_dt_up": dense_init(ks[5], (dt_rank, inner)),
        "dt_bias": dt_bias,
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((inner,), jnp.float32),
        "w_out": dense_init(ks[7], (inner, d)),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array = None) -> jax.Array:
    """Depthwise causal conv.  u: (B,S,inner); w: (K,inner).
    prev: (B,K-1,inner) carried context for decode/chunking (None = zeros)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([prev, u], axis=1)                    # (B,S+K-1,in)
    out = sum(up[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
              for i in range(K))
    return out + b.astype(u.dtype)


def _ssm_params(params, u, cfg):
    """Selective dt/B/C from the (conv'd, silu'd) input u: (B,L,inner)."""
    f32 = jnp.float32
    dt = u.astype(f32) @ params["w_dt_down"] @ params["w_dt_up"]
    dt = jax.nn.softplus(dt + params["dt_bias"])               # (B,L,inner)
    bm = u.astype(f32) @ params["w_b"]                         # (B,L,state)
    cm = u.astype(f32) @ params["w_c"]                         # (B,L,state)
    a = -jnp.exp(params["a_log"])                              # (inner,state)
    da = jnp.exp(dt[..., None] * a)                            # (B,L,in,st)
    dbu = (dt * u.astype(f32))[..., None] * bm[:, :, None, :]  # (B,L,in,st)
    return da, dbu, cm, dt


def _chunk_scan(da, dbu, h0):
    """Associative scan within a chunk; returns (h_all, h_last).
    da/dbu: (B,L,inner,state); h0: (B,inner,state)."""
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    acum, hloc = jax.lax.associative_scan(comb, (da, dbu), axis=1)
    h = acum * h0[:, None] + hloc
    return h, h[:, -1]


def mamba_fwd(params: dict, x: jax.Array, cfg, chunk: int = 256):
    """Full-sequence forward.  x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    dtype = x.dtype
    inner = d * cfg.ssm_expand
    state = cfg.ssm_state
    uz = x @ params["w_in"].astype(dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))

    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    uc = u.reshape(B, nc, chunk, inner).swapaxes(0, 1)          # (nc,B,L,in)

    def step(h, u_blk):
        da, dbu, cm, _ = _ssm_params(params, u_blk, cfg)
        h_all, h_last = _chunk_scan(da, dbu, h)
        y = jnp.einsum("blis,bls->bli", h_all, cm)
        return h_last, y.astype(dtype)

    h0 = jnp.zeros((B, inner, state), jnp.float32)
    _, ys = jax.lax.scan(step, h0, uc)
    y = ys.swapaxes(0, 1).reshape(B, S, inner)
    y = y + u * params["d_skip"].astype(dtype)
    y = y * jax.nn.silu(z)
    costbook.record(
        "mamba_scan",
        total_flops=10.0 * B * S * inner * state,
        total_bytes=8.0 * B * S * inner * state,
        trips=nc)
    return y @ params["w_out"].astype(dtype)


def mamba_prefill(params, x, cfg, chunk: int = 256):
    """Returns (out, cache) — cache carries final ssm state + conv tail."""
    B, S, d = x.shape
    dtype = x.dtype
    inner = d * cfg.ssm_expand
    uz = x @ params["w_in"].astype(dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    uc_raw = u
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))
    chunk = min(chunk, S)
    nc = S // chunk
    ucs = u.reshape(B, nc, chunk, inner).swapaxes(0, 1)

    def step(h, u_blk):
        da, dbu, cm, _ = _ssm_params(params, u_blk, cfg)
        h_all, h_last = _chunk_scan(da, dbu, h)
        y = jnp.einsum("blis,bls->bli", h_all, cm)
        return h_last, y.astype(dtype)

    h0 = jnp.zeros((B, inner, cfg.ssm_state), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, ucs)
    y = ys.swapaxes(0, 1).reshape(B, S, inner)
    y = (y + u * params["d_skip"].astype(dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"].astype(dtype)
    cache = {"ssm": h_final,
             "conv": uc_raw[:, S - (cfg.ssm_conv - 1):, :]}
    return out, cache


def mamba_decode(params, x, cfg, cache):
    """One token.  x: (B,1,d); cache: {ssm:(B,inner,state), conv:(B,K-1,inner)}."""
    B, _, d = x.shape
    dtype = x.dtype
    uz = x @ params["w_in"].astype(dtype)
    u_raw, z = jnp.split(uz, 2, axis=-1)                        # (B,1,inner)
    new_conv = jnp.concatenate([cache["conv"], u_raw], axis=1)[:, 1:]
    u = jax.nn.silu(
        _causal_conv(u_raw, params["conv_w"], params["conv_b"],
                     prev=cache["conv"].astype(dtype)))
    da, dbu, cm, _ = _ssm_params(params, u, cfg)                # (B,1,...)
    h = cache["ssm"] * da[:, 0] + dbu[:, 0]                    # (B,in,st)
    y = jnp.einsum("bis,bs->bi", h, cm[:, 0])[:, None, :].astype(dtype)
    y = (y + u * params["d_skip"].astype(dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"].astype(dtype)
    return out, {"ssm": h, "conv": new_conv}


def mamba_flops(cfg, n_tokens: int) -> float:
    d = cfg.d_model
    inner = d * cfg.ssm_expand
    state = cfg.ssm_state
    dt_rank = max(8, int(np.ceil(d / 16)))
    proj = 2.0 * n_tokens * d * 3 * inner                       # in + out
    sel = 2.0 * n_tokens * inner * (2 * state + 2 * dt_rank)
    scan = 10.0 * n_tokens * inner * state
    return proj + sel + scan
