"""Trip-count cost corrections for scanned regions.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE (verified in
this container: an 8-step scanned matmul reports 1x matmul FLOPs).  Modules
wrapping compute in sequence-level scans (chunked attention, SSM chunk scans,
token-level recurrences) record their *analytic* totals here at trace time so
the roofline harness can correct the raw HLO numbers:

    corrected = raw + sum(total * (trips - 1) / trips)

Layer-stack scans are handled separately (single-body lowering) in
``benchmarks/roofline.py``; this book only carries *inner* scans, which by
construction contain no collectives.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

_STATE = threading.local()


@dataclasses.dataclass
class CostEntry:
    label: str
    total_flops: float      # analytic flops for ALL trips of the scanned op
    total_bytes: float      # analytic HBM bytes for ALL trips
    trips: int

    @property
    def flops_correction(self) -> float:
        return self.total_flops * (self.trips - 1) / max(self.trips, 1)

    @property
    def bytes_correction(self) -> float:
        return self.total_bytes * (self.trips - 1) / max(self.trips, 1)


class CostBook:
    def __init__(self):
        self.entries: list = []

    def add(self, label: str, total_flops: float, total_bytes: float,
            trips: int) -> None:
        self.entries.append(CostEntry(label, float(total_flops),
                                      float(total_bytes), int(trips)))

    @property
    def flops_correction(self) -> float:
        return sum(e.flops_correction for e in self.entries)

    @property
    def bytes_correction(self) -> float:
        return sum(e.bytes_correction for e in self.entries)


@contextlib.contextmanager
def recording():
    """Collect inner-scan cost corrections while tracing a step function."""
    prev = getattr(_STATE, "book", None)
    book = CostBook()
    _STATE.book = book
    try:
        yield book
    finally:
        _STATE.book = prev


def record(label: str, total_flops: float, total_bytes: float, trips: int,
           per_layer_mult: int = 1) -> None:
    """Called by modules at trace time; no-op when not recording."""
    book = getattr(_STATE, "book", None)
    if book is not None and trips > 1:
        book.add(label, total_flops * per_layer_mult,
                 total_bytes * per_layer_mult, trips)
