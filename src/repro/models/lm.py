"""Decoder-only LM assembly: embed -> scan over layer periods -> norm -> head.

Layer stacks are ``lax.scan``s over *periods* of the block pattern (period=1
for homogeneous archs): compact HLO at any depth, which keeps the 512-device
AOT dry-run compiles tractable (see DESIGN.md §6).  Heterogeneous patterns
(gemma3 5:1, jamba 1:7+MoE, xlstm m/s) unroll one period inside the scan
body.  Training wraps the period body in ``jax.checkpoint`` (activation
recomputation at period boundaries).

Modes: ``lm_loss`` (train), ``lm_prefill`` (full sequence -> last logits +
cache), ``lm_decode`` (one token vs cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm, xlstm
from repro.models.layers import (cross_entropy, embed, init_embedding,
                                 init_mlp, init_rmsnorm, mlp, rmsnorm,
                                 unembed)
from repro.runtime import sharding as shd

ATTN_KINDS = ("attn", "local", "global")


def _position_is_moe(cfg, p: int) -> bool:
    if cfg.n_experts == 0:
        return False
    assert len(cfg.block_pattern) % cfg.moe_every == 0
    return p % cfg.moe_every == (cfg.moe_every - 1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_block(key, cfg, p: int) -> dict:
    kind = cfg.block_pattern[p]
    ks = jax.random.split(key, 4)
    params = {"ln1": init_rmsnorm(cfg.d_model)}
    if kind in ATTN_KINDS:
        params["attn"] = attn.init_attention(ks[0], cfg)
    elif kind == "mamba":
        params["mamba"] = ssm.init_mamba(ks[0], cfg)
    elif kind == "mlstm":
        params["core"] = xlstm.init_mlstm(ks[0], cfg)
        return params
    elif kind == "slstm":
        params["core"] = xlstm.init_slstm(ks[0], cfg)
        return params
    else:
        raise ValueError(kind)
    params["ln2"] = init_rmsnorm(cfg.d_model)
    if _position_is_moe(cfg, p):
        params["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        params["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return params


def init_lm(key, cfg) -> dict:
    P = len(cfg.block_pattern)
    nper = cfg.n_periods
    keys = jax.random.split(key, P + 3)
    params = {"embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
              "final_norm": init_rmsnorm(cfg.d_model)}
    blocks = {}
    for p in range(P):
        pkeys = jax.random.split(keys[p + 1], nper)
        blocks[f"pos{p}"] = jax.vmap(
            lambda k, _p=p: init_block(k, cfg, _p))(pkeys)
    params["blocks"] = blocks
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(keys[-1], cfg.vocab_size,
                                           cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def apply_block(cfg, p: int, params: dict, x: jax.Array, *, mode: str,
                cache: dict = None, position: jax.Array = None,
                positions: jax.Array = None, attn_impl: str = "auto",
                kv_repeat: int = 1, kv_quant: bool = False):
    """Returns (x, new_cache, aux)."""
    kind = cfg.block_pattern[p]
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        if mode == "fwd":
            a = attn.attention_fwd(params["attn"], h, cfg, kind=kind,
                                   positions=positions, impl=attn_impl)
        elif mode == "prefill":
            a, new_cache = attn.attention_prefill(
                params["attn"], h, cfg, kind=kind, positions=positions,
                impl=attn_impl, kv_repeat=kv_repeat, kv_quant=kv_quant)
        else:
            a, new_cache = attn.attention_decode(
                params["attn"], h, cfg, cache, position, kind=kind)
        x = x + a
    elif kind == "mamba":
        if mode == "fwd":
            a = ssm.mamba_fwd(params["mamba"], h, cfg)
        elif mode == "prefill":
            a, new_cache = ssm.mamba_prefill(params["mamba"], h, cfg)
        else:
            a, new_cache = ssm.mamba_decode(params["mamba"], h, cfg, cache)
        x = x + a
    elif kind in ("mlstm", "slstm"):
        fns = {"mlstm": (xlstm.mlstm_fwd, xlstm.mlstm_prefill,
                         xlstm.mlstm_decode),
               "slstm": (xlstm.slstm_fwd, xlstm.slstm_prefill,
                         xlstm.slstm_decode)}[kind]
        if mode == "fwd":
            a = fns[0](params["core"], h, cfg)
        elif mode == "prefill":
            a, new_cache = fns[1](params["core"], h, cfg)
        else:
            a, new_cache = fns[2](params["core"], h, cfg, cache)
        return x + a, new_cache, aux
    else:
        raise ValueError(kind)

    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        if mode == "fwd":
            m, aux = moe_lib.moe_apply(params["moe"], h, cfg)
        else:
            m, _ = moe_lib.moe_apply(params["moe"], h, cfg)
    else:
        m = mlp(params["mlp"], h, cfg.mlp_type)
    return x + m, new_cache, aux


def apply_period(cfg, period_params: dict, x: jax.Array, *, mode: str,
                 cache: dict = None, position=None, positions=None,
                 attn_impl: str = "auto", kv_repeat: int = 1,
                 kv_quant: bool = False):
    """One full period (all positions).  Standalone for roofline lowering."""
    P = len(cfg.block_pattern)
    new_cache = {}
    aux_total = jnp.zeros((), jnp.float32)
    for p in range(P):
        c_in = cache[f"pos{p}"] if cache is not None else None
        x, c_out, aux = apply_block(
            cfg, p, period_params[f"pos{p}"], x, mode=mode, cache=c_in,
            position=position, positions=positions, attn_impl=attn_impl,
            kv_repeat=kv_repeat, kv_quant=kv_quant)
        if c_out is not None:
            new_cache[f"pos{p}"] = c_out
        aux_total = aux_total + aux
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Full stacks
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, tokens):
    x = embed(params["embed"], tokens, cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    return shd.constrain_batch_major(x)


def _logits(params, cfg, x):
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["lm_head"]["table"]
    return shd.constrain_logits(unembed({}, x, table=table))


def lm_backbone(params, cfg, tokens, *, positions=None,
                attn_impl: str = "auto", remat: bool = False):
    """(B,S) tokens -> (B,S,d) hidden states (pre-final-norm is applied)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)
    x = _embed_in(params, cfg, tokens)

    def period_fn(carry, pp):
        x, aux = carry
        x, _, aux_p = apply_period(cfg, pp, x, mode="fwd",
                                   positions=positions, attn_impl=attn_impl)
        return (shd.constrain_batch_major(x), aux + aux_p), None

    fn = jax.checkpoint(period_fn) if remat else period_fn
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def lm_loss(params, cfg, tokens, labels, *, attn_impl: str = "auto",
            aux_coef: float = 0.01, remat: bool = True):
    x, aux = lm_backbone(params, cfg, tokens, attn_impl=attn_impl,
                         remat=remat)
    logits = _logits(params, cfg, x)
    loss = cross_entropy(logits, labels)
    if cfg.n_experts:
        loss = loss + aux_coef * aux / max(cfg.n_periods, 1)
    return loss


def lm_prefill(params, cfg, tokens, *, attn_impl: str = "auto",
               kv_repeat: int = 1, kv_quant: bool = False):
    """Returns (last-position logits (B,V), cache pytree)."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed_in(params, cfg, tokens)

    def period_fn(x, pp):
        x, cache_p, _ = apply_period(cfg, pp, x, mode="prefill",
                                     positions=positions,
                                     attn_impl=attn_impl,
                                     kv_repeat=kv_repeat, kv_quant=kv_quant)
        return shd.constrain_batch_major(x), cache_p

    x, cache = jax.lax.scan(period_fn, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x[:, -1])
    return logits, cache


def lm_decode(params, cfg, tokens, cache, position):
    """tokens: (B,1); position: (B,) index of the new token.
    Returns (logits (B,V), new cache)."""
    x = _embed_in(params, cfg, tokens)

    def period_fn(x, inp):
        pp, cache_p = inp
        x, new_cache_p, _ = apply_period(cfg, pp, x, mode="decode",
                                         cache=cache_p, position=position)
        return shd.constrain_batch_major(x), new_cache_p

    x, new_cache = jax.lax.scan(period_fn, x, (params["blocks"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x[:, -1])
    return logits, new_cache
