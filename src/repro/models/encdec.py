"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv frontend is a STUB per the assignment: ``encoder_frames``
(precomputed (B, F, d) frame embeddings) arrive as an input.  Encoder is
bidirectional self-attention; decoder interleaves causal self-attention,
cross-attention to the encoder output, and a GELU MLP.  Learned absolute
positions (no RoPE).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.layers import (cross_entropy, dense_init, embed,
                                 init_embedding, init_layernorm, init_mlp,
                                 layernorm, mlp, unembed)
from repro.runtime import sharding as shd


def init_cross_attention(key, cfg) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model),
                         scale=1.0 / np.sqrt(cfg.n_heads * hd)),
    }


def cross_attention(params, x, enc_out, cfg):
    """x: (B,Sq,d) queries; enc_out: (B,F,d)."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dtype))
    qpos = jnp.arange(q.shape[1])
    kpos = jnp.arange(k.shape[1])
    o = attn.mha_full(q, k, v, qpos, kpos, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dtype))


def init_enc_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {"ln1": init_layernorm(cfg.d_model),
            "attn": attn.init_attention(ks[0], cfg),
            "ln2": init_layernorm(cfg.d_model),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, "gelu", bias=True)}


def init_dec_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 3)
    return {"ln1": init_layernorm(cfg.d_model),
            "attn": attn.init_attention(ks[0], cfg),
            "ln_x": init_layernorm(cfg.d_model),
            "xattn": init_cross_attention(ks[1], cfg),
            "ln2": init_layernorm(cfg.d_model),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, "gelu", bias=True)}


def init_encdec(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_pos": jax.random.normal(ks[2], (cfg.enc_positions, cfg.d_model),
                                     jnp.float32) * 0.02,
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_layernorm(cfg.d_model),
        "embed": init_embedding(ks[3], cfg.vocab_size, cfg.d_model),
        "dec_pos": jax.random.normal(ks[4], (cfg.max_position, cfg.d_model),
                                     jnp.float32) * 0.02,
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": init_layernorm(cfg.d_model),
    }


def encode(params, cfg, frames):
    """frames: (B,F,d) stub conv output -> (B,F,d)."""
    x = frames + params["enc_pos"].astype(frames.dtype)[None]

    def layer(x, lp):
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        x = x + attn.attention_fwd(lp["attn"], h, cfg, causal=False,
                                   impl="full")
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        return shd.constrain_batch_major(x + mlp(lp["mlp"], h, "gelu")), None

    x, _ = jax.lax.scan(layer, x, params["enc_layers"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(cfg, lp, x, enc_out, *, mode, cache=None, position=None,
               positions=None):
    h = layernorm(lp["ln1"], x, cfg.norm_eps)
    new_cache = None
    if mode == "fwd":
        a = attn.attention_fwd(lp["attn"], h, cfg, positions=positions)
    elif mode == "prefill":
        a, new_cache = attn.attention_prefill(lp["attn"], h, cfg,
                                              positions=positions)
    else:
        a, new_cache = attn.attention_decode(lp["attn"], h, cfg, cache,
                                             position)
    x = x + a
    h = layernorm(lp["ln_x"], x, cfg.norm_eps)
    x = x + cross_attention(lp["xattn"], h, enc_out, cfg)
    h = layernorm(lp["ln2"], x, cfg.norm_eps)
    return x + mlp(lp["mlp"], h, "gelu"), new_cache


def _dec_positions(params, positions, dtype):
    return params["dec_pos"].astype(dtype)[positions]


def encdec_loss(params, cfg, tokens, labels, encoder_frames):
    enc_out = encode(params, cfg, encoder_frames)
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = embed(params["embed"], tokens, cfg.dtype)
    x = x + _dec_positions(params, positions, cfg.dtype)[None]

    def layer(x, lp):
        x, _ = _dec_layer(cfg, lp, x, enc_out, mode="fwd",
                          positions=positions)
        return shd.constrain_batch_major(x), None

    x, _ = jax.lax.scan(layer, x, params["dec_layers"])
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = shd.constrain_logits(
        unembed({}, x, table=params["embed"]["table"]))
    return cross_entropy(logits, labels)


def encdec_prefill(params, cfg, tokens, encoder_frames):
    enc_out = encode(params, cfg, encoder_frames)
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = embed(params["embed"], tokens, cfg.dtype)
    x = x + _dec_positions(params, positions, cfg.dtype)[None]

    def layer(x, lp):
        x, c = _dec_layer(cfg, lp, x, enc_out, mode="prefill",
                          positions=positions)
        return shd.constrain_batch_major(x), c

    x, cache = jax.lax.scan(layer, x, params["dec_layers"])
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = shd.constrain_logits(
        unembed({}, x[:, -1], table=params["embed"]["table"]))
    return logits, {"self": cache, "encoder_out": enc_out}


def encdec_decode(params, cfg, tokens, cache, position):
    enc_out = cache["encoder_out"]
    x = embed(params["embed"], tokens, cfg.dtype)
    x = x + _dec_positions(params, position, cfg.dtype)[:, None, :]

    def layer(x, inp):
        lp, c = inp
        x, new_c = _dec_layer(cfg, lp, x, enc_out, mode="decode", cache=c,
                              position=position)
        return shd.constrain_batch_major(x), new_c

    x, new_self = jax.lax.scan(layer, x, (params["dec_layers"],
                                          cache["self"]))
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = shd.constrain_logits(
        unembed({}, x[:, -1], table=params["embed"]["table"]))
    return logits, {"self": new_self, "encoder_out": enc_out}
