"""xLSTM blocks: mLSTM (matrix memory, 2x expansion) and sLSTM (scalar
memory with head-wise recurrent gating).

Both use exponential gating with the max-stabilizer state m (xLSTM paper,
arXiv:2405.04517).  The recurrences are token-level lax.scans — sLSTM is
inherently sequential (gates depend on h_{t-1}); mLSTM additionally has a
chunked-parallel form implemented as a §Perf optimization in
``mlstm_fwd_chunked``.  CostBook corrections are registered for the scans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import costbook
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg) -> dict:
    d = cfg.d_model
    inner = 2 * d
    nh = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * inner)),     # -> (u, z)
        "w_q": dense_init(ks[1], (inner, inner)),
        "w_k": dense_init(ks[2], (inner, inner)),
        "w_v": dense_init(ks[3], (inner, inner)),
        "w_i": dense_init(ks[4], (inner, nh), scale=0.02),
        "b_i": jnp.zeros((nh,), jnp.float32),
        "w_f": dense_init(ks[5], (inner, nh), scale=0.02),
        "b_f": jnp.full((nh,), 3.0, jnp.float32),      # forget-open init
        "norm": init_rmsnorm(inner),
        "w_down": dense_init(ks[6], (inner, d)),
    }


def _mlstm_qkvgates(params, x, cfg):
    dtype = x.dtype
    d = cfg.d_model
    inner = 2 * d
    nh = cfg.n_heads
    dh = inner // nh
    uz = x @ params["w_up"].astype(dtype)
    u, z = jnp.split(uz, 2, axis=-1)                   # (B,S,inner)
    B, S, _ = u.shape
    q = (u @ params["w_q"].astype(dtype)).reshape(B, S, nh, dh)
    k = (u @ params["w_k"].astype(dtype)).reshape(B, S, nh, dh) / np.sqrt(dh)
    v = (u @ params["w_v"].astype(dtype)).reshape(B, S, nh, dh)
    it = (u.astype(jnp.float32) @ params["w_i"] + params["b_i"])   # (B,S,nh)
    ft = (u.astype(jnp.float32) @ params["w_f"] + params["b_f"])
    return q, k, v, it, ft, z


def _mlstm_step(carry, inp):
    """carry: (C:(B,nh,dh,dh), n:(B,nh,dh), m:(B,nh)); one token."""
    C, n, m = carry
    q, k, v, it, ft = inp                              # (B,nh,dh)x3,(B,nh)x2
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])           # (B,nh,dh,dh)
    n = f_p[..., None] * n + i_p[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return (C, n, m_new), h


def mlstm_fwd(params: dict, x: jax.Array, cfg) -> jax.Array:
    B, S, d = x.shape
    dtype = x.dtype
    inner = 2 * d
    nh = cfg.n_heads
    dh = inner // nh
    q, k, v, it, ft, z = _mlstm_qkvgates(params, x, cfg)
    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.zeros((B, nh), jnp.float32)
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          it.swapaxes(0, 1), ft.swapaxes(0, 1))
    _, hs = jax.lax.scan(_mlstm_step, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, inner).astype(dtype)
    costbook.record("mlstm_scan",
                    total_flops=6.0 * B * S * nh * dh * dh,
                    total_bytes=8.0 * B * S * nh * dh * dh,
                    trips=S)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["w_down"].astype(dtype)


def mlstm_prefill(params, x, cfg):
    B, S, d = x.shape
    dtype = x.dtype
    inner = 2 * d
    nh = cfg.n_heads
    dh = inner // nh
    q, k, v, it, ft, z = _mlstm_qkvgates(params, x, cfg)
    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.zeros((B, nh), jnp.float32)
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          it.swapaxes(0, 1), ft.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(_mlstm_step, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, inner).astype(dtype)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["w_down"].astype(dtype), {"C": C, "n": n, "m": m}


def mlstm_decode(params, x, cfg, cache):
    B, _, d = x.shape
    dtype = x.dtype
    inner = 2 * d
    nh = cfg.n_heads
    dh = inner // nh
    q, k, v, it, ft, z = _mlstm_qkvgates(params, x, cfg)
    inp = (q[:, 0], k[:, 0], v[:, 0], it[:, 0], ft[:, 0])
    (C, n, m), h = _mlstm_step((cache["C"], cache["n"], cache["m"]), inp)
    h = h.reshape(B, 1, inner).astype(dtype)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["w_down"].astype(dtype), {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    return {
        "w_x": dense_init(ks[0], (d, 4 * d)),          # z,i,f,o pre-acts
        "b_x": jnp.concatenate([
            jnp.zeros((d,)), jnp.zeros((d,)),
            jnp.full((d,), 3.0), jnp.zeros((d,))]).astype(jnp.float32),
        "r": dense_init(ks[1], (nh, dh, 4 * dh),       # head-wise recurrence
                        scale=1.0 / np.sqrt(dh)),
        "norm": init_rmsnorm(d),
        "w_out": dense_init(ks[2], (d, d)),
    }


def _slstm_step(params, cfg, carry, xproj):
    """carry: (h,c,n,m) each (B,nh,dh); xproj: (B,4d) input pre-activation."""
    h, c, n, m = carry
    B = h.shape[0]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    rec = jnp.einsum("bhd,hde->bhe", h, params["r"])   # (B,nh,4dh)
    pre = xproj.reshape(B, nh, 4 * dh) + rec
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)        # (B,nh,dh)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c = f_p * c + i_p * zt
    n = f_p * n + i_p
    h_new = ot * c / jnp.maximum(n, 1e-6)
    return (h_new, c, n, m_new)


def slstm_fwd(params: dict, x: jax.Array, cfg) -> jax.Array:
    B, S, d = x.shape
    dtype = x.dtype
    nh = cfg.n_heads
    dh = d // nh
    xp = (x.astype(jnp.float32) @ params["w_x"] + params["b_x"])

    def step(carry, xt):
        new = _slstm_step(params, cfg, carry, xt)
        return new, new[0]

    z0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.zeros((B, nh, dh), jnp.float32)
    _, hs = jax.lax.scan(step, (z0, z0, z0, m0), xp.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(dtype)
    costbook.record("slstm_scan",
                    total_flops=2.0 * B * S * nh * dh * 4 * dh,
                    total_bytes=4.0 * B * S * d,
                    trips=S)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    return h @ params["w_out"].astype(dtype)


def slstm_prefill(params, x, cfg):
    B, S, d = x.shape
    dtype = x.dtype
    nh = cfg.n_heads
    dh = d // nh
    xp = (x.astype(jnp.float32) @ params["w_x"] + params["b_x"])

    def step(carry, xt):
        new = _slstm_step(params, cfg, carry, xt)
        return new, new[0]

    z0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.zeros((B, nh, dh), jnp.float32)
    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(
        step, (z0, z0, z0, m0), xp.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(dtype)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    out = h @ params["w_out"].astype(dtype)
    return out, {"h": h_f, "c": c_f, "n": n_f, "m": m_f}


def slstm_decode(params, x, cfg, cache):
    B, _, d = x.shape
    dtype = x.dtype
    xp = (x[:, 0].astype(jnp.float32) @ params["w_x"] + params["b_x"])
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    h_new, c, n, m = _slstm_step(params, cfg, carry, xp)
    h = h_new.reshape(B, 1, d).astype(dtype)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    out = h @ params["w_out"].astype(dtype)
    return out, {"h": h_new, "c": c, "n": n, "m": m}


def xlstm_flops(cfg, n_tokens: int, kind: str) -> float:
    d = cfg.d_model
    nh = cfg.n_heads
    if kind == "mlstm":
        inner = 2 * d
        dh = inner // nh
        proj = 2.0 * n_tokens * d * (2 * inner) + \
            2.0 * n_tokens * inner * (3 * inner + d)
        rec = 6.0 * n_tokens * nh * dh * dh
        return proj + rec
    dh = d // nh
    proj = 2.0 * n_tokens * d * 4 * d + 2.0 * n_tokens * d * d
    rec = 2.0 * n_tokens * nh * dh * 4 * dh
    return proj + rec
