"""Shared primitive layers: norms, rotary embeddings, MLPs, embeddings.

Pure-pytree parameter style: every layer is an ``init_*`` returning a dict of
arrays and an ``apply`` function.  No framework dependency; all control flow
is jax.lax.  Compute dtype follows the config; params are stored in f32
(master weights) and cast at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


def init_layernorm(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim//2,)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float = None) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str,
             bias: bool = False) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k1, (d_model, d_ff))
        p["w_up"] = dense_init(k2, (d_model, d_ff))
        p["w_down"] = dense_init(k3, (d_ff, d_model))
    elif mlp_type == "gelu":
        p["w_up"] = dense_init(k1, (d_model, d_ff))
        p["w_down"] = dense_init(k2, (d_ff, d_model))
        if bias:
            p["b_up"] = jnp.zeros((d_ff,), jnp.float32)
            p["b_down"] = jnp.zeros((d_model,), jnp.float32)
    else:
        raise ValueError(mlp_type)
    return p


def mlp(params: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    dtype = x.dtype
    if mlp_type in ("swiglu", "geglu"):
        gate = x @ params["w_gate"].astype(dtype)
        up = x @ params["w_up"].astype(dtype)
        act = jax.nn.silu(gate) if mlp_type == "swiglu" else \
            jax.nn.gelu(gate, approximate=True)
        return (act * up) @ params["w_down"].astype(dtype)
    h = x @ params["w_up"].astype(dtype)
    if "b_up" in params:
        h = h + params["b_up"].astype(dtype)
    h = jax.nn.gelu(h, approximate=True)
    out = h @ params["w_down"].astype(dtype)
    if "b_down" in params:
        out = out + params["b_down"].astype(dtype)
    return out


def mlp_flops(d_model: int, d_ff: int, mlp_type: str, n_tokens: int) -> float:
    n_mats = 3 if mlp_type in ("swiglu", "geglu") else 2
    return 2.0 * n_mats * d_model * d_ff * n_tokens


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d_model),
                                       jnp.float32) * 0.02}


def embed(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jax.Array, table: jax.Array = None) -> jax.Array:
    """Logits in f32 (softmax stability)."""
    t = table if table is not None else params["table"]
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      t.astype(jnp.float32))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with optional z-loss; logits f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
