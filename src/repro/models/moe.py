"""Mixture-of-Experts with sort-based token dispatch (MegaBlocks-style).

Dense one-hot dispatch tensors (T,E,C) blow up memory at production token
counts; instead tokens are argsorted by expert id and gathered into a padded
(E, capacity, d) buffer — linear memory, and the expert einsum batches over
the expert axis, which shards cleanly (EP) over the mesh.

Capacity overflow tokens are dropped (standard); the router aux loss is the
Switch-style load-balance term E * sum_e f_e * p_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init
from repro.runtime import sharding as shd
from repro.runtime.compat import shard_map


def init_moe(key, cfg) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        # expert arrays are (E, in, out): fan-in is axis 1, not axis 0
        "w_gate": dense_init(ks[1], (E, d, f), scale=1.0 / np.sqrt(d)),
        "w_up": dense_init(ks[2], (E, d, f), scale=1.0 / np.sqrt(d)),
        "w_down": dense_init(ks[3], (E, f, d), scale=1.0 / np.sqrt(f)),
    }


def capacity(n_tokens: int, n_experts: int, topk: int,
             factor: float = 1.25) -> int:
    c = int(np.ceil(n_tokens * topk * factor / n_experts))
    return max(8, int(np.ceil(c / 8)) * 8)


def moe_apply(params: dict, x: jax.Array, cfg,
              capacity_factor: float = 1.25):
    """x: (B,S,d) -> (y, aux_loss).

    Under an activation policy (distributed step), dispatch runs LOCALLY per
    DP shard via shard_map: data-dependent scatter/gather does not SPMD-
    partition (the global path materializes (E, C_global, d) — 40 GiB at a
    1M-token prefill), so each shard routes its own tokens and the expert
    einsum runs on model-axis weight slices with a psum combine.  The global
    path below remains for single-host execution and as the oracle the
    sharded path is tested against.
    """
    pol = shd.current_policy()
    if pol is not None and pol[1] is not None:
        return _moe_apply_sharded(params, x, cfg, capacity_factor, pol)
    return _moe_apply_global(params, x, cfg, capacity_factor)


def _moe_apply_sharded(params, x, cfg, capacity_factor, pol):
    mesh, dp, train = pol
    from jax.sharding import PartitionSpec as P
    fsdp = "data" if train else None

    def g(shape, spec):
        return shd._guard(mesh, shape, spec)

    r_spec = g(params["router"].shape, [fsdp, None])
    wg_spec = g(params["w_gate"].shape,
                [None if train else "data", fsdp, "model"])
    wd_spec = g(params["w_down"].shape,
                [None if train else "data", "model", fsdp])
    x_spec = P(dp, None, None)

    ep_axis = wg_spec[0]                # experts resident per-shard (EP)?
    if ep_axis is not None:
        # EP strategy (classic tradeoff): route TOKENS when their traffic
        # is below the resident weight stack (decode: ~MBs of slots vs
        # hundreds of MB of weights), otherwise gather WEIGHTS (prefill /
        # train: millions of tokens dwarf the weights — §Perf hillclimb 2
        # iter 2 fixed a 2.8x prefill regression from unconditional a2a).
        n_shards = 1
        for a in (ep_axis if isinstance(ep_axis, tuple) else (ep_axis,)):
            n_shards *= mesh.shape[a]
        B_, S_, d_ = x.shape
        t_loc = (B_ * S_) // max(
            1, (B_ * S_ if dp is None else
                int(np.prod([mesh.shape[a] for a in dp]))))
        c_loc = capacity(max(t_loc, 1), cfg.n_experts, cfg.topk_experts,
                         capacity_factor)
        token_bytes = 2 * cfg.n_experts * c_loc * d_ * 2
        weight_bytes = (3 * cfg.n_experts * d_ * cfg.d_ff * 2
                        // max(1, mesh.shape.get("model", 1)))
        if token_bytes >= weight_bytes:
            ep_axis = None              # fall back to weight gathering

    gather_ep = wg_spec[0] is not None and ep_axis is None

    def body(router, wg, wu, wd, xl):
        # FSDP gathers: reassemble full (E, d, ff_local) weight slices
        if r_spec[0] is not None:
            router = jax.lax.all_gather(router, r_spec[0], axis=0,
                                        tiled=True)
        if gather_ep:                   # weight-gather EP (token-heavy)
            wg = jax.lax.all_gather(wg, wg_spec[0], axis=0, tiled=True)
            wu = jax.lax.all_gather(wu, wg_spec[0], axis=0, tiled=True)
            wd = jax.lax.all_gather(wd, wd_spec[0], axis=0, tiled=True)
        if wg_spec[1] is not None:      # FSDP at training: gather d
            wg = jax.lax.all_gather(wg, wg_spec[1], axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, wg_spec[1], axis=1, tiled=True)
        if wd_spec[2] is not None:
            wd = jax.lax.all_gather(wd, wd_spec[2], axis=2, tiled=True)
        w = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        if ep_axis is None:
            # experts fully local (replicated, gathered, or ff-shard only)
            y, aux = _dispatch_and_compute(
                w, xl, cfg, capacity_factor, psum_axis="model")
        else:
            # true EP: all_to_all TOKEN slots to the shard holding their
            # expert (weights stay resident) — 2 small token buffers per
            # layer instead of the full expert stack (§Perf hillclimb 2)
            y, aux = _dispatch_ep_a2a(
                w, xl, cfg, capacity_factor, ep_axis=ep_axis,
                psum_axis="model")
        return y, jax.lax.pmean(aux, dp)

    return shard_map(
        body, mesh=mesh,
        in_specs=(r_spec, wg_spec, wg_spec, wd_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"],
      params["w_down"], x)


def _dispatch_ep_a2a(params, x, cfg, capacity_factor, *, ep_axis,
                     psum_axis):
    """Expert-parallel dispatch: local route -> all_to_all token slots to
    the expert's shard -> FFN on resident weights -> all_to_all back ->
    combine.  params weights are the LOCAL slices (E_local, d, ff_local)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.topk_experts
    dtype = x.dtype
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)
    fe = jnp.mean(one_hot.sum(1), axis=0) / K
    aux = E * jnp.sum(fe * me)

    C = capacity(T, E, K, capacity_factor)
    e_flat = top_e.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), K)
    w_flat = top_p.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, t_s = e_flat[order], t_flat[order]
    seg_starts = jnp.searchsorted(e_s, jnp.arange(E))
    pos = jnp.arange(T * K) - seg_starts[e_s]
    keep = pos < C
    dest = jnp.where(keep, e_s * C + pos, E * C)

    gathered = jnp.zeros((E * C + 1, d), dtype)
    gathered = gathered.at[dest].set(xf[t_s])
    g = gathered[:-1].reshape(E, C, d)                  # (E, C_local, d)

    # ship token slots to their expert's shard:
    # (E, C, d) -> (E_local, n_shards*C, d)
    ga = jax.lax.all_to_all(g, ep_axis, split_axis=0, concat_axis=1,
                            tiled=True)
    gate = jnp.einsum("ecd,edf->ecf", ga, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", ga, params["w_up"].astype(dtype))
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0,
                             tiled=True)                # back: (E, C_loc, d)

    out_flat = out.reshape(E * C, d)
    contrib = jnp.where(keep, w_flat[order], 0.0).astype(dtype)
    picked = jnp.where(keep[:, None],
                       out_flat[jnp.clip(dest, 0, E * C - 1)], 0.0)
    yf = jnp.zeros((T, d), dtype).at[t_s].add(picked * contrib[:, None])
    return yf.reshape(B, S, d), aux


def _moe_apply_global(params, x, cfg, capacity_factor):
    return _dispatch_and_compute(params, x, cfg, capacity_factor,
                                 psum_axis=None)


def _dispatch_and_compute(params, x, cfg, capacity_factor, *,
                          psum_axis=None):
    """Sort-based dispatch + expert FFN on (possibly local) tokens.

    psum_axis: mesh axis holding the ff shards of the expert weights
    (shard_map path) — w_down partial products are psum'd over it.
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.topk_experts
    dtype = x.dtype
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"])        # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # (T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch): E * sum_e f_e * p_e ----
    me = jnp.mean(probs, axis=0)                                # (E,)
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)       # (T,K,E)
    fe = jnp.mean(one_hot.sum(1), axis=0) / K
    aux = E * jnp.sum(fe * me)

    # ---- sort-based dispatch ----
    C = capacity(T, E, K, capacity_factor)
    e_flat = top_e.reshape(-1)                                  # (T*K,)
    t_flat = jnp.repeat(jnp.arange(T), K)
    w_flat = top_p.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    # rank within expert segment
    seg_starts = jnp.searchsorted(e_s, jnp.arange(E))
    pos = jnp.arange(T * K) - seg_starts[e_s]
    keep = pos < C
    dest = jnp.where(keep, e_s * C + pos, E * C)                # drop slot

    gathered = jnp.zeros((E * C + 1, d), dtype)
    gathered = gathered.at[dest].set(xf[t_s])
    g = gathered[:-1].reshape(E, C, d)

    # ---- expert FFN, batched over E ----
    gate = jnp.einsum("ecd,edf->ecf", g, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", g, params["w_up"].astype(dtype))
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))
    if psum_axis is not None:
        # shard_map path: ff was sharded over the model axis -> partial sums
        out = jax.lax.psum(out, psum_axis)

    # ---- combine back ----
    out_flat = out.reshape(E * C, d)
    contrib = jnp.where(keep, w_flat[order], 0.0).astype(dtype)
    picked = jnp.where(keep[:, None],
                       out_flat[jnp.clip(dest, 0, E * C - 1)], 0.0)
    yf = jnp.zeros((T, d), dtype).at[t_s].add(picked * contrib[:, None])
    return yf.reshape(B, S, d), aux


def moe_flops(cfg, n_tokens: int, capacity_factor: float = 1.25) -> float:
    C = capacity(n_tokens, cfg.n_experts, cfg.topk_experts, capacity_factor)
    per_expert = 2.0 * 3 * C * cfg.d_model * cfg.d_ff
    router = 2.0 * n_tokens * cfg.d_model * cfg.n_experts
    return per_expert * cfg.n_experts + router
