from repro.models.factory import make_model, param_specs, cache_specs  # noqa: F401
