"""Model factory: uniform (init, loss, prefill, decode) per architecture.

Every arch exposes the same step signatures so the launcher, dry-run, and
benchmarks are arch-agnostic:

    init_fn(key)                                   -> params
    loss_fn(params, batch)                         -> scalar
    prefill_fn(params, batch)                      -> (logits, cache)
    decode_fn(params, batch)                       -> (logits, cache)

``batch`` is the dict produced by ``configs.input_specs``.
"""
from __future__ import annotations

import jax

from repro.models import encdec, lm


def make_model(cfg, *, kv_repeat: int = 1, kv_quant: bool = False):
    if cfg.is_encoder_decoder:
        def init_fn(key):
            return encdec.init_encdec(key, cfg)

        def loss_fn(params, batch):
            return encdec.encdec_loss(params, cfg, batch["tokens"],
                                      batch["labels"],
                                      batch["encoder_frames"])

        def prefill_fn(params, batch):
            return encdec.encdec_prefill(params, cfg, batch["tokens"],
                                         batch["encoder_frames"])

        def decode_fn(params, batch):
            return encdec.encdec_decode(params, cfg, batch["tokens"],
                                        batch["cache"], batch["position"])
    else:
        def init_fn(key):
            return lm.init_lm(key, cfg)

        def loss_fn(params, batch):
            return lm.lm_loss(params, cfg, batch["tokens"], batch["labels"])

        def prefill_fn(params, batch):
            return lm.lm_prefill(params, cfg, batch["tokens"],
                                 kv_repeat=kv_repeat, kv_quant=kv_quant)

        def decode_fn(params, batch):
            return lm.lm_decode(params, cfg, batch["tokens"],
                                batch["cache"], batch["position"])
    return {"init": init_fn, "loss": loss_fn, "prefill": prefill_fn,
            "decode": decode_fn}


def param_specs(cfg, *, inference: bool = False):
    """ShapeDtypeStruct pytree of params — no allocation.

    inference=True casts matrix params (ndim >= 2) to the compute dtype
    (production serving loads bf16 weights; per-step f32->bf16 converts
    otherwise add ~50% to parameter HBM reads — §Perf hillclimb 1 iter 3).
    Norm scales/biases stay f32.
    """
    model = make_model(cfg)
    specs = jax.eval_shape(model["init"], jax.random.key(0))
    if not inference:
        return specs
    import jax.numpy as jnp

    def cast(s):
        if s.dtype == jnp.float32 and s.ndim >= 2:
            return jax.ShapeDtypeStruct(s.shape, cfg.dtype)
        return s

    return jax.tree.map(cast, specs)


def cache_specs(cfg, batch: int, seq_len: int, kv_repeat: int = 1,
                kv_quant: bool = False):
    """Cache structure for a decode cell, derived from the actual prefill
    function via eval_shape (no allocation, always layout-consistent)."""
    import jax.numpy as jnp
    model = make_model(cfg, kv_repeat=kv_repeat, kv_quant=kv_quant)
    specs = param_specs(cfg)
    batch_spec = {"tokens": jax.ShapeDtypeStruct((batch, seq_len),
                                                 jnp.int32)}
    if cfg.is_encoder_decoder:
        batch_spec["encoder_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_positions, cfg.d_model), cfg.dtype)
    out = jax.eval_shape(model["prefill"], specs, batch_spec)
    return out[1]
