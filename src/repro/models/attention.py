"""Attention: GQA, RoPE, sliding-window / local:global, QK-norm, KV cache.

Three execution paths:
  * ``full``     — one einsum + masked softmax (small S; also encoders).
  * ``chunked``  — flash-attention algorithm in pure lax.scan (nested q/kv
                   blocks, running max/denominator).  Bounded memory for 32k
                   prefill; registers CostBook corrections for the scanned
                   FLOPs (cost_analysis counts scan bodies once).
  * ``decode``   — single new token vs a filled cache (global: full-length
                   cache indexed by position; local: ring buffer of the
                   sliding window).

The Pallas flash kernel (kernels/flash_attention.py) is a drop-in for the
chunked path on real TPUs; the dry-run lowers the XLA paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import costbook
from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model),
                         scale=1.0 / np.sqrt(cfg.n_heads * hd)),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), jnp.float32)
    if cfg.qk_norm:
        p["qnorm"] = init_rmsnorm(hd)
        p["knorm"] = init_rmsnorm(hd)
    return p


def _project_qkv(params: dict, x: jax.Array, cfg, positions, theta: float):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.attn_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.qk_norm:
        q = rmsnorm(params["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(params["knorm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _theta_for(cfg, kind: str) -> float:
    # gemma3: local layers use the short-range 10k base, globals the long base
    if kind == "local" and cfg.rope_theta > 10_000.0 and \
            len(set(cfg.block_pattern)) > 1:
        return 10_000.0
    return cfg.rope_theta


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int) -> jax.Array:
    """(q, k) additive bias; window>0 limits lookback (sliding window)."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= dk <= dq
    if window > 0:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core attention (full / chunked)
# ---------------------------------------------------------------------------

def _gqa_scores_flops(B, Sq, Sk, H, hd):
    return 4.0 * B * H * Sq * Sk * hd  # qk^T + pv


def mha_full(q, k, v, q_pos, k_pos, *, causal=True, window=0):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KVH,hd). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / np.sqrt(hd) + _mask_bias(q_pos, k_pos, causal, window)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, Sq, H, hd)


def _chunk_fwd(q, k, v, q_pos, k_pos, causal, window, q_block, kv_block):
    """Forward streaming pass.  Returns (out (B,Sq,H,hd), lse (B,KVH,G,Sq))."""
    B, Sq, H, hd = q.shape
    KVH, Sk = k.shape[2], k.shape[1]
    G = H // KVH
    nq, nk = Sq // q_block, Sk // kv_block
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, nq, q_block, KVH, G, hd)
    qp = q_pos.reshape(nq, q_block)
    kb = k.reshape(B, nk, kv_block, KVH, hd).swapaxes(0, 1)
    vb = v.reshape(B, nk, kv_block, KVH, hd).swapaxes(0, 1)
    kp = k_pos.reshape(nk, kv_block)

    def q_step(_, qi):
        qblk, qpos = qi

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kpos = ki
            s = jnp.einsum("bqhgk,bnhk->bhgqn", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _mask_bias(qpos, kpos, causal, window)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqn,bnhk->bhgqk", p.astype(qblk.dtype), vblk)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KVH, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kp))
        out = (acc / jnp.maximum(l, 1e-30)[..., None])
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (out, lse) = jax.lax.scan(q_step, None, (qg.swapaxes(0, 1), qp))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd).astype(q.dtype)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, KVH, G, Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _mha_chunked_core(q, k, v, q_pos, k_pos, causal, window, q_block,
                      kv_block):
    out, _ = _chunk_fwd(q, k, v, q_pos, k_pos, causal, window, q_block,
                        kv_block)
    return out


def _mha_fwd_rule(q, k, v, q_pos, k_pos, causal, window, q_block, kv_block):
    out, lse = _chunk_fwd(q, k, v, q_pos, k_pos, causal, window, q_block,
                          kv_block)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _mha_bwd_rule(causal, window, q_block, kv_block, res, dout):
    """Flash backward: recompute s/p per block pair; O(block^2) live memory.

    delta = rowsum(dout * out); p = exp(s - lse);
    dv += p^T dout; ds = p * (dout v^T - delta); dq += ds k; dk += ds^T q.
    """
    q, k, v, q_pos, k_pos, out, lse = res
    B, Sq, H, hd = q.shape
    KVH, Sk = k.shape[2], k.shape[1]
    G = H // KVH
    nq, nk = Sq // q_block, Sk // kv_block
    scale = 1.0 / np.sqrt(hd)
    f32 = jnp.float32

    qg = q.reshape(B, nq, q_block, KVH, G, hd).swapaxes(0, 1)
    og = out.reshape(B, nq, q_block, KVH, G, hd).swapaxes(0, 1)
    dg = dout.reshape(B, nq, q_block, KVH, G, hd).swapaxes(0, 1)
    lg = lse.reshape(B, KVH, G, nq, q_block).transpose(3, 0, 1, 2, 4)
    qp = q_pos.reshape(nq, q_block)
    kb = k.reshape(B, nk, kv_block, KVH, hd).swapaxes(0, 1)
    vb = v.reshape(B, nk, kv_block, KVH, hd).swapaxes(0, 1)
    kp = k_pos.reshape(nk, kv_block)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        qblk, oblk, doblk, lseb, qpos = qi
        delta = jnp.sum(doblk.astype(f32) * oblk.astype(f32), axis=-1)
        delta = delta.transpose(0, 2, 3, 1)            # (B,KVH,G,qb)

        def kv_step(carry2, ki):
            dq_acc, dk_a, dv_a = carry2
            kblk, vblk, kpos, j = ki
            s = jnp.einsum("bqhgk,bnhk->bhgqn", qblk, kblk,
                           preferred_element_type=f32) * scale
            s = s + _mask_bias(qpos, kpos, causal, window)
            p = jnp.exp(s - lseb[..., None])           # (B,KVH,G,qb,kb)
            dov = jnp.einsum("bqhgk,bnhk->bhgqn", doblk, vblk,
                             preferred_element_type=f32)
            ds = p * (dov - delta[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhgqn,bnhk->bqhgk",
                                         ds.astype(kblk.dtype), kblk)
            dk_blk = jnp.einsum("bhgqn,bqhgk->bnhk",
                                ds.astype(qblk.dtype), qblk)
            dv_blk = jnp.einsum("bhgqn,bqhgk->bnhk",
                                p.astype(doblk.dtype), doblk)
            dk_a = dk_a.at[j].add(dk_blk)
            dv_a = dv_a.at[j].add(dv_blk)
            return (dq_acc, dk_a, dv_a), None

        dq0 = jnp.zeros((B, q_block, KVH, G, hd), f32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            (kb, vb, kp, jnp.arange(nk)))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((nk, B, kv_block, KVH, hd), f32)
    dv0 = jnp.zeros((nk, B, kv_block, KVH, hd), f32)
    (dk_acc, dv_acc), dq = jax.lax.scan(
        q_step, (dk0, dv0), (qg, og, dg, lg, qp))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd).astype(q.dtype)
    dk = dk_acc.swapaxes(0, 1).reshape(B, Sk, KVH, hd).astype(k.dtype)
    dv = dv_acc.swapaxes(0, 1).reshape(B, Sk, KVH, hd).astype(v.dtype)
    return dq, dk, dv, None, None


_mha_chunked_core.defvjp(_mha_fwd_rule, _mha_bwd_rule)


def mha_chunked(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                q_block=2048, kv_block=1024):
    """Flash-attention algorithm in lax.scan with a flash-style custom VJP:
    both passes hold O(q_block x kv_block) live memory (the backward
    recomputes block scores instead of saving the S^2 attention matrix)."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    Sk = k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    nq, nk = Sq // q_block, Sk // kv_block
    out = _mha_chunked_core(q, k, v, q_pos, k_pos, causal, window, q_block,
                            kv_block)
    costbook.record(
        "mha_chunked",
        total_flops=_gqa_scores_flops(B, Sq, Sk, H, hd),
        total_bytes=float(  # q,k,v read + out write, once each (flash ideal)
            (q.size + k.size + v.size + out.size) * q.dtype.itemsize),
        trips=nq * nk)
    return out


def attend(q, k, v, q_pos, k_pos, *, causal=True, window=0, impl="auto"):
    Sq, Sk = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "chunked" if Sq * Sk > (1 << 22) and Sq >= 2048 else "full"
    if impl == "full":
        return mha_full(q, k, v, q_pos, k_pos, causal=causal, window=window)
    return mha_chunked(q, k, v, q_pos, k_pos, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Block-level entry points
# ---------------------------------------------------------------------------

def attention_fwd(params, x, cfg, *, kind="attn", positions=None,
                  causal=True, impl="auto"):
    """Training / prefill self-attention.  x: (B,S,d) -> (B,S,d)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    theta = _theta_for(cfg, kind)
    q, k, v = _project_qkv(params, x, cfg, positions, theta)
    window = cfg.sliding_window if kind == "local" else 0
    o = attend(q, k, v, positions, positions, causal=causal,
               window=window, impl=impl)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


def kv_tp_repeat(cfg, model_axis: int) -> int:
    """KV-head replication factor for TP (classic GQA practice): pad KV
    heads to the model-axis degree when group structure allows, so the
    decode cache shards cleanly on the head dim (no involuntary cache
    rematerialization).  1 when not applicable (e.g. phi3's kv=10)."""
    kvh, h = cfg.n_kv_heads, cfg.n_heads
    if model_axis % kvh != 0:
        return 1
    r = model_axis // kvh
    if r <= 1 or (kvh * r) > h or h % (kvh * r) != 0:
        return 1
    return r


def quantize_kv(t):
    """Per-(token, head) symmetric int8 quantization.  t: (B,T,KVH,hd) ->
    (int8 values, f32 scales (B,T,KVH,1))."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attention_prefill(params, x, cfg, *, kind="attn", positions=None,
                      impl="auto", kv_repeat: int = 1,
                      kv_quant: bool = False):
    """Prefill: returns (out, cache_entry) — cache holds roped K and V,
    with KV heads replicated x kv_repeat for TP-aligned cache sharding and
    optional int8 storage (halves decode-cache HBM traffic)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    theta = _theta_for(cfg, kind)
    q, k, v = _project_qkv(params, x, cfg, positions, theta)
    window = cfg.sliding_window if kind == "local" else 0
    o = attend(q, k, v, positions, positions, causal=True,
               window=window, impl=impl)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    if kind == "local" and cfg.sliding_window and S >= cfg.sliding_window:
        W = cfg.sliding_window
        # ring buffer: slot = pos % W; last W positions end aligned
        start = S - W
        shift = start % W
        k = jnp.roll(k[:, start:], shift, axis=1)
        v = jnp.roll(v[:, start:], shift, axis=1)
    if kv_quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return out, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return out, {"k": k, "v": v}


def attention_decode(params, x, cfg, cache, position, *, kind="attn"):
    """One-token decode.  x: (B,1,d); cache k/v: (B,T,KVH*r,hd);
    position: (B,) index of the NEW token.  The KV-replication factor r and
    int8 quantization are inferred from the cache.  Returns
    (out, new_cache)."""
    B = x.shape[0]
    theta = _theta_for(cfg, kind)
    q, k, v = _project_qkv(params, x, cfg, position[:, None], theta)
    rep = cache["k"].shape[2] // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    T = cache["k"].shape[1]
    window = cfg.sliding_window if kind == "local" else 0
    if window and T == window:
        slot = position % window
    else:
        slot = position
    bidx = jnp.arange(B)
    quant = "k_scale" in cache
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": cache["k"].at[bidx, slot].set(kq[:, 0]),
            "v": cache["v"].at[bidx, slot].set(vq[:, 0]),
            "k_scale": cache["k_scale"].at[bidx, slot].set(ks[:, 0]),
            "v_scale": cache["v_scale"].at[bidx, slot].set(vs[:, 0]),
        }
        ck = dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        cv = dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        ck = cache["k"].at[bidx, slot].set(k[:, 0])
        cv = cache["v"].at[bidx, slot].set(v[:, 0])

    KVH, hd = ck.shape[2], ck.shape[3]
    H = q.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bhgk,bthk->bhgt", qg, ck,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    # validity: global cache slots <= position; ring: reconstructed pos >= 0
    tpos = jnp.arange(T)[None, :]                       # (1,T)
    if window and T == window:
        recon = position[:, None] - ((position[:, None] - tpos) % window)
        valid = recon >= 0
    else:
        valid = tpos <= position[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgt,bthk->bhgk", p, cv).reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, (new_cache if quant else {"k": ck, "v": cv})


def attention_flops(cfg, B, Sq, Sk, *, train: bool) -> float:
    hd = cfg.resolved_head_dim
    proj = 2.0 * B * Sq * cfg.d_model * hd * (2 * cfg.n_heads +
                                              2 * cfg.n_kv_heads)
    core = _gqa_scores_flops(B, Sq, Sk, cfg.n_heads, hd)
    total = proj + core
    return total * (3.0 if train else 1.0)
