"""repro: a JAX/Pallas reproduction of LargeVis (Tang et al., WWW 2016).

Public API — see README "Public API":

* :class:`LargeVis` — the estimator (``fit`` / ``transform`` /
  ``fit_transform`` / ``insert``).
* :func:`largevis` / :class:`LargeVisResult` — the functional core and
  its fitted-model carrier (``repro.core.largevis``).
* :class:`LargeVisConfig` / :class:`RoutingConfig` — hyper-parameters
  and implementation routing (``repro.configs.largevis_default``).
"""
from repro.api import LargeVis, NotFittedError
from repro.configs.largevis_default import LargeVisConfig, RoutingConfig
from repro.core.largevis import LargeVisResult, largevis

__all__ = [
    "LargeVis",
    "LargeVisConfig",
    "LargeVisResult",
    "NotFittedError",
    "RoutingConfig",
    "largevis",
]
