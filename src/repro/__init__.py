"""repro: a JAX/Pallas reproduction of LargeVis (Tang et al., WWW 2016).

Public API — see README "Public API":

* :class:`LargeVis` — the estimator (``fit`` / ``transform`` /
  ``fit_transform`` / ``insert``).
* :func:`largevis` / :class:`LargeVisResult` — the functional core and
  its fitted-model carrier (``repro.core.largevis``).
* :class:`LargeVisConfig` / :class:`RoutingConfig` — hyper-parameters
  and implementation routing (``repro.configs.largevis_default``).
* :class:`CheckpointConfig` / :class:`HealthConfig` — crash-safe
  stage-checkpointed resume and the divergence guard (README
  "Robustness").
"""
from repro.api import LargeVis, NotFittedError
from repro.configs.largevis_default import (CheckpointConfig, HealthConfig,
                                            LargeVisConfig, RoutingConfig)
from repro.core.largevis import LargeVisResult, largevis

__all__ = [
    "CheckpointConfig",
    "HealthConfig",
    "LargeVis",
    "LargeVisConfig",
    "LargeVisResult",
    "NotFittedError",
    "RoutingConfig",
    "largevis",
]
