"""Pallas kernel: causal flash attention (prefill hot spot for LM substrate).

Grid: (batch*heads, q blocks); each program streams kv blocks with the
running-max/denominator recurrence held in VMEM scratch.  Block sizes are
MXU-aligned (q_block x head_dim and kv_block x head_dim tiles).  Causality
is enforced per-element; fully-masked kv blocks are skipped by bounding the
kv grid dimension per q block via block-index arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.largevis_grad import _resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, q_block: int, kv_block: int,
            n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        # kv block strictly in the future of the whole q block -> skip work
        run = ki * kv_block <= qi * q_block + q_block - 1
    else:
        run = ki >= 0

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (qb, hd)
        k = k_ref[0].astype(jnp.float32)                 # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            kpos = ki * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, interpret: bool | None = None):
    """q: (B,S,H,hd); k/v: (B,T,H,hd) — heads must be pre-broadcast (GQA
    callers repeat kv heads).  Returns (B,S,H,hd).

    ``interpret=None`` resolves backend-aware (compiled on TPU, interpret
    elsewhere) — the same ``_resolve_interpret`` contract every other
    kernel in this package follows; the old hard-coded ``True`` silently
    ran the interpreter on TPU."""
    interpret = _resolve_interpret(interpret)
    B, S, H, hd = q.shape
    T = k.shape[1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    assert S % q_block == 0 and T % kv_block == 0
    # fold heads into the grid's batch dim
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    n_kv = T // kv_block
    grid = (B * H, S // q_block, n_kv)
    kern = functools.partial(_kernel, scale=1.0 / np.sqrt(hd), causal=causal,
                             q_block=q_block, kv_block=kv_block, n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
