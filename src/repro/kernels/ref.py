"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# knn_topk: blocked pairwise squared distances
# ---------------------------------------------------------------------------

def pairwise_sqdist_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """a: (M,d), b: (N,d) -> (M,N) squared euclidean distances, f32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    an = jnp.sum(a * a, axis=-1, keepdims=True)          # (M,1)
    bn = jnp.sum(b * b, axis=-1, keepdims=True).T        # (1,N)
    d = an + bn - 2.0 * (a @ b.T)
    return jnp.maximum(d, 0.0)


# ---------------------------------------------------------------------------
# knn_topk: streaming fused distance -> top-k (flash-attention-style fold)
# ---------------------------------------------------------------------------

# Similarity value marking a masked candidate (padding, self-edge, bucket
# mismatch, duplicate-of-state).  Strictly above the kernel's -inf "already
# taken" marker so the selection loop and lax.top_k agree on tie order, and
# strictly below any real similarity (|2ab - |a|^2 - |b|^2| < 3e38 for any
# finite f32 coordinates that don't themselves overflow).
INVALID_SIM = -3.0e38  # Python float: jnp scalars would be captured
# The distance an invalid slot surfaces as (= -INVALID_SIM): callers seed
# running state with this, and -INVALID_DIST round-trips to INVALID_SIM
# exactly (IEEE negation is exact).
INVALID_DIST = 3.0e38  # constants inside the Pallas kernel body


def _pad_dim(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


def _sim_tile(a, b, an, bn):
    """Negated squared distance, unclamped: s = 2 a.b - |a|^2 - |b|^2.

    Shared by the streaming ref and the Pallas kernel (bit-identical op
    order: ((2ab - an) - bn)); larger similarity = closer.  The clamp to
    non-negative distance happens once on the final (M, k) output instead
    of per (bm, bn) tile — one fewer full pass over every candidate tile.
    """
    s = 2.0 * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return s - an[:, None] - bn[None, :]


def _mask_bad(s, a_ids, b_ids):
    """Invalidate padding (b_id < 0) and self-edges (b_id == a_id).

    A numerical no-op when the tile holds no negative b_id and the a/b id
    ranges are disjoint — the streaming ref exploits that by cond-ing the
    pass away on non-overlapping tiles (most of them: the diagonal plus
    the ragged tail), while the kernel applies it unconditionally (one
    VPU pass next to the MXU matmul); outputs are identical either way.
    """
    bad = (b_ids[None, :] < 0) | (b_ids[None, :] == a_ids[:, None])
    return jnp.where(bad, INVALID_SIM, s)


def _mask_tile(s, a_ids, b_ids, codes_a, codes_b, state_ids, dedup: bool,
               skip_bad: bool = False):
    """Invalidate padding (b_id < 0), self-edges, bucket mismatches and —
    when ``dedup`` — candidates whose id already sits in the running state.
    Shared by the streaming ref and the Pallas kernel (the ref conds the
    bad-mask separately and passes ``skip_bad=True``)."""
    if not skip_bad:
        s = _mask_bad(s, a_ids, b_ids)
    if codes_a is not None:
        match = (codes_a[:, None, :] == codes_b[None, :, :]).any(-1)
        s = jnp.where(match, s, INVALID_SIM)
    if dedup:
        dup = (b_ids[None, :, None] == state_ids[:, None, :]).any(-1)
        s = jnp.where(dup, INVALID_SIM, s)
    return s


def topk_sqdist_ref(a: jax.Array, b: jax.Array, k: int, *,
                    a_ids: jax.Array | None = None,
                    b_ids: jax.Array | None = None,
                    codes_a: jax.Array | None = None,
                    codes_b: jax.Array | None = None,
                    init_ids: jax.Array | None = None,
                    init_dists: jax.Array | None = None,
                    dedup: bool = False,
                    bm: int = 2048, bn: int | None = None, lane: int = 1,
                    merge: str = "auto"):
    """Streaming fused distance->top-k: the pure-jnp oracle AND the CPU
    production path (``ops.topk_sqdist`` routes impl="auto" here off-TPU).

    For each row of ``a`` (M, d), returns the ``k`` nearest rows of ``b``
    (N, d) as (ids (M, k) int32, sqdists (M, k) f32), distances ascending.
    The (M, N) distance matrix never materializes: column tiles of ``b``
    are folded into a running (bm, k) best state carried through a
    ``lax.scan``, exactly like flash-attention folds softmax tiles.  The
    fold works in *similarity* space (s = 2ab - |a|^2 - |b|^2, i.e. the
    negated squared distance) so ``lax.top_k`` applies directly — no
    negate pass, no clamp pass per tile; both happen once on the final
    (M, k) state.  Row tiles go through ``lax.map`` so the whole call is
    one dispatch (the ``brute_force_knn`` pattern).

    Masking/merging semantics (shared with the Pallas kernel, which is
    bit-identical — tests assert bitwise equality on ids AND dists):

      * ``b_ids`` (N,) gives candidate ids (default ``arange(N)``);
        negative ids are padding and never selected over real candidates.
      * ``a_ids`` (M,) enables self-edge masking (b_id == a_id).
      * ``codes_a`` (M, T) / ``codes_b`` (N, T): keep only pairs sharing
        a bucket code in at least one of T trees (the sharded pipeline's
        forest mask, applied per tile instead of as an (M, N) buffer).
      * ``init_ids``/``init_dists`` (M, k) seed the running state — this
        is how the sharded ring carries its top-k across ring steps and
        how ``forest_knn`` folds tree t+1 into the tree-t result; empty
        slots are (id=-1, dist=INVALID_DIST).
      * ``dedup=True`` masks candidates already present in the running
        state (cross-tree duplicates).  Costs a (bm, bn, k) compare per
        tile — enable only where duplicates are possible.

    Invalid output slots (fewer than k valid candidates) surface as
    (id=-1-or-masked-id, dist=INVALID_DIST-ish); they order after every
    real neighbor.

    ``lane`` pads d to a multiple (the kernel needs 128 for the MXU; the
    CPU default of 1 skips the pad — at d=100 the zero columns would
    inflate the matmul ~28% for nothing).  ``merge`` picks the fold
    formulation: "concat" top_k's over [state | tile] directly; "tile"
    top_k's the tile first and merges the (bm, 2k) shortlist — the same
    output bit-for-bit (top-k of a union is top-k of state ∪ top-k(tile),
    and both keep state-before-tile, earliest-position tie order) but
    cheaper when many column tiles would each pay the (bm, k+bn) concat
    copy; "auto" uses "tile" for a single column tile and from 8 tiles
    up.  Bitwise equality with the kernel therefore holds at equal
    (bm, bn, lane) for EITHER merge.
    """
    M, d = a.shape
    N = b.shape[0]
    bm = min(bm, M)
    if bn is None:
        # wider tiles amortize the per-tile merge once the column count
        # is large (the tile-shortlist regime); 4096 wins in between
        bn = 8192 if N >= 65536 else 4096
    bn = min(bn, N)
    a_ids = (jnp.full((M,), -1, jnp.int32) if a_ids is None
             else a_ids.astype(jnp.int32))
    b_ids = (jnp.arange(N, dtype=jnp.int32) if b_ids is None
             else b_ids.astype(jnp.int32))
    # pad: rows to a bm multiple, cols to a bn multiple, d to a lane
    # multiple (zero features add exact 0.0 terms; at equal lane the ref
    # and the kernel reduce over the same shapes -> the same bits)
    ap = _pad_dim(_pad_dim(a.astype(jnp.float32), bm, 0), lane, 1)
    bp = _pad_dim(_pad_dim(b.astype(jnp.float32), bn, 0), lane, 1)
    aip = _pad_dim(a_ids, bm, 0)
    bip = jnp.pad(b_ids, (0, bp.shape[0] - N), constant_values=-1)
    if codes_a is not None:
        codes_a = _pad_dim(codes_a.astype(jnp.int32), bm, 0)
        codes_b = _pad_dim(codes_b.astype(jnp.int32), bn, 0)
    if init_ids is not None:
        init_ids = _pad_dim(init_ids.astype(jnp.int32), bm, 0)
        init_s = jnp.maximum(-_pad_dim(init_dists.astype(jnp.float32),
                                       bm, 0), INVALID_SIM)
    n_m = ap.shape[0] // bm
    n_n = bp.shape[0] // bn
    if merge == "auto":
        # "tile" wins when the concat copy dominates: many column tiles
        # (each pays it) or a single tile (top_k the tile directly);
        # "concat" wins in between, where its single top_k beats the
        # double top_k per tile
        merge = "concat" if 1 < n_n < 8 else "tile"
    bT = bp.reshape(n_n, bn, -1)
    biT = bip.reshape(n_n, bn)
    cbT = codes_b.reshape(n_n, bn, -1) if codes_a is not None else None
    # per-column-tile id range, hoisted: the self/padding mask pass is a
    # numerical no-op unless the tile contains a negative id or its id
    # range overlaps the row tile's — cond it away elsewhere (one fewer
    # full (bm, bn) pass on most tiles; see _mask_bad)
    b_lo = jnp.min(biT, axis=1)
    b_hi = jnp.max(biT, axis=1)

    def row_tile(args):
        at, ait, cat, st0 = args
        an = jnp.sum(at * at, axis=1)
        a_lo, a_hi = jnp.min(ait), jnp.max(ait)

        def fold(carry, xs):
            si, ss = carry
            bt, bit, cbt, blo, bhi = xs
            bn_norm = jnp.sum(bt * bt, axis=1)
            s = _sim_tile(at, bt, an, bn_norm)
            need_bad = (blo < 0) | ((bhi >= a_lo) & (blo <= a_hi))
            s = jax.lax.cond(need_bad,
                             lambda t: _mask_bad(t, ait, bit),
                             lambda t: t, s)
            s = _mask_tile(s, None, bit, cat, cbt, si, dedup,
                           skip_bad=True)
            if merge == "tile":
                # shortlist the tile first: the (bm, k+bn) concat copy of
                # the full tile never happens; bitwise-identical (see
                # docstring)
                ts, ti = jax.lax.top_k(s, min(k, s.shape[1]))
                s_all = jnp.concatenate([ss, ts], axis=1)
                i_all = jnp.concatenate([si, bit[ti]], axis=1)
            else:
                s_all = jnp.concatenate([ss, s], axis=1)
                i_all = jnp.concatenate(
                    [si, jnp.broadcast_to(bit[None, :], s.shape)], axis=1)
            ns, ni = jax.lax.top_k(s_all, k)
            return (jnp.take_along_axis(i_all, ni, axis=1), ns), None

        (si, ss), _ = jax.lax.scan(fold, st0, (bT, biT, cbT, b_lo, b_hi))
        return si, jnp.maximum(-ss, 0.0)

    caT = codes_a.reshape(n_m, bm, -1) if codes_a is not None else None
    if init_ids is not None:
        st0 = (init_ids.reshape(n_m, bm, k), init_s.reshape(n_m, bm, k))
    else:
        st0 = (jnp.full((n_m, bm, k), -1, jnp.int32),
               jnp.full((n_m, bm, k), INVALID_SIM))
    idx, dist = jax.lax.map(
        row_tile, (ap.reshape(n_m, bm, -1), aip.reshape(n_m, bm), caT, st0))
    return idx.reshape(-1, k)[:M], dist.reshape(-1, k)[:M]


# ---------------------------------------------------------------------------
# largevis_grad: fused attractive + repulsive forces (f(x) = 1/(1+a x^2))
# ---------------------------------------------------------------------------

def largevis_grads_ref(yi, yj, yneg, *, gamma: float = 7.0, a: float = 1.0,
                       clip: float = 5.0, eps: float = 0.1,
                       neg_mask=None):
    """Gradients of the (negated, minimized) edge log-likelihood, Eqn (6).

    yi, yj: (B,s) endpoint embeddings of sampled positive edges.
    yneg:   (B,M,s) embeddings of sampled negative vertices.
    neg_mask: (B,M) 1.0 valid / 0.0 skip (collision with i or j).

    Returns (gi, gj, gneg): ascent directions are NEGATED (gradient of the
    loss to MINIMIZE), per-coordinate clipped to [-clip, clip] like the
    reference implementation.
    """
    f32 = jnp.float32
    yi, yj, yneg = yi.astype(f32), yj.astype(f32), yneg.astype(f32)
    # positive edge: d/dyi [-log f] = 2a(yi-yj) / (1 + a d2)
    dij = yi - yj                                        # (B,s)
    d2 = jnp.sum(dij * dij, axis=-1, keepdims=True)      # (B,1)
    gpos = (2.0 * a / (1.0 + a * d2)) * dij
    # negative: d/dyi [-gamma log(1-f)] = -2 gamma (yi-yn) / ((eps+d2)(1+a d2))
    din = yi[:, None, :] - yneg                          # (B,M,s)
    dn2 = jnp.sum(din * din, axis=-1, keepdims=True)     # (B,M,1)
    gneg_i = -2.0 * gamma * din / ((eps + dn2) * (1.0 + a * dn2))
    if neg_mask is not None:
        gneg_i = gneg_i * neg_mask[..., None]
    c = clip
    gi = jnp.clip(gpos + jnp.sum(gneg_i, axis=1), -c, c)
    gj = jnp.clip(-gpos, -c, c)
    gneg = jnp.clip(-gneg_i, -c, c)
    return gi, gj, gneg


# ---------------------------------------------------------------------------
# largevis_step: fully-fused gather -> grad -> scatter-update edge step
# ---------------------------------------------------------------------------

def fused_edge_step_ref(y, i, j, negs, neg_mask, lr, *, gamma: float = 7.0,
                        a: float = 1.0, clip: float = 5.0,
                        eps: float = 0.1, n_frozen: int = 0):
    """Pure-jnp oracle for ``largevis_step.fused_edge_step``.

    One SGD update of the (N, s) embedding over a sampled edge batch:
    gather the rows, compute the Eqn (6) forces (``largevis_grads_ref``),
    and scatter-accumulate ``-lr*g`` back into ``y``.

    Duplicate-index contract: intra-batch duplicates (the same row drawn as
    i, j and/or a negative, possibly by several edges) ACCUMULATE — every
    update lands.  The update stream is per-edge interleaved,
    ``[i_e, j_e, negs_e,0..M-1] for e = 0..B-1``, and XLA's scatter-add
    applies duplicate updates in stream order, which is exactly the order
    the fused kernel's sequential phase-1 loop uses — the kernel is
    bit-reproducible against this oracle (asserted by tests).

    ``lr`` may be a scalar (the layout drivers) or a (B,) per-edge vector
    (the serving engine, whose lockstep slots sit at different schedule
    positions); a scalar is the same computation as the broadcast vector.

    ``n_frozen``: rows with index < n_frozen never change — the
    out-of-sample transform mode, where the fitted corpus embedding is
    frozen and only appended query rows move.  Frozen-row updates are
    masked to -0.0, and x + (-0.0) == x bitwise for every f32 (including
    both zeros), so frozen rows are BIT-identical to their inputs.
    """
    f32 = jnp.float32
    y = y.astype(f32)
    gi, gj, gneg = largevis_grads_ref(y[i], y[j], y[negs], gamma=gamma,
                                      a=a, clip=clip, eps=eps,
                                      neg_mask=neg_mask)
    s = y.shape[1]
    idx = jnp.concatenate([i[:, None], j[:, None], negs], axis=1).reshape(-1)
    upd = jnp.concatenate([gi[:, None], gj[:, None], gneg],
                          axis=1).reshape(-1, s)
    lr = jnp.asarray(lr, f32)
    if lr.ndim:                       # (B,) per-edge -> per update row
        lr = jnp.repeat(lr, 2 + negs.shape[1])[:, None]
    upd = -lr * upd
    if n_frozen:
        upd = jnp.where((idx >= n_frozen)[:, None], upd, f32(-0.0))
    return y.at[idx].add(upd)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,T,H,hd) (heads pre-broadcast).  f32 softmax."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
