"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# knn_topk: blocked pairwise squared distances
# ---------------------------------------------------------------------------

def pairwise_sqdist_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """a: (M,d), b: (N,d) -> (M,N) squared euclidean distances, f32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    an = jnp.sum(a * a, axis=-1, keepdims=True)          # (M,1)
    bn = jnp.sum(b * b, axis=-1, keepdims=True).T        # (1,N)
    d = an + bn - 2.0 * (a @ b.T)
    return jnp.maximum(d, 0.0)


# ---------------------------------------------------------------------------
# largevis_grad: fused attractive + repulsive forces (f(x) = 1/(1+a x^2))
# ---------------------------------------------------------------------------

def largevis_grads_ref(yi, yj, yneg, *, gamma: float = 7.0, a: float = 1.0,
                       clip: float = 5.0, eps: float = 0.1,
                       neg_mask=None):
    """Gradients of the (negated, minimized) edge log-likelihood, Eqn (6).

    yi, yj: (B,s) endpoint embeddings of sampled positive edges.
    yneg:   (B,M,s) embeddings of sampled negative vertices.
    neg_mask: (B,M) 1.0 valid / 0.0 skip (collision with i or j).

    Returns (gi, gj, gneg): ascent directions are NEGATED (gradient of the
    loss to MINIMIZE), per-coordinate clipped to [-clip, clip] like the
    reference implementation.
    """
    f32 = jnp.float32
    yi, yj, yneg = yi.astype(f32), yj.astype(f32), yneg.astype(f32)
    # positive edge: d/dyi [-log f] = 2a(yi-yj) / (1 + a d2)
    dij = yi - yj                                        # (B,s)
    d2 = jnp.sum(dij * dij, axis=-1, keepdims=True)      # (B,1)
    gpos = (2.0 * a / (1.0 + a * d2)) * dij
    # negative: d/dyi [-gamma log(1-f)] = -2 gamma (yi-yn) / ((eps+d2)(1+a d2))
    din = yi[:, None, :] - yneg                          # (B,M,s)
    dn2 = jnp.sum(din * din, axis=-1, keepdims=True)     # (B,M,1)
    gneg_i = -2.0 * gamma * din / ((eps + dn2) * (1.0 + a * dn2))
    if neg_mask is not None:
        gneg_i = gneg_i * neg_mask[..., None]
    c = clip
    gi = jnp.clip(gpos + jnp.sum(gneg_i, axis=1), -c, c)
    gj = jnp.clip(-gpos, -c, c)
    gneg = jnp.clip(-gneg_i, -c, c)
    return gi, gj, gneg


# ---------------------------------------------------------------------------
# largevis_step: fully-fused gather -> grad -> scatter-update edge step
# ---------------------------------------------------------------------------

def fused_edge_step_ref(y, i, j, negs, neg_mask, lr, *, gamma: float = 7.0,
                        a: float = 1.0, clip: float = 5.0,
                        eps: float = 0.1):
    """Pure-jnp oracle for ``largevis_step.fused_edge_step``.

    One SGD update of the (N, s) embedding over a sampled edge batch:
    gather the rows, compute the Eqn (6) forces (``largevis_grads_ref``),
    and scatter-accumulate ``-lr*g`` back into ``y``.

    Duplicate-index contract: intra-batch duplicates (the same row drawn as
    i, j and/or a negative, possibly by several edges) ACCUMULATE — every
    update lands.  The update stream is per-edge interleaved,
    ``[i_e, j_e, negs_e,0..M-1] for e = 0..B-1``, and XLA's scatter-add
    applies duplicate updates in stream order, which is exactly the order
    the fused kernel's sequential phase-1 loop uses — the kernel is
    bit-reproducible against this oracle (asserted by tests).
    """
    f32 = jnp.float32
    y = y.astype(f32)
    gi, gj, gneg = largevis_grads_ref(y[i], y[j], y[negs], gamma=gamma,
                                      a=a, clip=clip, eps=eps,
                                      neg_mask=neg_mask)
    s = y.shape[1]
    idx = jnp.concatenate([i[:, None], j[:, None], negs], axis=1).reshape(-1)
    upd = jnp.concatenate([gi[:, None], gj[:, None], gneg],
                          axis=1).reshape(-1, s)
    lr = jnp.asarray(lr, f32)
    return y.at[idx].add(-lr * upd)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,T,H,hd) (heads pre-broadcast).  f32 softmax."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
