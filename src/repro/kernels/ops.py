"""Jit'd public wrappers over the Pallas kernels with ref fallbacks.

``impl`` resolution: "pallas" runs the kernel (interpret mode on CPU — this
container; compiled on TPU), "ref" runs the pure-jnp oracle, "auto" picks
pallas on TPU and ref on CPU (interpret-mode kernels are Python-slow, so CPU
production paths use the oracle, which is mathematically identical — the
kernel tests assert this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.knn_topk import pairwise_sqdist as _sqdist_pallas
from repro.kernels.knn_topk import topk_sqdist as _topk_pallas
from repro.kernels.largevis_grad import (
    largevis_grads_chunked as _lvgrad_pallas,
)
from repro.kernels.largevis_step import fused_edge_step as _lvstep_pallas

# the fused edge-step kernel keeps the whole (N, s) embedding VMEM-resident
# for the duration of the call; above this budget the split path takes over
_FUSED_MAX_Y_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def fused_step_supported(n_nodes: int, out_dim: int) -> bool:
    """Whether ``largevis_edge_step`` may route to the fused kernel.

    On TPU the kernel needs the full (N, s) f32 embedding resident in VMEM
    (~16 MB/core; half is budgeted for y, the rest for edge blocks and
    scratch), so it is bounded at ~1M nodes for s=2.  CPU interpret mode
    lowers to plain XLA ops and has no size bound.  Any other backend
    (GPU) gets the split path: there the interpret lowering's sequential
    per-row update loop would serialize B*(2+M) tiny updates per step,
    far slower than one parallel scatter-add.
    """
    backend = jax.default_backend()
    if backend == "cpu":
        return True
    if backend != "tpu":
        return False
    return n_nodes * out_dim * 4 <= _FUSED_MAX_Y_BYTES


def pairwise_sqdist(a, b, *, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return _sqdist_pallas(a, b, interpret=not _on_tpu(), **kw)
    return ref.pairwise_sqdist_ref(a, b)


def topk_sqdist(a, b, k, *, impl: str = "auto", **kw):
    """Streaming fused distance->top-k (ids (M, k), sqdists (M, k)).

    impl:
      "fused" | "pallas" — the Pallas kernel (``knn_topk.topk_sqdist``):
        (bm, k) running state in VMEM, max-extraction merge, no sort.
        Compiled on TPU, interpret mode elsewhere.
      "ref"  — the streaming jnp oracle (``ref.topk_sqdist_ref``):
        identical fold as a lax.map over row tiles + lax.scan over column
        tiles with a lax.top_k merge.  Bit-identical to the kernel at
        equal (bm, bn).
      "auto" — the kernel on TPU, the oracle elsewhere (same contract as
        ``pairwise_sqdist``: the interpreter's per-grid-step Python loop
        is the slow path on CPU, and the oracle is the SAME streaming
        computation — no (M, N) buffer either way).

    Both paths accept the a_ids/b_ids/codes/init/dedup keywords; see
    ``ref.topk_sqdist_ref``.  Each impl has its own (bm, bn) defaults
    (VMEM-sized for the kernel, CPU-cache-sized for the oracle) — pass
    explicit tiles when bitwise cross-impl equality matters.
    """
    if impl in ("fused", "pallas") or (impl == "auto" and _on_tpu()):
        return _topk_pallas(a, b, k, interpret=not _on_tpu(), **kw)
    if impl in ("ref", "auto"):
        return ref.topk_sqdist_ref(a, b, k, **kw)
    raise ValueError(f"unknown impl {impl!r}; expected fused|pallas|ref|auto")


def largevis_grads(yi, yj, yneg, neg_mask, *, gamma=7.0, a=1.0, clip=5.0,
                   eps=0.1, impl: str = "auto", **kw):
    # chunked entry: pads odd (collision-capped) batches to a tile multiple,
    # so the kernel is usable inside the scanned layout engine
    if _resolve(impl) == "pallas":
        return _lvgrad_pallas(yi, yj, yneg, neg_mask, gamma=gamma, a=a,
                              clip=clip, eps=eps,
                              interpret=not _on_tpu(), **kw)
    return ref.largevis_grads_ref(yi, yj, yneg, gamma=gamma, a=a, clip=clip,
                                  eps=eps, neg_mask=neg_mask)


def largevis_edge_step(y, i, j, negs, neg_mask, lr, *, gamma=7.0, a=1.0,
                       clip=5.0, eps=0.1, impl: str = "auto",
                       n_frozen: int = 0, **kw):
    """One fused in-place SGD edge-step update of the (N, s) embedding.

    ``n_frozen`` freezes rows below that index (their updates are masked
    to -0.0 — a bitwise no-op add): the out-of-sample transform /
    serving mode, where the fitted corpus embedding must stay
    bit-identical while appended query rows move.  ``lr`` may be a
    scalar or a (B,) per-edge vector (heterogeneous serving slots).

    impl:
      "fused" | "pallas" — the fully-fused Pallas kernel
        (``largevis_step.fused_edge_step``: in-kernel gather + grad +
        sequential scatter-accumulate, y aliased in place).
      "ref"  — the pure-jnp oracle (``ref.fused_edge_step_ref``).
      "auto" — the kernel on EVERY backend.  Unlike the wrappers above,
        interpret mode is not the slow path here: the kernel body lowers
        to XLA ops and its sequential phase-1 update loop beats XLA's
        general scatter-add (~1.5x at N=20k on CPU), so the kernel is the
        fastest formulation on CPU as well as TPU.

    Callers must check :func:`fused_step_supported` first (backend gate +
    TPU VMEM bound); ``core.layout_engine.sgd_edge_step`` falls back to
    the split gather/grad/scatter path when it fails, and for autodiff
    ``prob_fn``s.
    """
    if impl in ("auto", "fused", "pallas"):
        return _lvstep_pallas(y, i, j, negs, neg_mask, lr, gamma=gamma,
                              a=a, clip=clip, eps=eps, n_frozen=n_frozen,
                              **kw)
    if impl == "ref":
        return ref.fused_edge_step_ref(y, i, j, negs, neg_mask, lr,
                                       gamma=gamma, a=a, clip=clip, eps=eps,
                                       n_frozen=n_frozen)
    raise ValueError(f"unknown impl {impl!r}; "
                     "expected fused|pallas|ref|auto")


def flash_attention(q, k, v, *, causal=True, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return _flash_pallas(q, k, v, causal=causal,
                             interpret=not _on_tpu(), **kw)
    return ref.flash_attention_ref(q, k, v, causal=causal)
