"""Jit'd public wrappers over the Pallas kernels with ref fallbacks.

``impl`` resolution: "pallas" runs the kernel (interpret mode on CPU — this
container; compiled on TPU), "ref" runs the pure-jnp oracle, "auto" picks
pallas on TPU and ref on CPU (interpret-mode kernels are Python-slow, so CPU
production paths use the oracle, which is mathematically identical — the
kernel tests assert this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.knn_topk import pairwise_sqdist as _sqdist_pallas
from repro.kernels.knn_topk import topk_sqdist as _topk_pallas
from repro.kernels.largevis_grad import (
    largevis_grads_chunked as _lvgrad_pallas,
)
from repro.kernels.largevis_step import fused_edge_step as _lvstep_pallas
from repro.runtime import autotune

# VMEM budget for the fused edge-step kernel's resident slab of y.  This
# is no longer a support bound: past it the kernel switches to the
# embedding-tiled mode (y_tile row slabs, bitwise-equal — see
# ``largevis_step``) instead of being rejected.
_FUSED_MAX_Y_BYTES = 8 * 1024 * 1024


def _tuned(kernel: str, shape: dict, default: dict, kw: dict) -> dict:
    """Fill ``kw`` with autotuned tile parameters (explicit args win).

    ``default`` is the route's legacy hard-coded config — what
    ``AUTOTUNE=off`` (and a cold cache) reproduces bitwise — and also
    whitelists which keys a cached entry may contribute."""
    cfg = autotune.get(kernel, shape, default)
    for name, val in cfg.items():
        kw.setdefault(name, val)
    return kw


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def fused_step_supported(n_nodes: int, out_dim: int) -> bool:
    """Whether ``largevis_edge_step`` may route to the fused kernel.

    True for ANY size on CPU and TPU: past the per-call VMEM budget
    (``_FUSED_MAX_Y_BYTES`` for the resident y slab) the kernel runs in
    its embedding-tiled mode — per grid step only a (y_tile, s) slab of
    y is VMEM-resident, bitwise-equal to the untiled kernel — so size is
    a tiling decision here, not a rejection (``largevis_edge_step`` picks
    y_tile below).  Any other backend (GPU) gets the split path: there
    the sequential per-row update loop would serialize B*(2+M) tiny
    updates per step, far slower than one parallel scatter-add.
    """
    del n_nodes, out_dim  # size no longer bounds support — tiling does
    return jax.default_backend() in ("cpu", "tpu")


def _fused_y_tile(n_nodes: int, out_dim: int) -> int:
    """Row-tile for the fused step's embedding-tiled mode (0 = untiled).

    Untiled while the whole (N, s) f32 embedding fits the VMEM budget;
    past it, the largest row count whose slab stays inside the budget."""
    if n_nodes * out_dim * 4 <= _FUSED_MAX_Y_BYTES:
        return 0
    return max(8, _FUSED_MAX_Y_BYTES // (4 * out_dim))


def pairwise_sqdist(a, b, *, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return _sqdist_pallas(a, b, interpret=not _on_tpu(), **kw)
    return ref.pairwise_sqdist_ref(a, b)


def topk_sqdist(a, b, k, *, impl: str = "auto", **kw):
    """Streaming fused distance->top-k (ids (M, k), sqdists (M, k)).

    impl:
      "fused" | "pallas" — the Pallas kernel (``knn_topk.topk_sqdist``):
        (bm, k) running state in VMEM, max-extraction merge, no sort.
        Compiled on TPU, interpret mode elsewhere.
      "ref"  — the streaming jnp oracle (``ref.topk_sqdist_ref``):
        identical fold as a lax.map over row tiles + lax.scan over column
        tiles with a lax.top_k merge.  Bit-identical to the kernel at
        equal (bm, bn).
      "auto" — the kernel on TPU, the oracle elsewhere (same contract as
        ``pairwise_sqdist``: the interpreter's per-grid-step Python loop
        is the slow path on CPU, and the oracle is the SAME streaming
        computation — no (M, N) buffer either way).

    Both paths accept the a_ids/b_ids/codes/init/dedup keywords; see
    ``ref.topk_sqdist_ref``.  Tile parameters (bm/bn/lane, plus merge on
    the oracle) resolve through the autotuner per (backend, route,
    shape-bucket) — ``AUTOTUNE=off`` reproduces each route's legacy
    hard-coded defaults bitwise; pass explicit tiles when bitwise
    cross-impl equality matters.
    """
    shape = dict(m=a.shape[0], n=b.shape[0], d=a.shape[1], k=int(k))
    if impl in ("fused", "pallas") or (impl == "auto" and _on_tpu()):
        kw.pop("merge", None)                 # oracle-only knob
        _tuned("topk_sqdist", shape, dict(bm=256, bn=512, lane=128), kw)
        return _topk_pallas(a, b, k, interpret=not _on_tpu(), **kw)
    if impl in ("ref", "auto"):
        _tuned("topk_sqdist", shape,
               dict(bm=2048, bn=None, lane=1, merge="auto"), kw)
        return ref.topk_sqdist_ref(a, b, k, **kw)
    raise ValueError(f"unknown impl {impl!r}; expected fused|pallas|ref|auto")


def largevis_grads(yi, yj, yneg, neg_mask, *, gamma=7.0, a=1.0, clip=5.0,
                   eps=0.1, impl: str = "auto", **kw):
    # chunked entry: pads odd (collision-capped) batches to a tile multiple,
    # so the kernel is usable inside the scanned layout engine
    if _resolve(impl) == "pallas":
        _tuned("largevis_grads",
               dict(b=yi.shape[0], m=yneg.shape[1], s=yi.shape[1]),
               dict(tile=2048), kw)
        return _lvgrad_pallas(yi, yj, yneg, neg_mask, gamma=gamma, a=a,
                              clip=clip, eps=eps,
                              interpret=not _on_tpu(), **kw)
    return ref.largevis_grads_ref(yi, yj, yneg, gamma=gamma, a=a, clip=clip,
                                  eps=eps, neg_mask=neg_mask)


def largevis_edge_step(y, i, j, negs, neg_mask, lr, *, gamma=7.0, a=1.0,
                       clip=5.0, eps=0.1, impl: str = "auto",
                       n_frozen: int = 0, **kw):
    """One fused in-place SGD edge-step update of the (N, s) embedding.

    ``n_frozen`` freezes rows below that index (their updates are masked
    to -0.0 — a bitwise no-op add): the out-of-sample transform /
    serving mode, where the fitted corpus embedding must stay
    bit-identical while appended query rows move.  ``lr`` may be a
    scalar or a (B,) per-edge vector (heterogeneous serving slots).

    impl:
      "fused" | "pallas" — the fully-fused Pallas kernel
        (``largevis_step.fused_edge_step``: in-kernel gather + grad +
        sequential scatter-accumulate, y aliased in place).
      "ref"  — the pure-jnp oracle (``ref.fused_edge_step_ref``).
      "auto" — the kernel on EVERY backend.  Unlike the wrappers above,
        interpret mode is not the slow path here: the kernel body lowers
        to XLA ops and its sequential phase-1 update loop beats XLA's
        general scatter-add (~1.5x at N=20k on CPU), so the kernel is the
        fastest formulation on CPU as well as TPU.

    Callers must check :func:`fused_step_supported` first (a backend
    gate only, now that the embedding-tiled mode lifts the VMEM size
    bound); ``core.layout_engine.sgd_edge_step`` falls back to the split
    gather/grad/scatter path when it fails, and for autodiff
    ``prob_fn``s.  Tile parameters (edge ``tile``, ``gather`` mode, and
    the embedding row tile ``y_tile``) resolve through the autotuner;
    when neither the caller nor a tuned entry sets ``y_tile``, it is
    derived from the VMEM budget (0 = untiled while y fits).
    """
    if impl in ("auto", "fused", "pallas"):
        _tuned("largevis_edge_step",
               dict(n=y.shape[0], b=i.shape[0], m=negs.shape[1],
                    s=y.shape[1]),
               dict(tile=1024, gather="take", y_tile=0), kw)
        if not kw.get("y_tile"):
            kw["y_tile"] = _fused_y_tile(y.shape[0], y.shape[1])
        return _lvstep_pallas(y, i, j, negs, neg_mask, lr, gamma=gamma,
                              a=a, clip=clip, eps=eps, n_frozen=n_frozen,
                              **kw)
    if impl == "ref":
        return ref.fused_edge_step_ref(y, i, j, negs, neg_mask, lr,
                                       gamma=gamma, a=a, clip=clip, eps=eps,
                                       n_frozen=n_frozen)
    raise ValueError(f"unknown impl {impl!r}; "
                     "expected fused|pallas|ref|auto")


def flash_attention(q, k, v, *, causal=True, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return _flash_pallas(q, k, v, causal=causal,
                             interpret=not _on_tpu(), **kw)
    return ref.flash_attention_ref(q, k, v, causal=causal)
