"""Jit'd public wrappers over the Pallas kernels with ref fallbacks.

``impl`` resolution: "pallas" runs the kernel (interpret mode on CPU — this
container; compiled on TPU), "ref" runs the pure-jnp oracle, "auto" picks
pallas on TPU and ref on CPU (interpret-mode kernels are Python-slow, so CPU
production paths use the oracle, which is mathematically identical — the
kernel tests assert this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.knn_topk import pairwise_sqdist as _sqdist_pallas
from repro.kernels.largevis_grad import (
    largevis_grads_chunked as _lvgrad_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def pairwise_sqdist(a, b, *, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return _sqdist_pallas(a, b, interpret=not _on_tpu(), **kw)
    return ref.pairwise_sqdist_ref(a, b)


def largevis_grads(yi, yj, yneg, neg_mask, *, gamma=7.0, a=1.0, clip=5.0,
                   eps=0.1, impl: str = "auto", **kw):
    # chunked entry: pads odd (collision-capped) batches to a tile multiple,
    # so the kernel is usable inside the scanned layout engine
    if _resolve(impl) == "pallas":
        return _lvgrad_pallas(yi, yj, yneg, neg_mask, gamma=gamma, a=a,
                              clip=clip, eps=eps,
                              interpret=not _on_tpu(), **kw)
    return ref.largevis_grads_ref(yi, yj, yneg, gamma=gamma, a=a, clip=clip,
                                  eps=eps, neg_mask=neg_mask)


def flash_attention(q, k, v, *, causal=True, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return _flash_pallas(q, k, v, causal=causal,
                             interpret=not _on_tpu(), **kw)
    return ref.flash_attention_ref(q, k, v, causal=causal)
