"""Pallas kernel: fully-fused LargeVis edge step — gather, gradient and
scatter-update in one pass over the embedding.

The split layout step moves the edge batch through HBM ~5x: an XLA gather
materializes yi/yj/yneg, the gradient kernel reads and rewrites them, and the
driver concatenates a (B*(2+M), s) update buffer for a scatter-add back into
y.  This kernel takes the full embedding plus the pre-sampled edge batch and
does everything in place:

  phase 0 (per edge tile): row-gather yi/yj/yneg out of the resident y,
      compute the attractive/repulsive forces + per-coordinate clip (the
      same float ops as ``largevis_grad``/``ref.largevis_grads_ref``), and
      stage the ``-lr*g`` update rows in a VMEM scratch —
  phase 1 (per edge tile): sequentially accumulate the staged rows into y.

The grid is (2, n_tiles) and TPU grids iterate the minor dimension fastest,
so *every* gather happens before *any* update — the fused step is exactly
the split step's batch semantics (gather-all, then scatter-all), and the
sequential phase-1 loop serializes duplicate-index updates in the canonical
per-edge order ``[i_e, j_e, negs_e,0..M-1]`` — the same order the split
path's interleaved scatter-add applies, so fused and split trajectories
match bitwise (see ``ref.fused_edge_step_ref`` for the order contract).

In-place: y is aliased input->output via ``input_output_aliases``, so no
second (N, s) buffer and no materialized (B, M, s) HBM intermediates exist
outside the kernel.  In the default (untiled) mode y's block spec is the
full array, i.e. y stays resident in VMEM for the whole call — sized for
~1M nodes at s=2 (an 8 MiB y budget, half of VMEM).

Past that budget, ``y_tile=R`` selects the **embedding-tiled** mode: the
grid becomes (2, ceil(N/R)) over row tiles of y, and each grid step holds
only one (R, s) slab in VMEM.  Phase 0 sweeps the row tiles, each tile
contributing exactly the edge-referenced rows it owns into a persistent
(B, (2+M)*s) gathered-rows scratch (a masked vectorized gather per tile —
every referenced row is written by precisely one tile, so the assembled
rows equal a full gather bitwise).  Phase 1 computes all forces once (at
row tile 0, from the fully-assembled scratch — elementwise per edge, so
identical bits to the untiled formulation) and then, per row tile, runs
the same sequential accumulation loop restricted to updates landing in
the resident slab.  Each update touches exactly one row and rows never
interact, so restricting the canonical per-edge stream to one tile's rows
preserves every row's update order — the tiled result is **bitwise equal**
to the untiled kernel and to ``ref.fused_edge_step_ref`` for any R.  This
is what turns ``ops.fused_step_supported`` from a size rejection into a
tiling decision.

Interpret mode (CPU) is not a debug afterthought here: the kernel body
lowers to XLA ops, turning phase 1 into a fori-loop of row updates that
beats XLA's general scatter-add by ~1.5x at N=20k — so ``ops`` routes
``impl="auto"`` to this kernel on every backend.

``n_frozen=`` is the partial-update (out-of-sample transform) mode: rows
below ``n_frozen`` are gathered and contribute forces but are never
written — their phase-1 update is masked to -0.0, which is a bitwise
no-op add for every f32 value — so a fitted corpus embedding stays
BIT-identical while appended query rows optimize against it.  ``lr`` may
be per-edge (B,) so lockstep serving slots at different schedule
positions share one dispatch.

``gather=`` picks how phase 0 reads rows: ``"take"`` (default) gathers with
one vectorized ``jnp.take`` per operand — fast everywhere interpret mode
runs, and maps to Mosaic's dynamic-gather on current TPU toolchains;
``"loop"`` row-copies via dynamic slices, the conservative TPU fallback.
Both are bitwise-identical (tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.largevis_grad import _resolve_interpret


def _kernel(y_in, i_ref, j_ref, n_ref, mask_ref, lr_ref, y_ref, u_ref,
            g_ref=None, *, gamma: float, a: float, clip: float, eps: float,
            tile: int, m: int, s: int, gather: str, n_frozen: int):
    del y_in  # aliased with y_ref; all access goes through the output ref
    p = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(p == 0)
    def _grad():
        # ---- gather the edge rows out of the resident embedding --------
        if gather == "take":
            y = y_ref[...]
            iv = i_ref[...].reshape(-1)
            jv = j_ref[...].reshape(-1)
            yi = jnp.take(y, iv, axis=0)
            yj = jnp.take(y, jv, axis=0)
            yn = jnp.take(y, n_ref[...].reshape(-1),
                          axis=0).reshape(tile, m, s)
        else:  # "loop": per-row dynamic slices (conservative TPU path)
            def gbody(e, _):
                g_ref[e, 0:s] = y_ref[i_ref[e, 0], :]
                g_ref[e, s:2 * s] = y_ref[j_ref[e, 0], :]

                def nbody(mm, _):
                    g_ref[e, pl.ds((2 + mm) * s, s)] = y_ref[n_ref[e, mm], :]
                    return 0

                jax.lax.fori_loop(0, m, nbody, 0)
                return 0

            jax.lax.fori_loop(0, tile, gbody, 0)
            g = g_ref[...]
            yi = g[:, 0:s]
            yj = g[:, s:2 * s]
            yn = g[:, 2 * s:].reshape(tile, m, s)

        # ---- forces + clip: the same float ops as largevis_grads_ref ---
        mask = mask_ref[...].astype(jnp.float32)
        gi, gj, gn = _forces(yi, yj, yn, mask, gamma=gamma, a=a, clip=clip,
                             eps=eps)
        # stage -lr*g rows, per-edge interleaved: [u_i, u_j, u_n0..u_n{M-1}]
        # (lr enters as a (tile, 1) per-edge block — the layout drivers
        # broadcast one scalar, the serving engine carries per-slot
        # schedule positions; a broadcast scalar multiplies bitwise
        # identically to the old scalar form)
        lr = lr_ref[...]                                   # (tile, 1)
        u = jnp.concatenate([gi[:, None, :], gj[:, None, :], gn], axis=1)
        u_ref[pl.ds(t * tile, tile), :] = (-lr[:, :, None] * u).reshape(
            tile, (2 + m) * s)

    @pl.when(p == 1)
    def _scatter():
        # sequential accumulate: duplicate indices (within an edge, across
        # edges, across tiles) serialize in canonical per-edge order.
        # Rows below n_frozen (the fitted corpus in transform mode) get
        # their update masked to -0.0 — x + (-0.0) == x bitwise for every
        # f32 including both signed zeros, so frozen rows never change.
        neg_zero = jnp.float32(-0.0)

        def _acc(rr, u_row):
            if n_frozen:
                u_row = jnp.where(rr >= n_frozen, u_row, neg_zero)
            y_ref[rr, :] = y_ref[rr, :] + u_row

        def body(e, _):
            u = u_ref[t * tile + e, :].reshape(2 + m, s)
            _acc(i_ref[e, 0], u[0])
            _acc(j_ref[e, 0], u[1])

            def nbody(mm, _):
                _acc(n_ref[e, mm], u[2 + mm])
                return 0

            jax.lax.fori_loop(0, m, nbody, 0)
            return 0

        jax.lax.fori_loop(0, tile, body, 0)


def _forces(yi, yj, yn, mask, *, gamma, a, clip, eps):
    """The gradient math shared by both kernel modes (and bit-for-bit the
    ops of ``largevis_grad``/``ref.largevis_grads_ref``): rowwise over
    edges, reductions over s only — so any edge-row partitioning computes
    identical bits."""
    dij = yi - yj
    d2 = jnp.sum(dij * dij, axis=-1, keepdims=True)
    gpos = (2.0 * a / (1.0 + a * d2)) * dij
    din = yi[:, None, :] - yn
    dn2 = jnp.sum(din * din, axis=-1, keepdims=True)
    gneg_i = -2.0 * gamma * din / ((eps + dn2) * (1.0 + a * dn2))
    gneg_i = gneg_i * mask[..., None]
    gi = jnp.clip(gpos + jnp.sum(gneg_i, axis=1), -clip, clip)
    gj = jnp.clip(-gpos, -clip, clip)
    gn = jnp.clip(-gneg_i, -clip, clip)
    return gi, gj, gn


def _kernel_tiled(y_in, i_ref, j_ref, n_ref, mask_ref, lr_ref, y_ref,
                  g_ref, u_ref, *, gamma: float, a: float, clip: float,
                  eps: float, m: int, s: int, b: int, y_tile: int,
                  n_frozen: int):
    """Embedding-tiled fused step: only a (y_tile, s) slab of y per step.

    Grid (2, n_row_tiles), minor dim fastest: phase 0 visits every row
    tile and assembles the gathered edge rows into the persistent
    ``g_ref`` scratch (each tile contributes the rows it owns via a
    masked vectorized gather); phase 1 computes the staged ``-lr*g``
    update rows once (row tile 0 — the gather is complete by then) and
    accumulates, per row tile, exactly the updates that land in the
    resident slab, in the canonical per-edge order.  Updates are
    row-local, so per-tile restriction preserves each row's accumulation
    order — bitwise equal to the untiled kernel."""
    del y_in  # aliased with y_ref; all access goes through the output ref
    p = pl.program_id(0)
    t = pl.program_id(1)
    off = t * y_tile

    @pl.when(p == 0)
    def _gather():
        @pl.when(t == 0)
        def _init():
            g_ref[...] = jnp.zeros_like(g_ref)

        y = y_ref[...]                                     # (R, s) slab
        iv = i_ref[...].reshape(-1)                        # (B,)
        jv = j_ref[...].reshape(-1)
        nv = n_ref[...].reshape(-1)                        # (B*m,)

        def pull(idx):
            rel = idx - off
            ok = (rel >= 0) & (rel < y_tile)
            vals = jnp.take(y, jnp.clip(rel, 0, y_tile - 1), axis=0)
            return ok[:, None], vals

        ok_i, vi = pull(iv)
        ok_j, vj = pull(jv)
        ok_n, vn = pull(nv)
        g = g_ref[...]
        gi = jnp.where(ok_i, vi, g[:, 0:s])
        gj = jnp.where(ok_j, vj, g[:, s:2 * s])
        gn = jnp.where(ok_n, vn, g[:, 2 * s:].reshape(b * m, s))
        g_ref[...] = jnp.concatenate(
            [gi, gj, gn.reshape(b, m * s)], axis=1)

    @pl.when(p == 1)
    def _apply():
        @pl.when(t == 0)
        def _grad():
            g = g_ref[...]
            yi = g[:, 0:s]
            yj = g[:, s:2 * s]
            yn = g[:, 2 * s:].reshape(b, m, s)
            mask = mask_ref[...].astype(jnp.float32)
            gi, gj, gn = _forces(yi, yj, yn, mask, gamma=gamma, a=a,
                                 clip=clip, eps=eps)
            lr = lr_ref[...]                               # (B, 1)
            u = jnp.concatenate([gi[:, None, :], gj[:, None, :], gn],
                                axis=1)
            u_ref[...] = (-lr[:, :, None] * u).reshape(b, (2 + m) * s)

        def _acc(rr, u_row):
            # out-of-slab (and frozen-row) updates degrade to rewriting
            # the current value — a bitwise no-op, like the untiled
            # kernel's -0.0 add for frozen rows
            rel = rr - off
            ok = (rel >= 0) & (rel < y_tile)
            if n_frozen:
                ok = ok & (rr >= n_frozen)
            safe = jnp.clip(rel, 0, y_tile - 1)
            cur = y_ref[safe, :]
            y_ref[safe, :] = jnp.where(ok, cur + u_row, cur)

        def body(e, _):
            u = u_ref[e, :].reshape(2 + m, s)
            _acc(i_ref[e, 0], u[0])
            _acc(j_ref[e, 0], u[1])

            def nbody(mm, _):
                _acc(n_ref[e, mm], u[2 + mm])
                return 0

            jax.lax.fori_loop(0, m, nbody, 0)
            return 0

        jax.lax.fori_loop(0, b, body, 0)


@functools.partial(jax.jit, static_argnames=("gamma", "a", "clip", "eps",
                                             "tile", "interpret", "gather",
                                             "n_frozen", "y_tile"))
def fused_edge_step(y, i, j, negs, neg_mask, lr, *, gamma: float = 7.0,
                    a: float = 1.0, clip: float = 5.0, eps: float = 0.1,
                    tile: int = 1024, interpret: bool | None = None,
                    gather: str = "take", n_frozen: int = 0,
                    y_tile: int = 0):
    """One in-place SGD update of ``y`` over a sampled edge batch.

    y: (N, s) f32; i/j: (B,) int32 edge endpoints; negs: (B, M) int32
    negative samples; neg_mask: (B, M) 1.0 valid / 0.0 collision;
    lr: scalar learning rate, or a (B,) per-edge vector (the serving
    engine's lockstep slots sit at different schedule positions — the
    scalar form is the same computation broadcast).  Returns the updated
    (N, s) embedding (same buffer — y is donated to the kernel via
    input_output_aliases).

    ``n_frozen``: rows with index < n_frozen are never written (their
    phase-1 update is masked to -0.0, a bitwise no-op add) — the
    out-of-sample transform mode: corpus rows frozen, query rows moving.

    Any B: the batch is zero-padded to a tile multiple; padded edges point
    at row 0 with i == j and masked negatives, so their gradient is exactly
    zero and the padded updates are no-ops.

    ``y_tile=R`` (with ``0 < R < N``) selects the embedding-tiled mode:
    per grid step only an (R, s) slab of y is resident — the mode that
    lifts the full-VMEM-residency size bound (``ops.largevis_edge_step``
    picks R automatically past the 8 MiB budget).  Bitwise equal to the
    untiled mode for any R (see module docstring); ``gather``/``tile``
    are ignored there (the tiled gather is always the vectorized masked
    form, and edge blocks are whole-batch).
    """
    interpret = _resolve_interpret(interpret)
    assert gather in ("take", "loop"), gather
    N, s = y.shape
    B = i.shape[0]
    M = negs.shape[1]
    if 0 < y_tile < N:
        return _fused_edge_step_tiled(
            y, i, j, negs, neg_mask, lr, gamma=gamma, a=a, clip=clip,
            eps=eps, y_tile=int(y_tile), interpret=interpret,
            n_frozen=n_frozen)
    t = min(tile, B)
    pad = (-B) % t
    lr = jnp.broadcast_to(jnp.asarray(lr, jnp.float32), (B,))
    if pad:
        i = jnp.pad(i, (0, pad))
        j = jnp.pad(j, (0, pad))
        negs = jnp.pad(negs, ((0, pad), (0, 0)))
        neg_mask = jnp.pad(neg_mask, ((0, pad), (0, 0)))
        lr = jnp.pad(lr, (0, pad))
    Bp = B + pad
    n_tiles = Bp // t
    kern = functools.partial(_kernel, gamma=gamma, a=a, clip=clip, eps=eps,
                             tile=t, m=M, s=s, gather=gather,
                             n_frozen=n_frozen)
    return pl.pallas_call(
        kern,
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((N, s), lambda p, tt: (0, 0)),
            pl.BlockSpec((t, 1), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, 1), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, M), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, M), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, 1), lambda p, tt: (tt, 0)),
        ],
        out_specs=pl.BlockSpec((N, s), lambda p, tt: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, s), jnp.float32),
        scratch_shapes=(
            # staged -lr*g update rows, written in phase 0, read in phase 1
            [pltpu.VMEM((Bp, (2 + M) * s), jnp.float32)]
            # per-tile gathered rows — only the gather="loop" branch reads it
            + ([pltpu.VMEM((t, (2 + M) * s), jnp.float32)]
               if gather == "loop" else [])
        ),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(y.astype(jnp.float32), i.reshape(-1, 1).astype(jnp.int32),
      j.reshape(-1, 1).astype(jnp.int32), negs.astype(jnp.int32),
      neg_mask.astype(jnp.float32), lr.reshape(-1, 1))


def _fused_edge_step_tiled(y, i, j, negs, neg_mask, lr, *, gamma, a, clip,
                           eps, y_tile: int, interpret, n_frozen: int):
    """The embedding-tiled pallas_call (see ``_kernel_tiled``).

    y pads to a row-tile multiple (padded rows are never referenced by
    any edge, and are sliced off after the call); edge operands enter as
    whole-batch blocks — their VMEM footprint is O(B*(2+M)*s), never a
    function of N.  No batch padding: the untiled mode's padded edges
    only ever add -0.0 to row 0 (a bitwise no-op), so dropping them
    keeps the two modes bitwise equal.
    """
    N, s = y.shape
    B = i.shape[0]
    M = negs.shape[1]
    R = int(min(y_tile, N))
    n_tiles = -(-N // R)
    Np = n_tiles * R
    yp = y.astype(jnp.float32)
    if Np != N:
        yp = jnp.pad(yp, ((0, Np - N), (0, 0)))
    lr = jnp.broadcast_to(jnp.asarray(lr, jnp.float32), (B,))
    kern = functools.partial(_kernel_tiled, gamma=gamma, a=a, clip=clip,
                             eps=eps, m=M, s=s, b=B, y_tile=R,
                             n_frozen=n_frozen)
    out = pl.pallas_call(
        kern,
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((R, s), lambda p, t: (t, 0)),
            pl.BlockSpec((B, 1), lambda p, t: (0, 0)),
            pl.BlockSpec((B, 1), lambda p, t: (0, 0)),
            pl.BlockSpec((B, M), lambda p, t: (0, 0)),
            pl.BlockSpec((B, M), lambda p, t: (0, 0)),
            pl.BlockSpec((B, 1), lambda p, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((R, s), lambda p, t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, s), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((B, (2 + M) * s), jnp.float32),   # gathered rows
            pltpu.VMEM((B, (2 + M) * s), jnp.float32),   # staged -lr*g
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(yp, i.reshape(-1, 1).astype(jnp.int32),
      j.reshape(-1, 1).astype(jnp.int32), negs.astype(jnp.int32),
      neg_mask.astype(jnp.float32), lr.reshape(-1, 1))
    return out[:N] if Np != N else out
