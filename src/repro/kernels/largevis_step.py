"""Pallas kernel: fully-fused LargeVis edge step — gather, gradient and
scatter-update in one pass over the embedding.

The split layout step moves the edge batch through HBM ~5x: an XLA gather
materializes yi/yj/yneg, the gradient kernel reads and rewrites them, and the
driver concatenates a (B*(2+M), s) update buffer for a scatter-add back into
y.  This kernel takes the full embedding plus the pre-sampled edge batch and
does everything in place:

  phase 0 (per edge tile): row-gather yi/yj/yneg out of the resident y,
      compute the attractive/repulsive forces + per-coordinate clip (the
      same float ops as ``largevis_grad``/``ref.largevis_grads_ref``), and
      stage the ``-lr*g`` update rows in a VMEM scratch —
  phase 1 (per edge tile): sequentially accumulate the staged rows into y.

The grid is (2, n_tiles) and TPU grids iterate the minor dimension fastest,
so *every* gather happens before *any* update — the fused step is exactly
the split step's batch semantics (gather-all, then scatter-all), and the
sequential phase-1 loop serializes duplicate-index updates in the canonical
per-edge order ``[i_e, j_e, negs_e,0..M-1]`` — the same order the split
path's interleaved scatter-add applies, so fused and split trajectories
match bitwise (see ``ref.fused_edge_step_ref`` for the order contract).

In-place: y is aliased input->output via ``input_output_aliases``, so no
second (N, s) buffer and no materialized (B, M, s) HBM intermediates exist
outside the kernel.  y's block spec is the full array, i.e. y stays resident
in VMEM for the whole call — ``ops.fused_step_supported`` bounds this at
~1M nodes for s=2 (an 8 MiB y budget, half of VMEM); beyond that the split
path takes over (streaming y through ANY/HBM with per-tile DMA is the
follow-up for larger N).

Interpret mode (CPU) is not a debug afterthought here: the kernel body
lowers to XLA ops, turning phase 1 into a fori-loop of row updates that
beats XLA's general scatter-add by ~1.5x at N=20k — so ``ops`` routes
``impl="auto"`` to this kernel on every backend.

``n_frozen=`` is the partial-update (out-of-sample transform) mode: rows
below ``n_frozen`` are gathered and contribute forces but are never
written — their phase-1 update is masked to -0.0, which is a bitwise
no-op add for every f32 value — so a fitted corpus embedding stays
BIT-identical while appended query rows optimize against it.  ``lr`` may
be per-edge (B,) so lockstep serving slots at different schedule
positions share one dispatch.

``gather=`` picks how phase 0 reads rows: ``"take"`` (default) gathers with
one vectorized ``jnp.take`` per operand — fast everywhere interpret mode
runs, and maps to Mosaic's dynamic-gather on current TPU toolchains;
``"loop"`` row-copies via dynamic slices, the conservative TPU fallback.
Both are bitwise-identical (tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.largevis_grad import _resolve_interpret


def _kernel(y_in, i_ref, j_ref, n_ref, mask_ref, lr_ref, y_ref, u_ref,
            g_ref=None, *, gamma: float, a: float, clip: float, eps: float,
            tile: int, m: int, s: int, gather: str, n_frozen: int):
    del y_in  # aliased with y_ref; all access goes through the output ref
    p = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(p == 0)
    def _grad():
        # ---- gather the edge rows out of the resident embedding --------
        if gather == "take":
            y = y_ref[...]
            iv = i_ref[...].reshape(-1)
            jv = j_ref[...].reshape(-1)
            yi = jnp.take(y, iv, axis=0)
            yj = jnp.take(y, jv, axis=0)
            yn = jnp.take(y, n_ref[...].reshape(-1),
                          axis=0).reshape(tile, m, s)
        else:  # "loop": per-row dynamic slices (conservative TPU path)
            def gbody(e, _):
                g_ref[e, 0:s] = y_ref[i_ref[e, 0], :]
                g_ref[e, s:2 * s] = y_ref[j_ref[e, 0], :]

                def nbody(mm, _):
                    g_ref[e, pl.ds((2 + mm) * s, s)] = y_ref[n_ref[e, mm], :]
                    return 0

                jax.lax.fori_loop(0, m, nbody, 0)
                return 0

            jax.lax.fori_loop(0, tile, gbody, 0)
            g = g_ref[...]
            yi = g[:, 0:s]
            yj = g[:, s:2 * s]
            yn = g[:, 2 * s:].reshape(tile, m, s)

        # ---- forces + clip: the same float ops as largevis_grads_ref ---
        mask = mask_ref[...].astype(jnp.float32)
        dij = yi - yj
        d2 = jnp.sum(dij * dij, axis=-1, keepdims=True)
        gpos = (2.0 * a / (1.0 + a * d2)) * dij
        din = yi[:, None, :] - yn
        dn2 = jnp.sum(din * din, axis=-1, keepdims=True)
        gneg_i = -2.0 * gamma * din / ((eps + dn2) * (1.0 + a * dn2))
        gneg_i = gneg_i * mask[..., None]
        gi = jnp.clip(gpos + jnp.sum(gneg_i, axis=1), -clip, clip)
        gj = jnp.clip(-gpos, -clip, clip)
        gn = jnp.clip(-gneg_i, -clip, clip)
        # stage -lr*g rows, per-edge interleaved: [u_i, u_j, u_n0..u_n{M-1}]
        # (lr enters as a (tile, 1) per-edge block — the layout drivers
        # broadcast one scalar, the serving engine carries per-slot
        # schedule positions; a broadcast scalar multiplies bitwise
        # identically to the old scalar form)
        lr = lr_ref[...]                                   # (tile, 1)
        u = jnp.concatenate([gi[:, None, :], gj[:, None, :], gn], axis=1)
        u_ref[pl.ds(t * tile, tile), :] = (-lr[:, :, None] * u).reshape(
            tile, (2 + m) * s)

    @pl.when(p == 1)
    def _scatter():
        # sequential accumulate: duplicate indices (within an edge, across
        # edges, across tiles) serialize in canonical per-edge order.
        # Rows below n_frozen (the fitted corpus in transform mode) get
        # their update masked to -0.0 — x + (-0.0) == x bitwise for every
        # f32 including both signed zeros, so frozen rows never change.
        neg_zero = jnp.float32(-0.0)

        def _acc(rr, u_row):
            if n_frozen:
                u_row = jnp.where(rr >= n_frozen, u_row, neg_zero)
            y_ref[rr, :] = y_ref[rr, :] + u_row

        def body(e, _):
            u = u_ref[t * tile + e, :].reshape(2 + m, s)
            _acc(i_ref[e, 0], u[0])
            _acc(j_ref[e, 0], u[1])

            def nbody(mm, _):
                _acc(n_ref[e, mm], u[2 + mm])
                return 0

            jax.lax.fori_loop(0, m, nbody, 0)
            return 0

        jax.lax.fori_loop(0, tile, body, 0)


@functools.partial(jax.jit, static_argnames=("gamma", "a", "clip", "eps",
                                             "tile", "interpret", "gather",
                                             "n_frozen"))
def fused_edge_step(y, i, j, negs, neg_mask, lr, *, gamma: float = 7.0,
                    a: float = 1.0, clip: float = 5.0, eps: float = 0.1,
                    tile: int = 1024, interpret: bool | None = None,
                    gather: str = "take", n_frozen: int = 0):
    """One in-place SGD update of ``y`` over a sampled edge batch.

    y: (N, s) f32; i/j: (B,) int32 edge endpoints; negs: (B, M) int32
    negative samples; neg_mask: (B, M) 1.0 valid / 0.0 collision;
    lr: scalar learning rate, or a (B,) per-edge vector (the serving
    engine's lockstep slots sit at different schedule positions — the
    scalar form is the same computation broadcast).  Returns the updated
    (N, s) embedding (same buffer — y is donated to the kernel via
    input_output_aliases).

    ``n_frozen``: rows with index < n_frozen are never written (their
    phase-1 update is masked to -0.0, a bitwise no-op add) — the
    out-of-sample transform mode: corpus rows frozen, query rows moving.

    Any B: the batch is zero-padded to a tile multiple; padded edges point
    at row 0 with i == j and masked negatives, so their gradient is exactly
    zero and the padded updates are no-ops.
    """
    interpret = _resolve_interpret(interpret)
    assert gather in ("take", "loop"), gather
    N, s = y.shape
    B = i.shape[0]
    M = negs.shape[1]
    t = min(tile, B)
    pad = (-B) % t
    lr = jnp.broadcast_to(jnp.asarray(lr, jnp.float32), (B,))
    if pad:
        i = jnp.pad(i, (0, pad))
        j = jnp.pad(j, (0, pad))
        negs = jnp.pad(negs, ((0, pad), (0, 0)))
        neg_mask = jnp.pad(neg_mask, ((0, pad), (0, 0)))
        lr = jnp.pad(lr, (0, pad))
    Bp = B + pad
    n_tiles = Bp // t
    kern = functools.partial(_kernel, gamma=gamma, a=a, clip=clip, eps=eps,
                             tile=t, m=M, s=s, gather=gather,
                             n_frozen=n_frozen)
    return pl.pallas_call(
        kern,
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((N, s), lambda p, tt: (0, 0)),
            pl.BlockSpec((t, 1), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, 1), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, M), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, M), lambda p, tt: (tt, 0)),
            pl.BlockSpec((t, 1), lambda p, tt: (tt, 0)),
        ],
        out_specs=pl.BlockSpec((N, s), lambda p, tt: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, s), jnp.float32),
        scratch_shapes=(
            # staged -lr*g update rows, written in phase 0, read in phase 1
            [pltpu.VMEM((Bp, (2 + M) * s), jnp.float32)]
            # per-tile gathered rows — only the gather="loop" branch reads it
            + ([pltpu.VMEM((t, (2 + M) * s), jnp.float32)]
               if gather == "loop" else [])
        ),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(y.astype(jnp.float32), i.reshape(-1, 1).astype(jnp.int32),
      j.reshape(-1, 1).astype(jnp.int32), negs.astype(jnp.int32),
      neg_mask.astype(jnp.float32), lr.reshape(-1, 1))
