"""Pallas kernel: fused LargeVis edge-sampling gradient (the layout hot spot).

One grid step processes a tile of sampled edges: attractive force on the
positive pair, repulsive forces on M negatives, reference-impl per-coordinate
clipping — all fused in VMEM so the edge batch streams through HBM once.
The embedding dim s (2 or 3) is far below the 128-lane VPU width, so inputs
arrive (tile, M*s)-flattened to keep the trailing dim reasonable; on TPU the
compiler pads lanes (documented waste ~s/128, irrelevant next to the gather/
scatter traffic that dominates this op).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(yi_ref, yj_ref, yn_ref, mask_ref, gi_ref, gj_ref, gn_ref, *,
            gamma: float, a: float, clip: float, eps: float, m: int,
            s: int):
    yi = yi_ref[...].astype(jnp.float32)                 # (t, s)
    yj = yj_ref[...].astype(jnp.float32)                 # (t, s)
    t = yi.shape[0]
    yn = yn_ref[...].astype(jnp.float32).reshape(t, m, s)
    mask = mask_ref[...].astype(jnp.float32)             # (t, m)

    dij = yi - yj
    d2 = jnp.sum(dij * dij, axis=-1, keepdims=True)
    gpos = (2.0 * a / (1.0 + a * d2)) * dij

    din = yi[:, None, :] - yn                            # (t, m, s)
    dn2 = jnp.sum(din * din, axis=-1, keepdims=True)
    gneg_i = -2.0 * gamma * din / ((eps + dn2) * (1.0 + a * dn2))
    gneg_i = gneg_i * mask[..., None]

    gi_ref[...] = jnp.clip(gpos + jnp.sum(gneg_i, axis=1), -clip, clip)
    gj_ref[...] = jnp.clip(-gpos, -clip, clip)
    gn_ref[...] = jnp.clip(-gneg_i, -clip, clip).reshape(t, m * s)


def _resolve_interpret(interpret) -> bool:
    """Backend-aware default (mirrors ops.py): ``None`` -> interpret mode
    everywhere except TPU, where the kernel compiles.  The old hard
    ``interpret=True`` default silently ran the Python interpreter path on
    TPU unless every caller remembered to override it."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


@functools.partial(jax.jit, static_argnames=("gamma", "a", "clip", "eps",
                                             "tile", "interpret"))
def largevis_grads(yi, yj, yneg, neg_mask, *, gamma: float = 7.0,
                   a: float = 1.0, clip: float = 5.0, eps: float = 0.1,
                   tile: int = 2048, interpret: bool | None = None):
    """yi/yj: (B,s); yneg: (B,M,s); neg_mask: (B,M) -> (gi, gj, gneg)."""
    interpret = _resolve_interpret(interpret)
    B, s = yi.shape
    M = yneg.shape[1]
    tile = min(tile, B)
    assert B % tile == 0, (B, tile)
    grid = (B // tile,)
    kern = functools.partial(_kernel, gamma=gamma, a=a, clip=clip, eps=eps,
                             m=M, s=s)
    gi, gj, gn = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, M * s), lambda i: (i, 0)),
            pl.BlockSpec((tile, M), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, M * s), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, s), jnp.float32),
            jax.ShapeDtypeStruct((B, s), jnp.float32),
            jax.ShapeDtypeStruct((B, M * s), jnp.float32),
        ],
        interpret=interpret,
    )(yi, yj, yneg.reshape(B, M * s), neg_mask)
    return gi, gj, gn.reshape(B, M, s)


@functools.partial(jax.jit, static_argnames=("gamma", "a", "clip", "eps",
                                             "tile", "interpret"))
def largevis_grads_chunked(yi, yj, yneg, neg_mask, *, gamma: float = 7.0,
                           a: float = 1.0, clip: float = 5.0,
                           eps: float = 0.1, tile: int = 2048,
                           interpret: bool | None = None):
    """Tile-padded entry point: any batch size B, same contract as
    :func:`largevis_grads`.

    The strict kernel requires ``B % tile == 0`` — a non-starter inside the
    scanned layout engine, where the collision cap (≤ N/2) produces
    arbitrary odd batch sizes.  This wrapper pads B up to a tile multiple
    (zero rows, zero neg_mask) and slices the grads back; padded rows never
    reach the scatter-add.
    """
    B = yi.shape[0]
    M = yneg.shape[1]
    t = min(tile, B)
    pad = (-B) % t
    if pad == 0:
        return largevis_grads(yi, yj, yneg, neg_mask, gamma=gamma, a=a,
                              clip=clip, eps=eps, tile=t,
                              interpret=interpret)
    def zf(x):
        return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))

    gi, gj, gn = largevis_grads(
        zf(yi), zf(yj), zf(yneg), zf(neg_mask), gamma=gamma, a=a, clip=clip,
        eps=eps, tile=t, interpret=interpret)
    return gi[:B], gj[:B], gn[:B, :M]
