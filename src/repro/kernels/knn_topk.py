"""Pallas kernels for the KNN hot spot.

``pairwise_sqdist`` — blocked pairwise squared distances:
D[i,j] = |a_i|^2 + |b_j|^2 - 2 a_i . b_j — the -2ab^T term is an MXU matmul;
tiles are chosen so (bm, bk) + (bk, bn) + (bm, bn) blocks live in VMEM and
the contraction dim is 128-aligned (inputs are zero-padded to multiples of
the tile).  Grid is (M/bm, N/bn, d/bk) with a VMEM f32 accumulator; norms
are folded in on the last k-step.

``topk_sqdist`` — streaming fused distance -> top-k: a flash-attention-style
fold that keeps a running (bm, k) best-ids/best-similarities state in VMEM
and folds each (bm, bn) distance tile into it inside the column-tile grid
loop, so the (M, N) distance matrix and the post-hoc top_k/merge passes
never materialize.  Self-edges, padding, bucket-code mismatches and
duplicates of the running state are masked in-kernel (the shared
``ref._mask_tile``).  The merge is k rounds of max-extraction — plain
max/min/where/iota, no sort, so it lowers under Mosaic — and is
bit-identical to ``lax.top_k``'s earliest-index tie order, which is what
the streaming jnp oracle (``ref.topk_sqdist_ref``, also the CPU production
path) uses; tests assert bitwise (ids, dists) equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as ref_lib
from repro.kernels.largevis_grad import _resolve_interpret


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)                   # (bm, bk)
    b = b_ref[...].astype(jnp.float32)                   # (bn, bk)
    acc_ref[...] += -2.0 * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.sum(a * a, axis=1, keepdims=True)
    acc_ref[...] += jnp.sum(b * b, axis=1)[None, :]

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0)


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pairwise_sqdist(a: jax.Array, b: jax.Array, *, bm: int = 256,
                    bn: int = 256, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """a: (M,d), b: (N,d) -> (M,N) squared distances (f32).

    ``interpret=None`` resolves per backend (the shared largevis_grad
    helper, PR-2 fix): compiled on TPU, interpret mode (kernel body as
    XLA ops) elsewhere, e.g. this CPU container.  The old hard
    ``interpret=True`` default silently ran the interpreter path on TPU
    for every direct caller that forgot to override it.
    """
    interpret = _resolve_interpret(interpret)
    M, d = a.shape
    N = b.shape[0]
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, d)
    ap = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    bp = _pad_to(_pad_to(b, bn_, 0), bk_, 1)
    Mp, dp = ap.shape
    Np = bp.shape[0]
    n_k = dp // bk_
    grid = (Mp // bm_, Np // bn_, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# streaming fused distance -> top-k
# ---------------------------------------------------------------------------


def _select_topk(s_all, i_all, k: int):
    """Top-k of each row of ``s_all`` by repeated max-extraction.

    Bit-identical to ``lax.top_k(s_all, k)`` + gathering ``i_all`` at the
    winning positions: equal values resolve to the earliest position (the
    documented top_k tie order), and extracted slots drop to -inf, which
    is strictly below every live value (masked candidates sit at
    ``ref.INVALID_SIM`` = -3e38 > -inf), so a slot is never re-taken.
    Only max/min/where/sum/iota — lowers under Mosaic, where lax.top_k
    does not.
    """
    bm, W = s_all.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (bm, W), 1)
    slot = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)

    def pick(t, st):
        os_, oi_, cs = st
        m = jnp.max(cs, axis=1, keepdims=True)                    # (bm, 1)
        p = jnp.min(jnp.where(cs == m, pos, W), axis=1, keepdims=True)
        hit = pos == p
        sel_i = jnp.sum(jnp.where(hit, i_all, 0), axis=1, keepdims=True)
        os_ = jnp.where(slot == t, m, os_)
        oi_ = jnp.where(slot == t, sel_i, oi_)
        cs = jnp.where(hit, -jnp.inf, cs)
        return os_, oi_, cs

    os0 = jnp.zeros((bm, k), s_all.dtype)
    oi0 = jnp.zeros((bm, k), jnp.int32)
    os_, oi_, _ = jax.lax.fori_loop(0, k, pick, (os0, oi0, s_all))
    return os_, oi_


def _topk_kernel(*refs, k: int, n_n: int, has_codes: bool, has_init: bool,
                 dedup: bool):
    it = iter(refs)
    a_ref, b_ref, aid_ref, bid_ref = next(it), next(it), next(it), next(it)
    ca_ref = next(it) if has_codes else None
    cb_ref = next(it) if has_codes else None
    ii_ref = next(it) if has_init else None
    is_ref = next(it) if has_init else None
    oi_ref, od_ref, si_ref, ss_ref = next(it), next(it), next(it), next(it)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if has_init:
            si_ref[...] = ii_ref[...]
            ss_ref[...] = jnp.maximum(-is_ref[...], ref_lib.INVALID_SIM)
        else:
            si_ref[...] = jnp.full_like(si_ref, -1)
            ss_ref[...] = jnp.full_like(ss_ref, ref_lib.INVALID_SIM)

    a = a_ref[...].astype(jnp.float32)                            # (bm, dp)
    b = b_ref[...].astype(jnp.float32)                            # (bn, dp)
    an = jnp.sum(a * a, axis=1)
    bn_norm = jnp.sum(b * b, axis=1)
    s = ref_lib._sim_tile(a, b, an, bn_norm)                      # (bm, bn)
    si, ss = si_ref[...], ss_ref[...]
    s = ref_lib._mask_tile(
        s, aid_ref[...][:, 0], bid_ref[...][0, :],
        ca_ref[...] if has_codes else None,
        cb_ref[...] if has_codes else None, si, dedup)
    s_all = jnp.concatenate([ss, s], axis=1)
    i_all = jnp.concatenate(
        [si, jnp.broadcast_to(bid_ref[...][0:1, :], s.shape)], axis=1)
    ns, ni = _select_topk(s_all, i_all, k)
    ss_ref[...] = ns
    si_ref[...] = ni

    @pl.when(j == n_n - 1)
    def _done():
        oi_ref[...] = si_ref[...]
        od_ref[...] = jnp.maximum(-ss_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("k", "dedup", "bm", "bn",
                                             "lane", "interpret"))
def topk_sqdist(a: jax.Array, b: jax.Array, k: int, *,
                a_ids: jax.Array | None = None,
                b_ids: jax.Array | None = None,
                codes_a: jax.Array | None = None,
                codes_b: jax.Array | None = None,
                init_ids: jax.Array | None = None,
                init_dists: jax.Array | None = None,
                dedup: bool = False, bm: int = 256, bn: int = 512,
                lane: int = 128, interpret: bool | None = None):
    """Streaming fused distance->top-k Pallas kernel.

    a: (M, d), b: (N, d) -> (ids (M, k) int32, sqdists (M, k) f32),
    distances ascending.  Semantics, masking and tie order are exactly
    ``ref.topk_sqdist_ref`` (bit-identical when called with the same
    bm/bn/lane); see its docstring for the a_ids/b_ids/codes/init/dedup
    contract.  Grid is (M/bm, N/bn) with the column dimension innermost;
    the (bm, k) running state lives in VMEM scratch across the column
    sweep and the output block is written on the last column step.
    ``lane`` (default 128) zero-pads d to the MXU lane width.

    ``interpret=None`` resolves per backend (compiled on TPU, interpret
    elsewhere).  On CPU, ``ops.topk_sqdist`` routes impl="auto" to the
    jnp streaming oracle instead — the interpreter is Python-slow.
    """
    interpret = _resolve_interpret(interpret)
    M, d = a.shape
    N = b.shape[0]
    bm_ = min(bm, M)
    bn_ = min(bn, N)
    a_ids = (jnp.full((M,), -1, jnp.int32) if a_ids is None
             else a_ids.astype(jnp.int32))
    b_ids = (jnp.arange(N, dtype=jnp.int32) if b_ids is None
             else b_ids.astype(jnp.int32))
    pad = ref_lib._pad_dim
    ap = pad(pad(a.astype(jnp.float32), bm_, 0), lane, 1)
    bp = pad(pad(b.astype(jnp.float32), bn_, 0), lane, 1)
    Mp, dp = ap.shape
    Np = bp.shape[0]
    aip = pad(a_ids, bm_, 0)[:, None]                             # (Mp, 1)
    bip = jnp.pad(b_ids, (0, Np - N), constant_values=-1)[None, :]
    n_m, n_n = Mp // bm_, Np // bn_
    grid = (n_m, n_n)

    operands = [ap, bp, aip, bip]
    in_specs = [
        pl.BlockSpec((bm_, dp), lambda i, j: (i, 0)),
        pl.BlockSpec((bn_, dp), lambda i, j: (j, 0)),
        pl.BlockSpec((bm_, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((1, bn_), lambda i, j: (0, j)),
    ]
    has_codes = codes_a is not None
    if has_codes:
        T = codes_a.shape[1]
        operands += [pad(codes_a.astype(jnp.int32), bm_, 0),
                     pad(codes_b.astype(jnp.int32), bn_, 0)]
        in_specs += [pl.BlockSpec((bm_, T), lambda i, j: (i, 0)),
                     pl.BlockSpec((bn_, T), lambda i, j: (j, 0))]
    has_init = init_ids is not None
    if has_init:
        operands += [pad(init_ids.astype(jnp.int32), bm_, 0),
                     pad(init_dists.astype(jnp.float32), bm_, 0)]
        in_specs += [pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
                     pl.BlockSpec((bm_, k), lambda i, j: (i, 0))]

    idx, dist = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, n_n=n_n, has_codes=has_codes,
                          has_init=has_init, dedup=dedup),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
                   pl.BlockSpec((bm_, k), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Mp, k), jnp.int32),
                   jax.ShapeDtypeStruct((Mp, k), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm_, k), jnp.int32),
                        pltpu.VMEM((bm_, k), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return idx[:M], dist[:M]
