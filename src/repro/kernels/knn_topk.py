"""Pallas kernel: blocked pairwise squared distances (the KNN hot spot).

D[i,j] = |a_i|^2 + |b_j|^2 - 2 a_i . b_j — the -2ab^T term is an MXU matmul;
tiles are chosen so (bm, bk) + (bk, bn) + (bm, bn) blocks live in VMEM and
the contraction dim is 128-aligned (inputs are zero-padded to multiples of
the tile).  Grid is (M/bm, N/bn, d/bk) with a VMEM f32 accumulator; norms
are folded in on the last k-step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.largevis_grad import _resolve_interpret


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)                   # (bm, bk)
    b = b_ref[...].astype(jnp.float32)                   # (bn, bk)
    acc_ref[...] += -2.0 * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.sum(a * a, axis=1, keepdims=True)
    acc_ref[...] += jnp.sum(b * b, axis=1)[None, :]

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0)


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pairwise_sqdist(a: jax.Array, b: jax.Array, *, bm: int = 256,
                    bn: int = 256, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """a: (M,d), b: (N,d) -> (M,N) squared distances (f32).

    ``interpret=None`` resolves per backend (the shared largevis_grad
    helper, PR-2 fix): compiled on TPU, interpret mode (kernel body as
    XLA ops) elsewhere, e.g. this CPU container.  The old hard
    ``interpret=True`` default silently ran the interpreter path on TPU
    for every direct caller that forgot to override it.
    """
    interpret = _resolve_interpret(interpret)
    M, d = a.shape
    N = b.shape[0]
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, d)
    ap = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    bp = _pad_to(_pad_to(b, bn_, 0), bk_, 1)
    Mp, dp = ap.shape
    Np = bp.shape[0]
    n_k = dp // bk_
    grid = (Mp // bm_, Np // bn_, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:M, :N]
