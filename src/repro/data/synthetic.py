"""Synthetic datasets for tests/benchmarks (offline container: the paper's
corpora are unavailable, so structured stand-ins with the same shape —
clustered high-dim data with labels — back the quality metrics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_mixture(key, n: int, d: int, n_clusters: int,
                     sep: float = 6.0, scale: float = 1.0):
    """Well-separated clusters on a random simplex.  Returns (x, labels)."""
    kc, kx, kl = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_clusters, d)) * sep / np.sqrt(2)
    labels = jax.random.randint(kl, (n,), 0, n_clusters)
    x = centers[labels] + jax.random.normal(kx, (n, d)) * scale
    return x.astype(jnp.float32), labels


def swiss_roll(key, n: int, d: int = 3, noise: float = 0.05):
    """Classic manifold; extra dims are noise-padded.  Labels = roll angle
    quartile (for the KNN-classifier metric)."""
    k1, k2, k3 = jax.random.split(key, 3)
    t = 1.5 * np.pi * (1 + 2 * jax.random.uniform(k1, (n,)))
    h = 21 * jax.random.uniform(k2, (n,))
    x3 = jnp.stack([t * jnp.cos(t), h, t * jnp.sin(t)], axis=1)
    x3 = x3 + noise * jax.random.normal(k3, (n, 3))
    if d > 3:
        pad = 0.01 * jax.random.normal(jax.random.fold_in(key, 9),
                                       (n, d - 3))
        x3 = jnp.concatenate([x3, pad], axis=1)
    labels = jnp.clip(((t - t.min()) / (t.max() - t.min()) * 4), 0, 3)
    return x3.astype(jnp.float32), labels.astype(jnp.int32)


def mnist_like(key, n: int = 4096, d: int = 784, n_classes: int = 10):
    """MNIST-shaped stand-in: class templates + structured deformation."""
    kt, kd, kl, kn = jax.random.split(key, 4)
    templates = jax.random.normal(kt, (n_classes, d)) * 2.0
    basis = jax.random.normal(kd, (n_classes, 8, d)) * 0.8
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    coeff = jax.random.normal(jax.random.fold_in(kn, 1), (n, 8))
    x = templates[labels] + jnp.einsum("nk,nkd->nd", coeff, basis[labels])
    x = x + 0.3 * jax.random.normal(kn, (n, d))
    return x.astype(jnp.float32), labels


def token_stream(key, n_batches: int, batch: int, seq: int, vocab: int,
                 markov: float = 0.9):
    """Deterministic synthetic token batches with learnable structure:
    next = perm[prev] with prob ``markov`` (else uniform) — cross-entropy
    floor ~= H(markov) << ln(vocab), so training loss visibly drops.
    markov=0 gives uniform-random tokens (floor = ln(vocab))."""
    perm = jax.random.permutation(jax.random.fold_in(key, 10**6), vocab)
    for i in range(n_batches):
        k = jax.random.fold_in(key, i)
        if markov <= 0:
            toks = jax.random.randint(k, (batch, seq + 1), 0, vocab)
        else:
            k0, k1, k2 = jax.random.split(k, 3)
            start = jax.random.randint(k0, (batch,), 0, vocab)
            noise = jax.random.randint(k1, (batch, seq), 0, vocab)
            use_noise = jax.random.uniform(k2, (batch, seq)) > markov

            def step(prev, inp):
                nz, un = inp
                nxt = jnp.where(un, nz, perm[prev])
                return nxt, nxt

            _, rest = jax.lax.scan(
                step, start, (noise.T, use_noise.T))
            toks = jnp.concatenate([start[:, None], rest.T], axis=1)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
