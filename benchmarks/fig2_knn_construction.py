"""Paper Fig 2: KNN graph construction — running time vs recall, 4 methods.

Methods (as in §4.2): random-projection forest alone (Annoy stand-in),
vantage-point tree (the t-SNE baseline), NN-Descent (exploring from random
init), LargeVis (forest init + exploring).  Each method sweeps its knob to
trace a time/recall curve.  Expected (paper claim C2): LargeVis reaches the
highest recall at the lowest time; vp-trees are the slowest at high d.

Multi-device mode (``--devices P``): exposes P host CPU devices via
``--xla_force_host_platform_device_count`` (parsed before any
backend-touching import — see the early argparse block) and adds the
sharded pipeline (`core/knn_sharded.py`) to the sweep next to its
single-device counterpart.
"""
from __future__ import annotations

import argparse
import os
import time

_ARGS = None
if __name__ == "__main__":
    # parse BEFORE the imports below: repro modules build jnp constants at
    # import time, which initializes the backend and freezes XLA_FLAGS
    _ap = argparse.ArgumentParser(description=__doc__)
    _ap.add_argument("--devices", type=int, default=0,
                     help="expose this many host CPU devices and add the "
                          "sharded-pipeline sweep (e.g. 8)")
    _ap.add_argument("--sharded-only", action="store_true",
                     help="skip the single-device method sweep")
    _ap.add_argument("--tiny", action="store_true",
                     help="CI bench-smoke mode: small N, reduced sweeps")
    _ARGS = _ap.parse_args()
    if _ARGS.sharded_only and _ARGS.devices < 1:
        _ap.error("--sharded-only requires --devices (e.g. --devices 8)")
    if _ARGS.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_ARGS.devices}")

import jax
import numpy as np

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.baselines.nn_descent import nn_descent
from repro.core.baselines.vptree import vptree_knn
from repro.core.knn import brute_force_knn, build_knn_graph, knn_recall

N = 6000
K = 20


def run_sharded(rows: Rows, n_devices: int, *, include_single: bool = True):
    """Sharded stage-1 sweep (+ the single-device arm for comparison when
    `run()` did not already benchmark it on this fixture)."""
    from repro.core.knn_sharded import build_knn_graph_sharded
    from repro.launch.mesh import make_data_mesh
    key = jax.random.key(0)
    x, _ = dataset("blobs100", N, key)
    true_idx, _ = brute_force_knn(x, K)
    mesh = make_data_mesh(n_devices)
    for nt in (2, 4, 8):
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=1,
                             window=32, distributed=True)
        (idx, _), secs = timed(build_knn_graph_sharded, x, key, cfg,
                               mesh=mesh)
        r = knn_recall(idx, true_idx)
        rows.add(f"sharded{mesh.shape['data']}_nt{nt}", secs,
                 recall=round(r, 4), method="largevis_sharded",
                 devices=mesh.shape["data"])
        if include_single:
            cfg1 = LargeVisConfig(n_neighbors=K, n_trees=nt,
                                  n_explore_iters=1, window=32)
            (idx1, _), secs1 = timed(build_knn_graph, x, key, cfg1)
            rows.add(f"single_nt{nt}", secs1,
                     recall=round(knn_recall(idx1, true_idx), 4),
                     method="largevis", devices=1)


def run(rows: Rows, *, n: int = N, tree_sweep=(2, 4, 8)):
    KEY = jax.random.key(0)
    x, _ = dataset("blobs100", n, KEY)
    true_idx, _ = brute_force_knn(x, K)

    # --- LargeVis: forest + 1 exploring iteration, sweep trees ---
    for nt in tree_sweep:
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=1,
                             window=32)
        (idx, _), secs = timed(build_knn_graph, x, KEY, cfg)
        r = knn_recall(idx, true_idx)
        rows.add(f"largevis_nt{nt}", secs, recall=round(r, 4), method="largevis")

    # --- RP forest alone (no exploring), sweep trees ---
    for nt in tuple(2 * t for t in tree_sweep):
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=0,
                             window=32)
        (idx, _), secs = timed(build_knn_graph, x, KEY, cfg)
        r = knn_recall(idx, true_idx)
        rows.add(f"rp_forest_nt{nt}", secs, recall=round(r, 4),
                 method="rp_trees")

    # --- NN-Descent from random init, sweep iterations ---
    for it in (2, 4):
        (idx, _), secs = timed(nn_descent, x, K, iters=it, key=KEY)
        r = knn_recall(idx, true_idx)
        rows.add(f"nn_descent_it{it}", secs, recall=round(r, 4),
                 method="nn_descent")

    # --- vp-tree (host numpy; queries a subset, extrapolated) ---
    n_q = min(400, n // 4)
    t0 = time.time()
    got = vptree_knn(np.asarray(x), K, eps=0.0, n_query=n_q)
    secs = (time.time() - t0) * (n / n_q)
    matches = (got[:, :, None] == np.asarray(true_idx)[:n_q, None, :]).any(-1)
    rows.add("vptree_exact", secs, recall=round(float(matches.mean()), 4),
             method="vptree", extrapolated_from=n_q)


def run_tiny(rows: Rows):
    """CI bench-smoke mode: same sweep structure at N=1500.

    Must be given a ``Rows("fig2_knn_construction_tiny")`` — row names are
    a stable interface matched across runs (benchmarks/README.md), and the
    tiny workload's timings are not comparable to the full N=6000 rows.
    """
    run(rows, n=1500, tree_sweep=(2, 4))


if __name__ == "__main__":
    if _ARGS.tiny:
        rows = Rows("fig2_knn_construction_tiny")
        run_tiny(rows)
    else:
        rows = Rows("fig2_knn_construction")
        if not _ARGS.sharded_only:
            run(rows)
        if _ARGS.devices >= 1:
            run_sharded(rows, _ARGS.devices,
                        include_single=_ARGS.sharded_only)
    rows.print_csv()
    rows.save()
