"""Paper Fig 2: KNN graph construction — running time vs recall, 4 methods.

Methods (as in §4.2): random-projection forest alone (Annoy stand-in),
vantage-point tree (the t-SNE baseline), NN-Descent (exploring from random
init), LargeVis (forest init + exploring).  Each method sweeps its knob to
trace a time/recall curve.  Expected (paper claim C2): LargeVis reaches the
highest recall at the lowest time; vp-trees are the slowest at high d.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.baselines.nn_descent import nn_descent
from repro.core.baselines.vptree import vptree_knn
from repro.core.knn import brute_force_knn, build_knn_graph, knn_recall

N = 6000
K = 20
KEY = jax.random.key(0)


def run(rows: Rows):
    x, _ = dataset("blobs100", N, KEY)
    true_idx, _ = brute_force_knn(x, K)

    # --- LargeVis: forest + 1 exploring iteration, sweep trees ---
    for nt in (2, 4, 8):
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=1,
                             window=32)
        (idx, _), secs = timed(build_knn_graph, x, KEY, cfg)
        r = knn_recall(idx, true_idx)
        rows.add(f"largevis_nt{nt}", secs, recall=round(r, 4), method="largevis")

    # --- RP forest alone (no exploring), sweep trees ---
    for nt in (4, 8, 16):
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=0,
                             window=32)
        (idx, _), secs = timed(build_knn_graph, x, KEY, cfg)
        r = knn_recall(idx, true_idx)
        rows.add(f"rp_forest_nt{nt}", secs, recall=round(r, 4),
                 method="rp_trees")

    # --- NN-Descent from random init, sweep iterations ---
    for it in (2, 4):
        (idx, _), secs = timed(nn_descent, x, K, iters=it, key=KEY)
        r = knn_recall(idx, true_idx)
        rows.add(f"nn_descent_it{it}", secs, recall=round(r, 4),
                 method="nn_descent")

    # --- vp-tree (host numpy; queries a subset, extrapolated) ---
    n_q = 400
    t0 = time.time()
    got = vptree_knn(np.asarray(x), K, eps=0.0, n_query=n_q)
    secs = (time.time() - t0) * (N / n_q)
    matches = (got[:, :, None] == np.asarray(true_idx)[:n_q, None, :]).any(-1)
    rows.add("vptree_exact", secs, recall=round(float(matches.mean()), 4),
             method="vptree", extrapolated_from=n_q)


if __name__ == "__main__":
    rows = Rows("fig2_knn_construction")
    run(rows)
    rows.print_csv()
    rows.save()
