"""Paper Fig 2: KNN graph construction — running time vs recall, 4 methods.

Methods (as in §4.2): random-projection forest alone (Annoy stand-in),
vantage-point tree (the t-SNE baseline), NN-Descent (exploring from random
init), LargeVis (forest init + exploring).  Each method sweeps its knob to
trace a time/recall curve.  Expected (paper claim C2): LargeVis reaches the
highest recall at the lowest time; vp-trees are the slowest at high d.

Multi-device mode (``--devices P``): exposes P host CPU devices via
``--xla_force_host_platform_device_count`` (parsed before any
backend-touching import — see the early argparse block) and adds the
sharded pipeline (`core/knn_sharded.py`) to the sweep next to its
single-device counterpart.

The `knn_n{2k,20k,100k}` / `knn_materialize_n*` rows compare the
streaming fused distance->top-k path (`kernels/ops.py::topk_sqdist`)
against the materialize-then-top_k baseline on q=4096 queries vs an
n-point corpus (interleaved best-of-5; ``us_per_point`` is the CI-gated
metric — see benchmarks/README.md).
"""
from __future__ import annotations

import argparse
import os
import time

_ARGS = None
if __name__ == "__main__":
    # parse BEFORE the imports below: repro modules build jnp constants at
    # import time, which initializes the backend and freezes XLA_FLAGS
    _ap = argparse.ArgumentParser(description=__doc__)
    _ap.add_argument("--devices", type=int, default=0,
                     help="expose this many host CPU devices and add the "
                          "sharded-pipeline sweep (e.g. 8)")
    _ap.add_argument("--sharded-only", action="store_true",
                     help="skip the single-device method sweep")
    _ap.add_argument("--tiny", action="store_true",
                     help="CI bench-smoke mode: small N, reduced sweeps")
    _ARGS = _ap.parse_args()
    if _ARGS.sharded_only and _ARGS.devices < 1:
        _ap.error("--sharded-only requires --devices (e.g. --devices 8)")
    if _ARGS.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_ARGS.devices}")

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, best_of_interleaved, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.baselines.nn_descent import nn_descent
from repro.core.baselines.vptree import vptree_knn
from repro.core.knn import INF, brute_force_knn, build_knn_graph, knn_recall
from repro.kernels import ops

N = 6000
K = 20

# streaming fused distance->top-k vs the materialize-then-top_k baseline:
# corpus sizes for the knn_n{2k,20k,100k} rows (q queries against an
# n-point corpus — the unit of work every KNN consumer performs)
TOPK_NS = (2_048, 20_480, 102_400)
TOPK_LABEL = {2_048: "2k", 20_480: "20k", 102_400: "100k"}
TOPK_Q = 4_096          # queries per call (capped at n)
TOPK_TILE = 4_096       # baseline row-tile height: the pre-streaming
#   brute_force_knn's shipped default — the row compares old-as-shipped
#   vs new-as-shipped (ops.topk_sqdist's own bm/bn/lane defaults)


@functools.partial(jax.jit, static_argnames=("k", "tile"))
def _materialize_topk(x, q, k, tile=TOPK_TILE):
    """The pre-fused baseline: per row tile, materialize the (tile, N)
    distance buffer, mask self-edges, run lax.top_k over the full width —
    exactly what `brute_force_knn` did before the streaming kernel."""
    M, d = q.shape
    n_real = x.shape[0]
    tile = min(tile, M)                # the old brute's t = min(tile, N)
    n_tiles = -(-M // tile)
    qp = jnp.pad(q, ((0, n_tiles * tile - M), (0, 0)))
    col = jnp.arange(n_real)

    def one(args):
        qa, start = args
        dd = ops.pairwise_sqdist(qa, x)                   # (tile, N)
        rows = start + jnp.arange(tile)
        dd = jnp.where(col[None, :] == rows[:, None], INF, dd)
        nd, ni = jax.lax.top_k(-dd, k)
        return ni.astype(jnp.int32), -nd

    idx, dist = jax.lax.map(one, (qp.reshape(n_tiles, tile, d),
                                  jnp.arange(n_tiles) * tile))
    return idx.reshape(-1, k)[:M], dist.reshape(-1, k)[:M]


@functools.partial(jax.jit, static_argnames=("k",))
def _stream_topk(x, q, k):
    """The fused path: `ops.topk_sqdist` (the Pallas kernel on TPU, the
    bit-identical streaming jnp fold on CPU — no (tile, N) buffer), at
    its production defaults."""
    return ops.topk_sqdist(
        q, x, k, a_ids=jnp.arange(q.shape[0], dtype=jnp.int32),
        b_ids=jnp.arange(x.shape[0], dtype=jnp.int32))


def knn_topk_rows(rows: Rows, ns=TOPK_NS):
    """`knn_n*` (fused streaming) vs `knn_materialize_n*` rows.

    Interleaved best-of-5 timing (the table2 methodology, two extra
    rounds: these calls are seconds long, so a single load spike can
    swallow a whole round); ``us_per_point`` (µs per query point) is the
    metric the CI bench-smoke gate regresses on the `knn_n*` rows.
    """
    key = jax.random.key(0)
    for n in ns:
        x, _ = dataset("blobs100", n, key)
        q = x[: min(TOPK_Q, n)]
        nq = q.shape[0]
        ((bi, _), (si, _)), (secs_base, secs_stream) = best_of_interleaved(
            [lambda: _materialize_topk(x, q, K),
             lambda: _stream_topk(x, q, K)], repeats=5)
        agree = float(jnp.mean(
            (jnp.sort(bi, axis=1) == jnp.sort(si, axis=1)).all(axis=1)))
        label = TOPK_LABEL.get(n, str(n))
        rows.add(f"knn_materialize_n{label}", secs_base, n=n, q=nq, k=K,
                 us_per_point=round(secs_base * 1e6 / nq, 3))
        rows.add(f"knn_n{label}", secs_stream, n=n, q=nq, k=K,
                 us_per_point=round(secs_stream * 1e6 / nq, 3),
                 speedup_vs_materialize=round(
                     secs_base / max(secs_stream, 1e-9), 2),
                 rows_matching_baseline=round(agree, 4))


def run_sharded(rows: Rows, n_devices: int, *, include_single: bool = True):
    """Sharded stage-1 sweep (+ the single-device arm for comparison when
    `run()` did not already benchmark it on this fixture)."""
    from repro.core.knn_sharded import build_knn_graph_sharded
    from repro.launch.mesh import make_data_mesh
    key = jax.random.key(0)
    x, _ = dataset("blobs100", N, key)
    true_idx, _ = brute_force_knn(x, K)
    mesh = make_data_mesh(n_devices)
    for nt in (2, 4, 8):
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=1,
                             window=32, distributed=True)
        (idx, _), secs = timed(build_knn_graph_sharded, x, key, cfg,
                               mesh=mesh)
        r = knn_recall(idx, true_idx)
        rows.add(f"sharded{mesh.shape['data']}_nt{nt}", secs,
                 recall=round(r, 4), method="largevis_sharded",
                 devices=mesh.shape["data"])
        if include_single:
            cfg1 = LargeVisConfig(n_neighbors=K, n_trees=nt,
                                  n_explore_iters=1, window=32)
            (idx1, _), secs1 = timed(build_knn_graph, x, key, cfg1)
            rows.add(f"single_nt{nt}", secs1,
                     recall=round(knn_recall(idx1, true_idx), 4),
                     method="largevis", devices=1)


def run(rows: Rows, *, n: int = N, tree_sweep=(2, 4, 8),
        knn_rows: bool = True):
    if knn_rows:
        # first, on a fresh process: these rows carry the CI-gated
        # us_per_point trajectory and are the most allocator/load
        # sensitive numbers in the file
        knn_topk_rows(rows)
    KEY = jax.random.key(0)
    x, _ = dataset("blobs100", n, KEY)
    true_idx, _ = brute_force_knn(x, K)

    # --- LargeVis: forest + 1 exploring iteration, sweep trees ---
    for nt in tree_sweep:
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=1,
                             window=32)
        (idx, _), secs = timed(build_knn_graph, x, KEY, cfg)
        r = knn_recall(idx, true_idx)
        rows.add(f"largevis_nt{nt}", secs, recall=round(r, 4), method="largevis")

    # --- RP forest alone (no exploring), sweep trees ---
    for nt in tuple(2 * t for t in tree_sweep):
        cfg = LargeVisConfig(n_neighbors=K, n_trees=nt, n_explore_iters=0,
                             window=32)
        (idx, _), secs = timed(build_knn_graph, x, KEY, cfg)
        r = knn_recall(idx, true_idx)
        rows.add(f"rp_forest_nt{nt}", secs, recall=round(r, 4),
                 method="rp_trees")

    # --- NN-Descent from random init, sweep iterations ---
    for it in (2, 4):
        (idx, _), secs = timed(nn_descent, x, K, iters=it, key=KEY)
        r = knn_recall(idx, true_idx)
        rows.add(f"nn_descent_it{it}", secs, recall=round(r, 4),
                 method="nn_descent")

    # --- vp-tree (host numpy; queries a subset, extrapolated) ---
    n_q = min(400, n // 4)
    t0 = time.time()
    got = vptree_knn(np.asarray(x), K, eps=0.0, n_query=n_q)
    secs = (time.time() - t0) * (n / n_q)
    matches = (got[:, :, None] == np.asarray(true_idx)[:n_q, None, :]).any(-1)
    rows.add("vptree_exact", secs, recall=round(float(matches.mean()), 4),
             method="vptree", extrapolated_from=n_q)


def run_tiny(rows: Rows):
    """CI bench-smoke mode: same sweep structure at N=1500.

    Must be given a ``Rows("fig2_knn_construction_tiny")`` — row names are
    a stable interface matched across runs (benchmarks/README.md), and the
    tiny workload's timings are not comparable to the full N=6000 rows.
    The `knn_n*` topk rows are NOT here: their tiny mode (`knn_n2k`, run
    with the exact full-run config) shares the committed
    ``fig2_knn_construction`` baseline, so __main__ writes it to the main
    table — the same split table2 uses for its engine rows.
    """
    run(rows, n=1500, tree_sweep=(2, 4), knn_rows=False)


if __name__ == "__main__":
    if _ARGS.tiny:
        # the gated topk rows FIRST, on a fresh process — matching how
        # the committed baseline measures them (run() does the same) —
        # with the exact full-run config at n=2048 only, into the main
        # table so the committed baseline's row names match
        rows = Rows("fig2_knn_construction")
        knn_topk_rows(rows, ns=(2_048,))
        rows.print_csv()
        rows.save()
        rows = Rows("fig2_knn_construction_tiny")
        run_tiny(rows)
    else:
        rows = Rows("fig2_knn_construction")
        if not _ARGS.sharded_only:
            run(rows)
        if _ARGS.devices >= 1:
            run_sharded(rows, _ARGS.devices,
                        include_single=_ARGS.sharded_only)
    rows.print_csv()
    rows.save()
