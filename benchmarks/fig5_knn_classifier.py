"""Paper Fig 5: layout quality (2D KNN-classifier accuracy) across methods.

LargeVis (default params) vs t-SNE (default + tuned lr) vs symmetric SNE vs
LINE-2D, all consuming the SAME LargeVis-built KNN graph (paper §4.3).
Claims C4: LargeVis >= t-SNE-tuned with defaults; LINE is a poor visualizer.
"""
from __future__ import annotations

import jax

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core import sampler as S
from repro.core.baselines.line import line_layout
from repro.core.baselines.tsne import tsne_layout
from repro.core.largevis import build_graph, layout_graph
from repro.core.metrics import knn_classifier_accuracy

N = 2500          # exact O(N^2) t-SNE bounds the size
KEY = jax.random.key(3)


def run(rows: Rows):
    for ds in ("blobs100", "mnist_like"):
        x, labels = dataset(ds, N, KEY)
        cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                             window=32, perplexity=12.0,
                             samples_per_node=4000, batch_size=4096)
        idx, dist, w, _ = build_graph(x, KEY, cfg=cfg)

        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg=cfg)
        acc = knn_classifier_accuracy(res.y, labels, k=5)
        rows.add(f"{ds}/largevis_default", secs, accuracy=round(acc, 4))

        for lr, tag in ((200.0, "default_lr"), (1000.0, "tuned_lr")):
            (y, _), secs = timed(tsne_layout, idx, w, n_iter=300, lr=lr,
                                 key=KEY)
            acc = knn_classifier_accuracy(y, labels, k=5)
            rows.add(f"{ds}/tsne_{tag}", secs, accuracy=round(acc, 4))

        # SNE's Gaussian kernel needs a much smaller lr than t-SNE's
        # Student-t (gradients lack the heavy-tail damping factor)
        (y, _), secs = timed(tsne_layout, idx, w, n_iter=300, lr=20.0,
                             student_t=False, key=KEY)
        acc = knn_classifier_accuracy(y, labels, k=5)
        rows.add(f"{ds}/symmetric_sne", secs, accuracy=round(acc, 4))

        es = S.build_edge_sampler(idx, w)
        ns = S.build_negative_sampler(idx, w)
        y, secs = timed(line_layout, KEY, es, ns, x.shape[0],
                        samples_per_node=4000)
        acc = knn_classifier_accuracy(y, labels, k=5)
        rows.add(f"{ds}/line_2d", secs, accuracy=round(acc, 4))


if __name__ == "__main__":
    rows = Rows("fig5_knn_classifier")
    run(rows)
    rows.print_csv()
    rows.save()
