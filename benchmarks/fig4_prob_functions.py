"""Paper Fig 4: probability functions f for P(e_ij=1) = f(||yi-yj||).

Compares f(x) = 1/(1+a x^2) for a in {1, 4, 9} and f(x) = 1/(1+exp(x^2))
by downstream KNN-classifier accuracy.  Claim C3: a=1 (long-tailed,
t-SNE's Student-t argument) wins."""
from __future__ import annotations

import jax

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import build_graph, layout_graph
from repro.core.metrics import knn_classifier_accuracy

N = 4000
KEY = jax.random.key(2)


def run(rows: Rows):
    x, labels = dataset("blobs100", N, KEY)
    base = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                          window=32, perplexity=12.0, samples_per_node=3000,
                          batch_size=4096)
    idx, dist, w, _ = build_graph(x, KEY, cfg=base)
    variants = [("inv_quadratic", 1.0), ("inv_quadratic", 4.0),
                ("inv_quadratic", 9.0), ("exp_quadratic", 1.0)]
    import dataclasses
    for fn, a in variants:
        cfg = dataclasses.replace(base, prob_fn=fn, prob_a=a)
        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg=cfg)
        acc = knn_classifier_accuracy(res.y, labels, k=5)
        label = f"{fn}_a{a:g}" if fn == "inv_quadratic" else fn
        rows.add(label, secs, accuracy=round(acc, 4))


if __name__ == "__main__":
    rows = Rows("fig4_prob_functions")
    run(rows)
    rows.print_csv()
    rows.save()
