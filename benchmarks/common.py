"""Shared benchmark utilities: datasets, timing, CSV rows.

Artifacts are written as ``BENCH_<table>.json`` under
``benchmarks/artifacts/`` (override the directory with the
``BENCH_ARTIFACTS_DIR`` env var — the CI bench-smoke job writes fresh
artifacts next to the checkout and gates them against the committed
baselines; see benchmarks/README.md for the JSON contract).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax

ART = pathlib.Path(os.environ.get(
    "BENCH_ARTIFACTS_DIR",
    pathlib.Path(__file__).resolve().parent / "artifacts"))


def dataset(name: str, n: int, key=None):
    """(x, labels) — synthetic stand-ins shaped like the paper's corpora
    (clustered, high-dim; offline container has no MNIST/Wiki downloads)."""
    from repro.data.synthetic import gaussian_mixture, mnist_like, swiss_roll
    key = jax.random.key(0) if key is None else key
    if name == "blobs100":          # WikiDoc-like: 100-dim clustered
        return gaussian_mixture(key, n, 100, 20, sep=7.0)
    if name == "mnist_like":        # MNIST-like: 784-dim, 10 classes
        return mnist_like(key, n, 784, 10)
    if name == "manifold":          # Isomap-style curved manifold
        return swiss_roll(key, n, 32)
    raise KeyError(name)


def _report_stragglers(watchdog, label: str):
    """One stderr line when timed repeats hit load-spike outliers.

    best-of timing already discards stragglers from the *numbers*; the
    report makes the discard visible so a row measured during a load
    spike is never mistaken for a clean one."""
    if watchdog is not None and watchdog.stragglers:
        import sys
        worst = max(dt for _, dt, _ in watchdog.stragglers)
        med = watchdog.stragglers[-1][2]
        print(f"[bench] {label}: {len(watchdog.stragglers)} straggler "
              f"repeat(s) (worst {worst:.3f}s vs median {med:.3f}s) — "
              f"using best-of, but treat this row with suspicion",
              file=sys.stderr)


def best_of_interleaved(fns, repeats: int):
    """Best-of-``repeats`` per fn, *alternating* fns every round.

    Machine-load drift over tens of seconds is the dominant noise source
    for comparison rows on a shared CPU; back-to-back repeats of one
    config land entirely inside one load regime and make cross-config
    ratios meaningless.  Interleaving spreads every config across the
    same load windows, so the per-config minima are comparable.  Each fn
    gets one untimed warmup call first (compile time never lands in a
    number).  A per-fn :class:`~repro.runtime.fault_tolerance.Watchdog`
    flags outlier repeats (load spikes) on stderr.  Returns
    (outs, best_seconds), one entry per fn.
    """
    from repro.runtime.fault_tolerance import Watchdog
    outs = [jax.block_until_ready(f()) for f in fns]   # warmup / compile
    best = [float("inf")] * len(fns)
    dogs = [Watchdog() for _ in fns]
    for r in range(repeats):
        for f_i, f in enumerate(fns):
            t0 = time.time()
            outs[f_i] = jax.block_until_ready(f())
            dt = time.time() - t0
            best[f_i] = min(best[f_i], dt)
            dogs[f_i].observe(r, dt)
    for f_i, dog in enumerate(dogs):
        _report_stragglers(dog, f"fn[{f_i}]")
    return outs, best


def timed(fn, *args, repeats: int = 1, warmup: int = 1, **kw):
    """(result, best_seconds) with jax block_until_ready.

    ``warmup`` untimed calls run first so jit compilation never lands in
    the timed repeats — with the old behaviour every ``repeats=1`` number
    (all of fig2–fig7) measured compile time, not runtime.  Pass
    ``warmup=0`` only when compilation is the thing being measured.
    A :class:`~repro.runtime.fault_tolerance.Watchdog` over the repeats
    reports load-spike outliers on stderr.
    """
    from repro.runtime.fault_tolerance import Watchdog
    out = None
    for _ in range(max(0, warmup)):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    best = float("inf")
    dog = Watchdog()
    for r in range(repeats):
        t0 = time.time()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        dt = time.time() - t0
        best = min(best, dt)
        dog.observe(r, dt)
    _report_stragglers(dog, getattr(fn, "__name__", "timed"))
    return out, best


class Rows:
    """Collect 'name,us_per_call,derived' CSV rows (run.py contract)."""

    def __init__(self, table: str):
        self.table = table
        self.rows = []

    def add(self, name: str, seconds: float, **derived):
        self.rows.append((f"{self.table}/{name}", seconds * 1e6, derived))

    def print_csv(self):
        for name, us, derived in self.rows:
            d = json.dumps(derived, sort_keys=True) if derived else ""
            print(f"{name},{us:.1f},{d}")

    def save(self):
        ART.mkdir(parents=True, exist_ok=True)
        path = ART / f"BENCH_{self.table}.json"
        path.write_text(json.dumps(
            [dict(name=n, us=u, **d) for n, u, d in self.rows], indent=1))
        return path
