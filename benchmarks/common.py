"""Shared benchmark utilities: datasets, timing, CSV rows.

Artifacts are written as ``BENCH_<table>.json`` under
``benchmarks/artifacts/`` (override the directory with the
``BENCH_ARTIFACTS_DIR`` env var — the CI bench-smoke job writes fresh
artifacts next to the checkout and gates them against the committed
baselines; see benchmarks/README.md for the JSON contract).
"""
from __future__ import annotations

import json
import os
import pathlib

import jax

# timing helpers live in the installed package now (the autotuner shares
# them); re-exported here so every bench module keeps its import path
from repro.runtime.timing import (  # noqa: F401
    AUTOTUNE_REPEATS,
    _report_stragglers,
    best_of_interleaved,
    timed,
)

ART = pathlib.Path(os.environ.get(
    "BENCH_ARTIFACTS_DIR",
    pathlib.Path(__file__).resolve().parent / "artifacts"))


def dataset(name: str, n: int, key=None):
    """(x, labels) — synthetic stand-ins shaped like the paper's corpora
    (clustered, high-dim; offline container has no MNIST/Wiki downloads)."""
    from repro.data.synthetic import gaussian_mixture, mnist_like, swiss_roll
    key = jax.random.key(0) if key is None else key
    if name == "blobs100":          # WikiDoc-like: 100-dim clustered
        return gaussian_mixture(key, n, 100, 20, sep=7.0)
    if name == "mnist_like":        # MNIST-like: 784-dim, 10 classes
        return mnist_like(key, n, 784, 10)
    if name == "manifold":          # Isomap-style curved manifold
        return swiss_roll(key, n, 32)
    raise KeyError(name)


class Rows:
    """Collect 'name,us_per_call,derived' CSV rows (run.py contract)."""

    def __init__(self, table: str):
        self.table = table
        self.rows = []

    def add(self, name: str, seconds: float, **derived):
        self.rows.append((f"{self.table}/{name}", seconds * 1e6, derived))

    def print_csv(self):
        for name, us, derived in self.rows:
            d = json.dumps(derived, sort_keys=True) if derived else ""
            print(f"{name},{us:.1f},{d}")

    def save(self, table: str | None = None):
        # ``table`` overrides the artifact FILE name only — row names keep
        # ``self.table`` so a companion artifact (e.g. the autotune bench's
        # hardcoded-config baseline) matches the main table row-for-row
        # under check_regression's name-based pairing
        ART.mkdir(parents=True, exist_ok=True)
        path = ART / f"BENCH_{table or self.table}.json"
        path.write_text(json.dumps(
            [dict(name=n, us=u, **d) for n, u, d in self.rows], indent=1))
        return path
