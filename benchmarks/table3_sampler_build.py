"""Sampler-table construction benchmark: device vs host alias building.

The stage-1 -> stage-2 boundary builds alias tables over all E = N*K
directed edges.  The host path is Vose's method as a Python loop — O(E)
but single-core and interpreter-bound, minutes at the paper's E = 150M —
while the device path (``core/sampler.py::build_alias_device``) is one
jitted partition/prefix-sum/searchsorted computation (no sort — O(E)
data movement plus O(E log E) binary searches) with zero host round
trips.  These rows record that boundary's cost the
same way the ``layout_*`` rows record stepping cost.

Rows: ``sampler_build_n{2000,20000,100000}`` at K=50 (E = 100k..5M).
``us`` is the *device* build (best-of-5, untimed warmup excludes
compile); ``us_per_edge`` is the metric the CI regression gate consumes
(``check_regression --rows sampler_build``, 2x); ``host_us`` /
``speedup_vs_host`` record the Vose oracle on the identical weights.

``--tiny`` runs only N=2000 with the exact full-run config (same row
name, so the committed baseline stays valid for both modes — the CI
bench-smoke mode).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import Rows, timed
from repro.core import sampler as sampler_lib

NS = (2_000, 20_000, 100_000)
K = 50          # edges per node: E = N*K spans 100k .. 5M


def _graph(n: int, k: int = K, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    w = rng.uniform(0.1, 2.0, (n, k)).astype(np.float32)
    return idx, w


def sampler_rows(rows: Rows, ns=NS):
    for n in ns:
        idx, w = _graph(n)
        e_total = idx.size

        def build_device():
            es = sampler_lib.build_edge_sampler(idx, w, impl="device")
            jax.block_until_ready(es.threshold)
            return es

        # device: best-of-5 after an untimed warmup (compile excluded)
        _, dev_s = timed(build_device, repeats=5)
        # host Vose: single timed pass, no warmup (nothing compiles, and
        # the Python loop at E=5M is too slow to repeat)
        _, host_s = timed(sampler_lib.build_edge_sampler, idx, w,
                          impl="host", repeats=1, warmup=0)
        rows.add(f"sampler_build_n{n}", dev_s,
                 edges=e_total,
                 us_per_edge=round(dev_s * 1e6 / e_total, 6),
                 host_us=round(host_s * 1e6, 1),
                 host_us_per_edge=round(host_s * 1e6 / e_total, 6),
                 speedup_vs_host=round(host_s / max(dev_s, 1e-9), 2))


def run(rows: Rows):
    sampler_rows(rows)


def run_tiny(rows: Rows):
    """CI bench-smoke mode: N=2000 only, identical config to the full
    run's n2000 row (the committed baseline covers both modes)."""
    sampler_rows(rows, ns=(2_000,))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="N=2000 row only (CI smoke mode)")
    args = ap.parse_args()
    rows = Rows("table3_sampler_build")
    (run_tiny if args.tiny else run)(rows)
    rows.print_csv()
    rows.save()
