"""Paper Fig 3: KNN recall vs neighbor-exploring iterations, from initial
graphs of different accuracy (built with different numbers of trees).

Claim C1: recall climbs to ~1.0 within <=3 iterations even from a weak
initial graph."""
from __future__ import annotations

import jax

from benchmarks.common import Rows, dataset, timed
from repro.core.knn import brute_force_knn, forest_knn, knn_recall
from repro.core.neighbor_explore import neighbor_explore

N = 6000
K = 20
KEY = jax.random.key(1)


def run(rows: Rows):
    x, _ = dataset("blobs100", N, KEY)
    true_idx, _ = brute_force_knn(x, K)
    for nt in (1, 2, 8):
        idx, dist = forest_knn(x, KEY, n_trees=nt, depth=7, k=K, window=32)
        r0 = knn_recall(idx, true_idx)
        rows.add(f"init_nt{nt}_iter0", 0.0, recall=round(r0, 4), trees=nt,
                 iters=0)
        for it in (1, 2, 3):
            (idx, dist), secs = timed(
                neighbor_explore, x, idx, dist, iters=1,
                key=jax.random.fold_in(KEY, it))
            r = knn_recall(idx, true_idx)
            rows.add(f"init_nt{nt}_iter{it}", secs, recall=round(r, 4),
                     trees=nt, iters=it)


if __name__ == "__main__":
    rows = Rows("fig3_neighbor_exploring")
    run(rows)
    rows.print_csv()
    rows.save()
