"""Benchmark regression gate for the CI bench-smoke job.

Compares a freshly produced ``BENCH_*.json`` artifact against the committed
baseline under ``benchmarks/artifacts/`` and fails (exit 1) if the chosen
metric regressed by more than ``--factor`` on any row present in both files
(rows are matched by ``name``).  Rows missing the metric are skipped; zero
overlapping rows is an error so a silent row rename cannot disable the gate.

Usage (what .github/workflows/ci.yml runs):

    python benchmarks/check_regression.py \
        --fresh bench-fresh/BENCH_table2_layout_time.json \
        --baseline benchmarks/artifacts/BENCH_table2_layout_time.json \
        --metric us_per_edge_sample --factor 2.0
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def load_rows(path: str) -> dict[str, dict]:
    rows = json.loads(pathlib.Path(path).read_text())
    return {r["name"]: r for r in rows}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True, help="artifact from this run")
    ap.add_argument("--baseline", required=True, help="committed baseline")
    ap.add_argument("--metric", default="us_per_edge_sample")
    ap.add_argument("--factor", type=float, default=2.0)
    ap.add_argument(
        "--rows",
        default="",
        help="only gate rows whose name contains one of these comma-"
        "separated substrings (e.g. 'layout_scan,layout_fused' to gate "
        "both engine paths while skipping the dispatch-bound loop rows, "
        "whose wall-clock is the most machine-sensitive)",
    )
    args = ap.parse_args()

    fresh = load_rows(args.fresh)
    baseline = load_rows(args.baseline)
    row_filters = [s for s in args.rows.split(",") if s]

    compared, failures = 0, []
    for name, base_row in sorted(baseline.items()):
        if row_filters and not any(s in name for s in row_filters):
            continue
        if args.metric not in base_row or name not in fresh:
            continue
        if args.metric not in fresh[name]:
            continue
        base_v = float(base_row[args.metric])
        fresh_v = float(fresh[name][args.metric])
        if base_v <= 0:
            continue
        ratio = fresh_v / base_v
        compared += 1
        verdict = "REGRESSED" if ratio > args.factor else "ok"
        print(
            f"{name}: {args.metric} baseline={base_v:.4f} "
            f"fresh={fresh_v:.4f} ratio={ratio:.2f}x [{verdict}]"
        )
        if ratio > args.factor:
            failures.append((name, ratio))

    if compared == 0:
        print(
            f"ERROR: no rows with metric '{args.metric}' overlap between "
            f"{args.fresh} and {args.baseline} — the gate compared nothing",
            file=sys.stderr,
        )
        return 1
    if failures:
        worst = max(r for _, r in failures)
        print(
            f"FAIL: {len(failures)}/{compared} rows regressed more than "
            f"{args.factor}x (worst {worst:.2f}x)",
            file=sys.stderr,
        )
        return 1
    print(f"PASS: {compared} rows within {args.factor}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
