"""Projection-serving latency: the continuous-batching engine under load.

Drives ``launch/serve_projection.ProjectionEngine`` — the fixed-slot
transform server — at 1k/10k/100k concurrent requests against a resident
corpus and reports queries/sec plus p50/p99 end-to-end latency (submit ->
retire, queue wait included).  The corpus is a synthetic fitted model
(random points + random layout + uniform negative sampler): serving
throughput measures the admit/lockstep/retire machinery and the fused
frozen-corpus kernel, not layout quality, so a converged fit would only
add minutes of fixture time without changing what the rows measure.

Row contract:

* ``queries_per_sec`` — total drain throughput (Q / wall seconds).
* ``p50_ms`` / ``p99_ms`` — end-to-end request latency percentiles.  At
  full concurrency most of p50 is queue wait (a request admitted in wave
  w waits ~w * transform_steps engine steps), so this is the serving
  number a capacity planner wants, not the per-step kernel time.

``p50_ms`` of the ``serve_q1k`` row is the CI bench-smoke gate metric
(benchmarks/check_regression.py, 2x factor).  ``--tiny`` runs exactly
that row with the full-run config, so the committed baseline stays valid.
"""
from __future__ import annotations

import argparse
import time
from types import SimpleNamespace

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs.largevis_default import LargeVisConfig
from repro.launch.serve_projection import ProjectionEngine, ProjectRequest

KEY = jax.random.key(11)

# per-row grid: concurrency -> (corpus N, slots).  d and the transform
# config are shared; slots scale with load the way a deployment would.
GRID = (
    ("serve_q1k", 1_000, 4_000, 256),
    ("serve_q10k", 10_000, 10_000, 1_024),
    ("serve_q100k", 100_000, 10_000, 2_048),
)
DIM = 32
CFG = LargeVisConfig(n_neighbors=10, transform_steps=16)


def _synthetic_model(n: int, d: int, seed: int = 0):
    """Fitted-carrier stand-in: corpus + frozen layout, uniform noise."""
    kx, ky = jax.random.split(jax.random.key(seed))
    return SimpleNamespace(
        x=jax.random.normal(kx, (n, d), np.float32),
        y=jax.random.normal(ky, (n, 2), np.float32),
        neg_sampler=None,  # engine falls back to the uniform node sampler
        cfg=None,
    )


def _serve_row(rows: Rows, name: str, *, q: int, n: int, slots: int):
    model = _synthetic_model(n, DIM)
    xq = np.asarray(jax.random.normal(KEY, (q, DIM)), np.float32)

    # warmup engine at identical shapes: triggers both engine compiles
    # (padded prefill block + lockstep step) so the timed drain below
    # measures serving, not jit
    warm = ProjectionEngine(model, slots=slots, cfg=CFG, seed=7)
    warm.submit(ProjectRequest(rid=-1, x=xq[0]))
    warm.run()

    eng = ProjectionEngine(model, slots=slots, cfg=CFG, seed=7)
    t0 = time.perf_counter()
    for i in range(q):
        eng.submit(ProjectRequest(rid=i, x=xq[i]))
    n_steps = eng.run()
    secs = time.perf_counter() - t0

    assert len(eng.completed) == q, (len(eng.completed), q)
    lat_ms = np.array([r.latency for r in eng.completed]) * 1e3
    rows.add(name, secs,
             queries=q, slots=slots, corpus_n=n, engine_steps=n_steps,
             queries_per_sec=round(q / max(secs, 1e-9), 1),
             p50_ms=round(float(np.percentile(lat_ms, 50)), 3),
             p99_ms=round(float(np.percentile(lat_ms, 99)), 3))


def run(rows: Rows):
    for name, q, n, slots in GRID:
        _serve_row(rows, name, q=q, n=n, slots=slots)


def run_tiny(rows: Rows):
    """CI bench-smoke mode: the serve_q1k row only, with the exact
    full-run config, so the committed baseline stays valid."""
    name, q, n, slots = GRID[0]
    _serve_row(rows, name, q=q, n=n, slots=slots)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="serve_q1k row only (CI smoke mode)")
    args = ap.parse_args()
    rows = Rows("serve_latency")
    (run_tiny if args.tiny else run)(rows)
    rows.print_csv()
    rows.save()
