"""Paper Fig 7: sensitivity to the number of negatives (M) and the total
edge-sample budget (T).  Claim C4b: quality is stable once M >= 5 and T is
large enough — the 'defaults work everywhere' property."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import build_graph, layout_graph
from repro.core.metrics import knn_classifier_accuracy

N = 4000
KEY = jax.random.key(6)


def run(rows: Rows):
    x, labels = dataset("blobs100", N, KEY)
    base = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                          window=32, perplexity=12.0, samples_per_node=3000,
                          batch_size=4096)
    idx, dist, w, _ = build_graph(x, KEY, cfg=base)

    for m in (1, 3, 5, 7):
        cfg = dataclasses.replace(base, n_negatives=m)
        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg=cfg)
        acc = knn_classifier_accuracy(res.y, labels, k=5)
        rows.add(f"negatives_m{m}", secs, accuracy=round(acc, 4))

    for spn in (500, 1500, 3000, 6000):
        cfg = dataclasses.replace(base, samples_per_node=spn)
        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg=cfg)
        acc = knn_classifier_accuracy(res.y, labels, k=5)
        rows.add(f"samples_t{spn}", secs, accuracy=round(acc, 4))


if __name__ == "__main__":
    rows = Rows("fig7_sensitivity")
    run(rows)
    rows.print_csv()
    rows.save()
