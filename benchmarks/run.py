"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract and saves
JSON artifacts under benchmarks/artifacts/.  ``--only fig2`` runs one
table.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Rows

TABLES = [
    ("fig2_knn_construction", "benchmarks.fig2_knn_construction"),
    ("fig3_neighbor_exploring", "benchmarks.fig3_neighbor_exploring"),
    ("fig4_prob_functions", "benchmarks.fig4_prob_functions"),
    ("fig5_knn_classifier", "benchmarks.fig5_knn_classifier"),
    ("table2_layout_time", "benchmarks.table2_layout_time"),
    ("table3_sampler_build", "benchmarks.table3_sampler_build"),
    ("fig6_scaling", "benchmarks.fig6_scaling"),
    ("fig7_sensitivity", "benchmarks.fig7_sensitivity"),
    ("serve_latency", "benchmarks.serve_latency"),
    ("autotune", "benchmarks.autotune_sweep"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    # declared-environment preset (flag hygiene) before any kernel compiles
    from repro.runtime import platform
    platform.apply_bench_preset()
    import importlib
    t_all = time.time()
    failures = []
    for name, modpath in TABLES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        rows = Rows(name)
        try:
            mod = importlib.import_module(modpath)
            mod.run(rows)
            rows.print_csv()
            rows.save()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", file=sys.stderr)
    print(f"# total {time.time()-t_all:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
