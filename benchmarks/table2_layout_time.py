"""Paper Table 2: graph-visualization wall time, LargeVis vs t-SNE —
plus the layout-engine dispatch benchmark (per-step loop vs scan-fused).

At container scale the paper comparison is per-(edge-sample|gradient-
iteration) throughput plus total wall time on equal sample budgets; the
paper's headline (LargeVis ~7x faster at millions of nodes) comes from
O(N) vs O(N log N) — fig6 measures the scaling directly.

The engine rows (``layout_loop_n*`` / ``layout_scan_n*``) time the SAME
sample budget through the per-step Python driver (one device dispatch
per SGD step) and the scan-fused engine (``core/layout_engine.py``,
``steps_per_dispatch`` steps per dispatch).  They run in the small-batch
regime (batch 256) where dispatch overhead dominates — the regime the
paper's linear-time layout optimizes — on a synthetic random KNN graph,
since the engine benchmark measures stepping, not graph quality.  The
``us_per_edge_sample`` field of the scan rows is the perf-trajectory
metric the CI bench-smoke gate regresses against
(benchmarks/check_regression.py).

``--tiny`` runs only the N=2000 engine comparison (the CI smoke mode).
"""
from __future__ import annotations

import argparse
import dataclasses
import shutil
import tempfile

import jax
import numpy as np

from benchmarks.common import Rows, best_of_interleaved, dataset, timed
from repro.configs.largevis_default import CheckpointConfig, LargeVisConfig
from repro.core import sampler as sampler_lib
from repro.core.layout import run_layout

KEY = jax.random.key(4)

# engine-comparison grid: N -> samples_per_node, at batch 256 (dispatch-
# bound small-batch regime; equal budgets for both drivers)
ENGINE_NS = (2_000, 20_000, 100_000)
ENGINE_SPN = {2_000: 256, 20_000: 64, 100_000: 16}
ENGINE_BATCH = 256
ENGINE_STEPS_PER_DISPATCH = 100


def _synthetic_graph_samplers(n: int, k: int = 10, seed: int = 0):
    """Random directed KNN graph + weights — stage-2 stepping fixture."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (n, k)).astype(np.float32)
    es = sampler_lib.build_edge_sampler(idx, w)
    ns = sampler_lib.build_negative_sampler(idx, w)
    return es, ns


def engine_rows(rows: Rows, ns=ENGINE_NS):
    """Per-step loop vs scan-fused engine vs fused edge-step kernel, on
    equal sample budgets.

    The loop/scan rows pin ``fused_step=False`` so they keep measuring the
    split gather/grad/scatter path their committed baselines measured; the
    ``layout_fused_n*`` rows run the same scanned budget through the
    fully-fused edge-step kernel (``kernels/largevis_step.py``) —
    ``speedup_vs_split`` is the kernel's win over the split scan.

    The ``layout_ckpt_n*`` rows rerun the scan config with crash-safe
    checkpointing at the DEFAULT cadence (an atomic keep-2 save every
    ``CheckpointConfig.every_chunks`` dispatches; ``resume=False`` so
    each timed repeat does the full work).  Saves take the production
    async-writer path (on-device snapshot + off-thread persist), so
    ``overhead_vs_scan`` — the resume-insurance price — must stay
    ~1.0x, i.e. <5% (benchmarks/README.md; the every-dispatch stress
    cadence is exercised by the chaos tests, not timed here).
    """
    for n in ns:
        es, neg = _synthetic_graph_samplers(n)
        base = LargeVisConfig(samples_per_node=ENGINE_SPN[n],
                              batch_size=ENGINE_BATCH)
        cfg_loop = dataclasses.replace(base, steps_per_dispatch=1,
                                       fused_step=False)
        cfg_scan = dataclasses.replace(
            base, steps_per_dispatch=ENGINE_STEPS_PER_DISPATCH,
            fused_step=False)
        cfg_fused = dataclasses.replace(cfg_scan, fused_step=True)
        ckpt_dir = tempfile.mkdtemp(prefix=f"bench_ckpt_n{n}_")
        cfg_ckpt = dataclasses.replace(
            base, steps_per_dispatch=ENGINE_STEPS_PER_DISPATCH,
            fused_step=False,
            checkpoint=CheckpointConfig(directory=ckpt_dir, keep=2,
                                        resume=False))

        def run_blocked(cfg):
            # LayoutResult is not a pytree, so block on .y explicitly —
            # otherwise async dispatch escapes the timer
            r = run_layout(KEY, es, neg, n, cfg)
            jax.block_until_ready(r.y)
            return r

        try:
            # 8 interleaved rounds: the ckpt-vs-scan ratio is a few percent,
            # which 3 rounds cannot resolve on a noisy shared box — the
            # best-of min only converges once every fn has hit a quiet
            # scheduling window
            ((r_loop, r_scan, r_fused, r_ckpt),
             (secs_loop, secs_scan, secs_fused, secs_ckpt)) = (
                best_of_interleaved(
                    [lambda: run_blocked(cfg_loop),
                     lambda: run_blocked(cfg_scan),
                     lambda: run_blocked(cfg_fused),
                     lambda: run_blocked(cfg_ckpt)], repeats=8))
        finally:
            shutil.rmtree(ckpt_dir, ignore_errors=True)
        rows.add(f"layout_loop_n{n}", secs_loop,
                 steps=r_loop.steps, edge_samples=r_loop.edge_samples,
                 dispatches=r_loop.steps,
                 us_per_edge_sample=round(
                     secs_loop * 1e6 / r_loop.edge_samples, 4))
        rows.add(f"layout_scan_n{n}", secs_scan,
                 steps=r_scan.steps, edge_samples=r_scan.edge_samples,
                 dispatches=-(-r_scan.steps // ENGINE_STEPS_PER_DISPATCH),
                 us_per_edge_sample=round(
                     secs_scan * 1e6 / r_scan.edge_samples, 4),
                 speedup_vs_loop=round(secs_loop / max(secs_scan, 1e-9), 2))
        rows.add(f"layout_fused_n{n}", secs_fused,
                 steps=r_fused.steps, edge_samples=r_fused.edge_samples,
                 dispatches=-(-r_fused.steps // ENGINE_STEPS_PER_DISPATCH),
                 us_per_edge_sample=round(
                     secs_fused * 1e6 / r_fused.edge_samples, 4),
                 speedup_vs_split=round(secs_scan / max(secs_fused, 1e-9),
                                        2))
        rows.add(f"layout_ckpt_n{n}", secs_ckpt,
                 steps=r_ckpt.steps, edge_samples=r_ckpt.edge_samples,
                 dispatches=-(-r_ckpt.steps // ENGINE_STEPS_PER_DISPATCH),
                 us_per_edge_sample=round(
                     secs_ckpt * 1e6 / r_ckpt.edge_samples, 4),
                 overhead_vs_scan=round(secs_ckpt / max(secs_scan, 1e-9),
                                        3))


def reshard_resume_rows(rows: Rows, ns=ENGINE_NS):
    """Elastic-restore cost: resume a P=4 checkpoint on a 1-device mesh.

    Writes stage checkpoints (``graph`` + ``weights``, global arrays)
    tagged as written by a 4-shard mesh — the tag is metadata, the
    arrays are host-gathered globals, so no 4-device mesh is needed to
    produce them — then times the full topology-crossing resume path:
    ``StageCheckpointer.restore`` (CRC-verified load + re-shard onto the
    current mesh) for both stages plus the ``build_samplers_sharded``
    alias-table rebuild, which a resuming process always redoes (sharded
    tables are P-dependent and never checkpointed).  The ``us`` wall
    time is the CI-gated metric: the price of coming back from a mesh
    shrink must stay within 2x of the committed baseline (README
    "Robustness" quotes this row as the resume-cost contract)."""
    import tempfile

    from repro.checkpoint.largevis_state import StageCheckpointer
    from repro.launch.mesh import make_data_mesh

    k = 10
    for n in ns:
        rng = np.random.default_rng(0)
        idx = rng.integers(0, n, (n, k)).astype(np.int32)
        dist = rng.uniform(0.1, 2.0, (n, k)).astype(np.float32)
        w = rng.uniform(0.5, 1.5, (n, k)).astype(np.float32)
        ckdir = tempfile.mkdtemp(prefix=f"bench_reshard_n{n}_")
        ckpt = StageCheckpointer(
            CheckpointConfig(directory=ckdir, resume=True), "bench")
        topo = {"topology": {"distributed": True, "data_shards": 4,
                             "n_rows": int(n)}}
        ckpt.save("graph", {"idx": idx, "dist": dist}, extra=topo)
        ckpt.save("weights", {"w": w}, extra=topo)
        mesh = make_data_mesh(1)

        def restore_rebuild():
            g, _, _ = ckpt.restore("graph", mesh=mesh)
            wt, _, _ = ckpt.restore("weights", mesh=mesh)
            es, neg = sampler_lib.build_samplers_sharded(
                np.asarray(g["idx"]), np.asarray(wt["w"]), mesh=mesh)
            jax.block_until_ready((es.threshold, neg.threshold))
            return es

        try:
            _, (secs,) = best_of_interleaved([restore_rebuild], repeats=8)
        finally:
            shutil.rmtree(ckdir, ignore_errors=True)
        rows.add(f"reshard_resume_n{n // 1000}k", secs,
                 n_rows=n, n_edges=n * k, from_shards=4, to_shards=1,
                 us_per_row=round(secs * 1e6 / n, 4))


def run(rows: Rows):
    from repro.core.baselines.tsne import tsne_layout
    from repro.core.largevis import build_graph, layout_graph
    for n in (1500, 3000):
        x, _ = dataset("blobs100", n, KEY)
        cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=1,
                             window=32, perplexity=12.0,
                             samples_per_node=3000, batch_size=4096)
        idx, dist, w, _ = build_graph(x, KEY, cfg=cfg)
        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg=cfg)
        rows.add(f"largevis_n{n}", secs,
                 edge_samples=res.edge_samples,
                 samples_per_sec=round(res.edge_samples / max(secs, 1e-9)))
        (y, _), secs_t = timed(tsne_layout, idx, w, n_iter=250, key=KEY)
        rows.add(f"tsne_n{n}", secs_t, iters=250,
                 sec_per_iter=round(secs_t / 250, 5),
                 speedup_largevis=round(secs_t / max(secs, 1e-9), 2))
    engine_rows(rows)
    reshard_resume_rows(rows)


def run_tiny(rows: Rows):
    """CI bench-smoke mode: N=2000 engine comparison only (same config as
    the full run's n2000 rows, so the committed baseline stays valid),
    plus the N=2000 elastic-restore row for the reshard-resume gate."""
    engine_rows(rows, ns=(2_000,))
    reshard_resume_rows(rows, ns=(2_000,))


def run_engine(rows: Rows):
    """Engine rows only, at every N — regenerates the committed baseline
    (the paper's largevis-vs-tsne rows are not part of the CI gate)."""
    engine_rows(rows)
    reshard_resume_rows(rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="engine comparison at N=2000 only (CI smoke mode)")
    ap.add_argument("--engine", action="store_true",
                    help="engine rows at all N (baseline regeneration)")
    args = ap.parse_args()
    rows = Rows("table2_layout_time")
    (run_tiny if args.tiny else run_engine if args.engine else run)(rows)
    rows.print_csv()
    rows.save()
