"""Paper Table 2: graph-visualization wall time, LargeVis vs t-SNE.

At container scale the comparison is per-(edge-sample|gradient-iteration)
throughput plus total wall time on equal sample budgets; the paper's
headline (LargeVis ~7x faster at millions of nodes) comes from O(N) vs
O(N log N) — fig6 measures the scaling directly."""
from __future__ import annotations

import jax

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.baselines.tsne import tsne_layout
from repro.core.largevis import build_graph, layout_graph

KEY = jax.random.key(4)


def run(rows: Rows):
    for n in (1500, 3000):
        x, _ = dataset("blobs100", n, KEY)
        cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=1,
                             window=32, perplexity=12.0,
                             samples_per_node=3000, batch_size=4096)
        idx, dist, w, _ = build_graph(x, KEY, cfg)
        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg)
        rows.add(f"largevis_n{n}", secs,
                 edge_samples=res.edge_samples,
                 samples_per_sec=round(res.edge_samples / max(secs, 1e-9)))
        (y, _), secs_t = timed(tsne_layout, idx, w, n_iter=250, key=KEY)
        rows.add(f"tsne_n{n}", secs_t, iters=250,
                 sec_per_iter=round(secs_t / 250, 5),
                 speedup_largevis=round(secs_t / max(secs, 1e-9), 2))


if __name__ == "__main__":
    rows = Rows("table2_layout_time")
    run(rows)
    rows.print_csv()
    rows.save()
