"""Paper Fig 6: time + quality vs data size, to the paper's N=1M.

Claim C5: the whole LargeVis procedure is O(N) — normalized layout cost
(``us_per_edge_sample``, the CI-gated lower-is-better metric; its
reciprocal ``samples_per_sec`` must stay flat within 2x) does not grow
with N, and the graph-preparation stages (calibration, symmetrization,
sampler build — all sharded over the data mesh since PR 6) scale
linearly.  t-SNE's exact per-iteration cost (O(N^2), the paper's Fig 6
contrast) is reported at small N only.

Stage 1 runs the paper's *linear* RP-forest + neighbor-exploring KNN
(``knn_distributed=False``): the sharded ring pass keeps a fixed
per-device memory footprint but its masked distance fold is O(N^2 d/P)
*compute*, which is the wrong algorithm for an O(N) sweep unless the
device count scales with N (fig2 ``--devices`` benchmarks the ring on
a real mesh).  Everything downstream of the KNN graph — calibration,
symmetrization, per-shard samplers, local-SGD layout — runs the
distributed drivers.

Every N runs the SAME total edge-sample budget (T = spn * N held
constant), so edge-samples/sec across rows compares equal work per
sample at different N — the paper's definition of "linear in N".

``--devices P`` exposes P host CPU devices (parsed before any
backend-touching import) and drives the identical sharded pipeline on
a real P-way mesh; the default runs it on one device, where the
sharded stages are bitwise the single-device path.

``--tiny`` is the CI bench-smoke mode: a reduced N sweep with its own
table name (``fig6_scaling_tiny``) since tiny timings are not
comparable to the full sweep — the gate contract is documented in
benchmarks/README.md.
"""
from __future__ import annotations

import argparse
import os

_ARGS = None
if __name__ == "__main__":
    _ap = argparse.ArgumentParser(description=__doc__)
    _ap.add_argument("--devices", type=int, default=0,
                     help="expose this many host CPU devices for the "
                          "data mesh (e.g. 4)")
    _ap.add_argument("--tiny", action="store_true",
                     help="CI bench-smoke mode: small N sweep, separate "
                          "fig6_scaling_tiny table")
    _ARGS = _ap.parse_args()
    if _ARGS.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_ARGS.devices}")

import jax

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.baselines.tsne import tsne_layout
from repro.core.largevis import build_graph, layout_graph
from repro.core.metrics import knn_classifier_accuracy

KEY = jax.random.key(5)

# one total edge-sample budget for the whole sweep: spn = TOTAL // n
TOTAL_SAMPLES = 4_000_000
TINY_TOTAL = 400_000


def _cfg(n: int, total: int) -> LargeVisConfig:
    return LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=1,
                          window=32, perplexity=12.0,
                          samples_per_node=max(1, total // n),
                          batch_size=4096, sync_every=8, distributed=True,
                          knn_distributed=False)


def run(rows: Rows, *, ns=(10_000, 100_000, 1_000_000),
        total=TOTAL_SAMPLES, accuracy_max_n=10_000, tsne_ns=(2000, 4000)):
    for n in ns:
        x, labels = dataset("blobs100", n, KEY)
        cfg = _cfg(n, total)
        idx, dist, w, t_graph = build_graph(x, KEY, cfg=cfg)
        jax.block_until_ready(w)
        # warmup=1 (timed default): the measured call excludes compile.
        # The gated metric derives from the stage-split layout_s, not the
        # whole-call secs — the one-time O(E) alias build (sampler_s,
        # recorded alongside) would otherwise smear into the per-sample
        # number exactly where it matters (large N, fixed total budget)
        (res, t_stage), secs = timed(layout_graph, idx, w, KEY, cfg=cfg)
        layout_s = t_stage["layout_s"]
        derived = dict(
            samples_per_sec=round(res.edge_samples / max(layout_s, 1e-9)),
            us_per_edge_sample=round(layout_s * 1e6 / res.edge_samples, 5),
            edge_samples=res.edge_samples,
            knn_s=round(t_graph["knn_s"], 3),
            weights_s=round(t_graph["weights_s"], 3),
            sampler_s=round(t_stage["sampler_s"], 3),
            layout_s=round(layout_s, 3),
        )
        if n <= accuracy_max_n:
            derived["accuracy"] = round(
                knn_classifier_accuracy(res.y, labels, k=5), 4)
        rows.add(f"largevis_n{n}", secs, **derived)
    for n in tsne_ns:
        x, _ = dataset("blobs100", n, KEY)
        cfg = _cfg(n, total)
        idx, dist, w, _ = build_graph(x, KEY, cfg=cfg)
        (y, _), secs_t = timed(tsne_layout, idx, w, n_iter=100, key=KEY)
        rows.add(f"tsne_n{n}", secs_t, sec_per_iter=round(secs_t / 100, 5))


def run_tiny(rows: Rows):
    """CI bench-smoke: same pipeline and equal-budget structure at small
    N.  Must be given a ``Rows("fig6_scaling_tiny")`` — tiny timings are
    not comparable to the full sweep, and row names are matched across
    runs (the gate compares ``us_per_edge_sample`` on ``largevis_n*``
    rows at 2x against the committed tiny baseline)."""
    run(rows, ns=(2000, 8000), total=TINY_TOTAL, accuracy_max_n=2000,
        tsne_ns=())


if __name__ == "__main__":
    if _ARGS.tiny:
        rows = Rows("fig6_scaling_tiny")
        run_tiny(rows)
    else:
        rows = Rows("fig6_scaling")
        run(rows)
    rows.print_csv()
    rows.save()
