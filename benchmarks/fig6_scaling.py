"""Paper Fig 6: time + quality vs data size.

Claim C5: LargeVis layout cost is O(N) — edge-samples/sec stays flat as N
grows (T ∝ N total) — while t-SNE's per-iteration cost grows superlinearly
(O(N log N) Barnes-Hut; O(N^2) exact as here)."""
from __future__ import annotations

import jax

from benchmarks.common import Rows, dataset, timed
from repro.configs.largevis_default import LargeVisConfig
from repro.core.baselines.tsne import tsne_layout
from repro.core.largevis import build_graph, layout_graph
from repro.core.metrics import knn_classifier_accuracy

KEY = jax.random.key(5)


def run(rows: Rows):
    for n in (1000, 2000, 4000, 8000):
        x, labels = dataset("blobs100", n, KEY)
        cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=1,
                             window=32, perplexity=12.0,
                             samples_per_node=2000, batch_size=4096)
        idx, dist, w, _ = build_graph(x, KEY, cfg)
        (res, _), secs = timed(layout_graph, idx, w, KEY, cfg)
        acc = knn_classifier_accuracy(res.y, labels, k=5)
        rows.add(f"largevis_n{n}", secs, accuracy=round(acc, 4),
                 samples_per_sec=round(res.edge_samples / max(secs, 1e-9)))
        if n <= 4000:      # exact t-SNE O(N^2) budget
            (y, _), secs_t = timed(tsne_layout, idx, w, n_iter=100, key=KEY)
            rows.add(f"tsne_n{n}", secs_t,
                     sec_per_iter=round(secs_t / 100, 5))


if __name__ == "__main__":
    rows = Rows("fig6_scaling")
    run(rows)
    rows.print_csv()
    rows.save()
