"""Per-kernel roofline analysis for THIS repo's kernels.

The previous version of this module analyzed a transformer dry-run
pipeline that no longer matches the codebase.  This one answers the
question the autotune bench needs: for each LargeVis kernel dispatch,
what fraction of the machine's roofline does the achieved time reach?

    fraction = bound_seconds / achieved_seconds          (1.0 = at roof)
    bound_seconds = max(flops / peak_flops, bytes / mem_bw)

* **Machine peaks are measured, not quoted**: ``measure_peaks`` times a
  1024^3 f32 matmul (compute peak) and a 64 MB array add (stream
  bandwidth) with the repo's interleaved best-of timing, so the roofline
  is the roof of THIS box under the same load conditions as the kernel
  rows — a spec-sheet number would make every fraction incomparable
  across machines.
* **Kernel flops/bytes come from XLA's own cost model**: ``cost_of``
  lowers and compiles the dispatch and reads ``cost_analysis()`` off the
  compiled module.  Arguments are passed explicitly to ``lower`` —
  closure-captured arrays become HLO constants and XLA constant-folds
  the very work being measured (observed: a 2000-point ``topk_sqdist``
  folding for 7 s at compile time and reporting zero runtime work).

Fractions are diagnostic, not gated: XLA's byte accounting counts every
buffer touch as HBM traffic, so cache-resident kernels can exceed 1.0
and interpreter-lowered Pallas loops sit far below it.  The value is the
*relative* ordering — which dispatch has headroom — reported per cell in
``BENCH_autotune.json`` (see benchmarks/autotune_sweep.py).
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import best_of_interleaved

ART = pathlib.Path(__file__).resolve().parent / "artifacts"

_MM_N = 1024            # compute probe: (N, N) @ (N, N) f32
_COPY_MB = 64           # bandwidth probe: elementwise add over this many MB


def measure_peaks(repeats: int = 5) -> dict:
    """Measured machine roof: f32 matmul flop/s + stream add bytes/s."""
    ka, kb = jax.random.split(jax.random.key(0))
    a = jax.random.normal(ka, (_MM_N, _MM_N), jnp.float32)
    b = jax.random.normal(kb, (_MM_N, _MM_N), jnp.float32)
    big = jnp.ones((_COPY_MB * (1 << 20) // 4,), jnp.float32)
    mm = jax.jit(lambda x, y: x @ y)
    cp = jax.jit(lambda x: x + 1.0)
    _, (t_mm, t_cp) = best_of_interleaved(
        [lambda: mm(a, b), lambda: cp(big)], repeats)
    return dict(
        peak_flops=2.0 * _MM_N**3 / t_mm,
        mem_bw=2.0 * big.nbytes / t_cp,          # read + write streams
        matmul_s=t_mm, copy_s=t_cp)


def cost_of(fn, *args) -> dict:
    """flops / bytes / temp bytes of one dispatch, from the compiled module.

    ``fn(*args)`` is lowered with the args as real parameters (never
    closure constants — see module docstring) and the compiled module's
    ``cost_analysis()`` / ``memory_analysis()`` are read back.  Missing
    counters (CPU XLA omits flops for some ops) come back as None."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):            # older jax: list of dicts
        ca = ca[0] if ca else {}
    flops = ca.get("flops")
    nbytes = ca.get("bytes accessed")
    try:
        temp = int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:                            # backend without the API
        temp = None
    return dict(flops=None if flops is None else float(flops),
                bytes=None if nbytes is None else float(nbytes),
                temp_bytes=temp)


def bound_seconds(cost: dict, peaks: dict) -> float | None:
    """Roofline time bound: the binding of the compute and memory terms."""
    terms = []
    if cost.get("flops"):
        terms.append(cost["flops"] / peaks["peak_flops"])
    if cost.get("bytes"):
        terms.append(cost["bytes"] / peaks["mem_bw"])
    return max(terms) if terms else None


def fraction(cost: dict, seconds: float, peaks: dict) -> float | None:
    """Achieved fraction of the roofline (1.0 = at the roof)."""
    b = bound_seconds(cost, peaks)
    if b is None or seconds <= 0:
        return None
    return b / seconds


def main() -> None:
    """Standalone report: fractions for the autotune bench's kernel cells
    at their legacy (hardcoded) configs."""
    from benchmarks.autotune_sweep import build_cells  # lazy: heavy imports
    from repro.runtime.timing import AUTOTUNE_REPEATS
    peaks = measure_peaks()
    print(f"# peaks: {peaks['peak_flops'] / 1e9:.1f} GF/s, "
          f"{peaks['mem_bw'] / 1e9:.1f} GB/s")
    out = [dict(name="peaks", **{k: float(v) for k, v in peaks.items()})]
    print("| kernel | achieved us | bound us | fraction |")
    print("|---|---|---|---|")
    for cell in build_cells(tiny=True):
        fn, args = cell.make_fn(dict(cell.default))
        cost = cost_of(fn, *args)
        _, (t,) = best_of_interleaved([lambda: fn(*args)], AUTOTUNE_REPEATS)
        frac = fraction(cost, t, peaks)
        b = bound_seconds(cost, peaks)
        print(f"| {cell.name} | {t * 1e6:.1f} | "
              f"{'—' if b is None else f'{b * 1e6:.1f}'} | "
              f"{'—' if frac is None else f'{frac:.3f}'} |")
        out.append(dict(name=cell.name, us=t * 1e6, **cost,
                        roofline_fraction=frac))
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / "roofline_kernels.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    from repro.runtime import platform
    platform.apply_bench_preset()
    main()
