"""§Roofline: three-term analysis per (arch x shape) from dry-run artifacts.

  compute term    = corrected_FLOPs_per_device / peak_FLOPs      (197 TF/s bf16)
  memory term     = corrected_bytes_per_device / HBM_bw          (819 GB/s)
  collective term = collective_bytes_per_device / link_bw        (50 GB/s)

Trip-count correction (cost_analysis counts scan bodies once — verified):

  total = full_raw + (n_micro-1)*micro_raw
        + n_micro*[(n_periods-1)*body_raw + n_periods*inner_corr]

MODEL_FLOPS uses 6*N_active*D (train) / 2*N_active*D (inference) GLOBAL
tokens; the useful-compute ratio divides it by corrected device flops x
chips.  Caveats recorded in EXPERIMENTS.md: bytes come from the CPU-backend
HLO (layout-faithful proxy for HBM traffic); collective bytes are operand
sizes in the partitioned module (ring-transfer proxy).
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link
CHIPS = {"single": 256, "multi": 512}

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def set_artifact_dir(path):
    global ART
    ART = pathlib.Path(path)


def _load(name):
    p = ART / name
    return json.loads(p.read_text()) if p.exists() else None


def _book_corr(entries, chips):
    """CostBook entries hold GLOBAL analytic totals (trace-time shapes are
    unpartitioned); scans of interest (attention blocks, SSM chunks) shard
    over batch x heads/inner across the mesh, so per-device = global/chips.
    (Archs whose head count under-shards the model axis — whisper-tiny,
    xlstm — undercount here; their compute term sits orders below the
    dominant term, so conclusions are unaffected.  Documented in
    EXPERIMENTS.md §Roofline caveats.)"""
    f = sum(e["total_flops"] * (e["trips"] - 1) / e["trips"]
            for e in entries) / chips
    b = sum(e["total_bytes"] * (e["trips"] - 1) / e["trips"]
            for e in entries) / chips
    return f, b


def corrected_cost(full, body_rec, chips):
    """(flops, bytes, collective_bytes) per device, trip-count corrected."""
    f_raw = full["cost"]["flops"]
    b_raw = full["cost"]["bytes_accessed"]
    c_raw = full["collectives"]["total"]
    if body_rec is None or body_rec.get("status") != "ok":
        f_corr, b_corr = _book_corr(full.get("costbook", []), chips)
        return f_raw + f_corr, b_raw + b_corr, c_raw, False
    n_per = body_rec["n_periods"]
    bodies = body_rec["bodies"]
    period = bodies.get("period")
    micro = bodies.get("micro")
    n_micro = period.get("n_micro", 1) if period else 1

    pf = period["cost"]["flops"] if period else 0.0
    pb = period["cost"]["bytes_accessed"] if period else 0.0
    pc = period["collectives"]["total"] if period else 0.0
    inf, inb = _book_corr(period["costbook"], chips) if period \
        else (0.0, 0.0)

    if micro is not None:
        mf = micro["cost"]["flops"]
        mb = micro["cost"]["bytes_accessed"]
        mc = micro["collectives"]["total"]
    else:
        mf = mb = mc = 0.0
        n_micro = 1

    def total(full_v, micro_v, body_v, inner_v):
        return (full_v + (n_micro - 1) * micro_v
                + n_micro * ((n_per - 1) * body_v + n_per * inner_v))

    return (total(f_raw, mf, pf, inf), total(b_raw, mb, pb, inb),
            total(c_raw, mc, pc, 0.0), True)


def model_flops(arch_cfg, shape_cfg) -> float:
    """Global useful FLOPs: 6*N_active*D (train) / 2*N_active*D (inference)
    plus the standard causal-attention term (MFU convention)."""
    n = arch_cfg.active_param_count()
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    hd = arch_cfg.resolved_head_dim
    n_attn = sum(1 for p in arch_cfg.block_pattern
                 if p in ("attn", "local", "global"))
    attn_layers = arch_cfg.n_layers * n_attn / len(arch_cfg.block_pattern)
    if shape_cfg.kind == "train":
        tokens = B * S
        # causal: S^2/2 pairs; qk+pv: x2 matmuls; x2 flops/MAC; x3 fwd+bwd
        attn = attn_layers * B * (S * S / 2) * arch_cfg.n_heads * hd * 4 * 3
        return 6.0 * n * tokens + attn
    if shape_cfg.kind == "prefill":
        tokens = B * S
        attn = attn_layers * B * (S * S / 2) * arch_cfg.n_heads * hd * 4
        return 2.0 * n * tokens + attn
    # decode: 1 new token attends the full cache
    attn = attn_layers * B * S * arch_cfg.n_heads * hd * 4
    return 2.0 * n * B + attn


def analyze_cell(arch: str, shape: str, mesh: str = "single"):
    full = _load(f"{arch}__{shape}__{mesh}.json")
    if full is None or full["status"] != "ok":
        return full
    body = _load(f"{arch}__{shape}__single__body.json")
    chips = CHIPS[mesh]
    f, b, c, exact = corrected_cost(full, body, chips)
    t_comp = f / PEAK_FLOPS
    t_mem = b / HBM_BW
    t_coll = c / LINK_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    rec = dict(arch=arch, shape=shape, mesh=mesh, chips=chips,
               flops_per_dev=f, bytes_per_dev=b, coll_bytes_per_dev=c,
               t_compute_s=t_comp, t_memory_s=t_mem, t_collective_s=t_coll,
               dominant=dominant, body_corrected=exact,
               temp_bytes=full["memory"]["temp_size_in_bytes"],
               arg_bytes=full["memory"]["argument_size_in_bytes"])
    if arch != "largevis":
        from repro.configs import SHAPES, get_config
        cfg = get_config(arch)
        mf = model_flops(cfg, SHAPES[shape])
        rec["model_flops_global"] = mf
        rec["useful_ratio"] = mf / max(f * chips, 1.0)
        # roofline fraction: useful model flops / (time-bound x peak)
        t_bound = max(t_comp, t_mem, t_coll)
        rec["roofline_fraction"] = mf / max(
            t_bound * PEAK_FLOPS * chips, 1e-9)
    return rec


def full_table(mesh: str = "single"):
    from repro.configs import ARCH_NAMES, SHAPES
    rows = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            r = analyze_cell(arch, shape, mesh)
            if r is None:
                continue
            if r.get("status") == "skipped":
                rows.append(dict(arch=arch, shape=shape, mesh=mesh,
                                 skipped=r["reason"]))
            elif "t_compute_s" in r:
                rows.append(r)
    for shape in ("layout_4m",):
        r = analyze_cell("largevis", shape, mesh)
        if r and "t_compute_s" in r:
            rows.append(r)
    return rows


def render(rows) -> str:
    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | useful ratio | roofline frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        ur = r.get("useful_ratio")
        rf = r.get("roofline_fraction")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant']} | "
            f"{'—' if ur is None else f'{ur:.2f}'} | "
            f"{'—' if rf is None else f'{rf:.3f}'} |")
    return "\n".join(lines)


def main():
    import sys
    if len(sys.argv) > 1:
        set_artifact_dir(sys.argv[1])
    rows = full_table("single")
    print(render(rows))
    out = pathlib.Path(__file__).resolve().parent / "artifacts" / \
        "roofline_single.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
