"""Autotuner sweep bench: autotuned vs hardcoded tiles, per kernel cell.

For each kernel x shape cell this bench (1) runs the runtime autotuner's
sweep for that cell (``repro.runtime.autotune.sweep`` — interleaved
best-of-3 shortlist, paired best-of-8 adopt rule), then (2) re-times the
adopted config against the legacy hardcoded config in a fresh **paired
interleaved best-of-8** on the bench's own inputs, and (3) reports the
achieved-vs-roofline fraction of the tuned dispatch (flops/bytes from
XLA ``cost_analysis`` on the lowered module, machine peaks measured —
see benchmarks/roofline.py).

Two artifacts with row-for-row matching names:

* ``BENCH_autotune.json`` — the autotuned timings (plus per-row
  ``speedup``, chosen ``config`` and ``roofline_fraction``).
* ``BENCH_autotune_hardcoded.json`` — the same cells at the legacy
  hardcoded configs.

The CI gate compares the two FRESH artifacts against each other
(autotuned must never be > 1.1x slower than hardcoded on any cell —
the adopt rule keeps the default on ties, so an autotuned loss beyond
noise means the tuner itself regressed), not fresh-vs-committed wall
clock, so the gate is robust to CI-runner speed.  ``--tiny`` is that CI
smoke mode (reduced shapes, ``autotune_tiny`` tables).

``--write-defaults`` additionally refreshes the committed in-repo
default table (``src/repro/runtime/autotune_defaults.json``) from the
sweep results — run on the reference box, never in CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import AUTOTUNE_REPEATS, Rows, best_of_interleaved
from repro.core import neighbor_explore as ne
from repro.core import perplexity
from repro.kernels import ops, ref
from repro.runtime import autotune


@dataclasses.dataclass
class Cell:
    name: str                 # row name
    kernel: str               # autotune kernel key
    shape: dict               # autotune shape dict (bucketed for the key)
    default: dict             # legacy hardcoded config for this call site
    make_fn: object           # cfg -> (fn, args): the measured dispatch
    # knobs pinned for BOTH configs of this cell (e.g. y_tile, so a
    # tiled-mode cell compares edge tiles within the tiled kernel); the
    # sweep itself runs un-pinned on the shared shape bucket
    force: dict = dataclasses.field(default_factory=dict)


def build_cells(tiny: bool) -> list[Cell]:
    """The repo's tuned call sites at representative (or CI-tiny) shapes.

    Every ``make_fn`` closes only over python scalars — arrays are
    returned as explicit args so ``roofline.cost_of`` can lower them as
    parameters (closure arrays constant-fold; see roofline.py)."""
    cells = []
    key = jax.random.key(42)

    # --- topk_sqdist: the brute-force KNN dispatch (fig2 shape) ---------
    m = 2000 if tiny else 6000
    d, k = (32, 20) if tiny else (100, 50)
    ka, _ = jax.random.split(key)
    x = jax.random.normal(ka, (m, d), jnp.float32)

    def topk_fn(cfg):
        def fn(a, b):
            return ops.topk_sqdist(a, b, k, **cfg)
        return fn, (x, x)

    cells.append(Cell("topk_bf", "topk_sqdist", dict(m=m, n=m, d=d, k=k),
                      autotune.legacy_default("topk_sqdist"), topk_fn))

    # --- knn_window_fold: the forest window dispatch --------------------
    w = 256 if tiny else 1024
    kw_ = min(k, w - 1)
    kc, kd = jax.random.split(jax.random.key(43))
    aw = jax.random.normal(kc, (w, d), jnp.float32)
    bw = jnp.concatenate(
        [aw, jax.random.normal(kd, (2 * w, d), jnp.float32)])
    a_ids = jnp.arange(w, dtype=jnp.int32)
    b_ids = jnp.arange(3 * w, dtype=jnp.int32)
    init_i = jnp.full((w, kw_), -1, jnp.int32)
    init_d = jnp.full((w, kw_), ref.INVALID_DIST, jnp.float32)

    def window_fn(cfg):
        def fn(a, b, ii, dd):
            return ops.topk_sqdist(a, b, kw_, a_ids=a_ids, b_ids=b_ids,
                                   init_ids=ii, init_dists=dd, dedup=True,
                                   bm=min(cfg["bm"], w),
                                   bn=min(cfg["bn"], 3 * w))
        return fn, (aw, bw, init_i, init_d)

    cells.append(Cell("window_fold", "knn_window_fold",
                      dict(w=w, k=kw_, d=d), dict(bm=w, bn=3 * w),
                      window_fn))

    # --- largevis_edge_step: the layout hot loop ------------------------
    def edge_cell(name, n, b, mneg, force=None):
        keys = jax.random.split(jax.random.key(44), 4)
        y = jax.random.normal(keys[0], (n, 2), jnp.float32) * 1e-2
        i = jax.random.randint(keys[1], (b,), 0, n, jnp.int32)
        j = jax.random.randint(keys[2], (b,), 0, n, jnp.int32)
        negs = jax.random.randint(keys[3], (b, mneg), 0, n, jnp.int32)
        nm = ((negs != i[:, None])
              & (negs != j[:, None])).astype(jnp.float32)

        def fn_maker(cfg):
            def fn(y_, i_, j_, negs_, nm_):
                return ops.largevis_edge_step(y_, i_, j_, negs_, nm_, 0.5,
                                              **cfg)
            return fn, (y, i, j, negs, nm)

        return Cell(name, "largevis_edge_step",
                    dict(n=n, b=b, m=mneg, s=2),
                    autotune.legacy_default("largevis_edge_step"), fn_maker,
                    force=force or {})

    if tiny:
        cells.append(edge_cell("edge_step", 4000, 1024, 5))
        cells.append(edge_cell("edge_step_ytile", 4000, 1024, 5,
                               force=dict(y_tile=1000)))
    else:
        cells.append(edge_cell("edge_step", 20000, 4096, 8))
        cells.append(edge_cell("edge_step_ytile", 20000, 4096, 8,
                               force=dict(y_tile=5000)))

    # --- symmetrize: the graph-weights reverse gather -------------------
    n_sym = 8000 if tiny else 100_000
    k_sym = 20 if tiny else 50
    ks = jax.random.split(jax.random.key(45))
    idx = jax.random.randint(ks[0], (n_sym, k_sym), 0, n_sym, jnp.int32)
    p = jax.random.uniform(ks[1], (n_sym, k_sym), jnp.float32)

    def sym_fn(cfg):
        def fn(idx_, p_):
            return perplexity.symmetrize(idx_, p_, tile=cfg["tile"])
        return fn, (idx, p)

    cells.append(Cell("symmetrize", "symmetrize", dict(n=n_sym, k=k_sym),
                      autotune.legacy_default("symmetrize"), sym_fn))

    # --- neighbor_explore: one un-sampled exploring round ---------------
    n_ex = 2000 if tiny else 6000
    k_ex = 10 if tiny else 20
    kx, kr = jax.random.split(jax.random.key(46))
    xe = jax.random.normal(kx, (n_ex, d), jnp.float32)
    from repro.core.knn import brute_force_knn
    eidx, edist = brute_force_knn(xe, k_ex)

    def explore_fn(cfg):
        tile = max(16, min(cfg["tile"], n_ex))

        def fn(x_, idx_, dist_):
            return ne._explore_round(x_, idx_, dist_, kr, sample=0,
                                     tile=tile, r_cap=k_ex)
        return fn, (xe, eidx, edist)

    cells.append(Cell("explore", "neighbor_explore",
                      dict(n=n_ex, k=k_ex, d=d),
                      autotune.legacy_default("neighbor_explore"),
                      explore_fn))
    return cells


def run(rows: Rows, rows_hard: Rows | None = None, *,
        tiny: bool = False) -> None:
    """Fill ``rows`` (autotuned) and the hardcoded companion.

    ``rows_hard=None`` (the benchmarks/run.py single-``rows`` contract)
    creates and SAVES the companion here, so the harness path still
    produces both artifacts."""
    from benchmarks import roofline
    own_companion = rows_hard is None
    if own_companion:
        rows_hard = Rows(rows.table)
    peaks = roofline.measure_peaks()
    print(f"# peaks: {peaks['peak_flops'] / 1e9:.1f} GF/s, "
          f"{peaks['mem_bw'] / 1e9:.1f} GB/s", file=sys.stderr)
    swept: dict[str, dict] = {}        # bucket key -> config (cells share)
    for cell in build_cells(tiny):
        bkey = autotune.bucket_key(cell.kernel, cell.shape)
        tuned = swept.get(bkey)
        if tuned is None:
            tuned = autotune.sweep(cell.kernel, cell.shape, cell.default)
            swept[bkey] = tuned
        cfg_def = {**cell.default, **cell.force}
        cfg_tuned = {**cell.default, **tuned, **cell.force}
        fn_d, args_d = cell.make_fn(cfg_def)
        fn_t, args_t = cell.make_fn(cfg_tuned)
        # the decision-grade paired comparison, on the bench's inputs
        _, (t_def, t_tuned) = best_of_interleaved(
            [lambda: fn_d(*args_d), lambda: fn_t(*args_t)],
            AUTOTUNE_REPEATS)
        cost = roofline.cost_of(fn_t, *args_t)
        frac = roofline.fraction(cost, t_tuned, peaks)
        derived = dict(config=json.dumps(cfg_tuned, sort_keys=True),
                       speedup=round(t_def / max(t_tuned, 1e-12), 3))
        if frac is not None:
            derived["roofline_fraction"] = round(frac, 4)
        if cost.get("flops") is not None:
            derived["flops"] = cost["flops"]
        if cost.get("bytes") is not None:
            derived["bytes"] = cost["bytes"]
        rows.add(cell.name, t_tuned, **derived)
        rows_hard.add(cell.name, t_def,
                      config=json.dumps(cfg_def, sort_keys=True))
    if own_companion:
        rows_hard.save(table=f"{rows.table}_hardcoded")


def write_defaults() -> None:
    """Refresh the committed default table from this box's sweep cache."""
    backend = jax.default_backend()
    entries = autotune._read_entries(autotune._cache_path(backend))
    path = autotune._defaults_path()
    doc = {"version": autotune.AUTOTUNE_VERSION, "backend": backend,
           "jax": jax.__version__, "entries": entries}
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"# wrote {path} ({len(entries)} entries)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced shapes, autotune_tiny tables (CI smoke)")
    ap.add_argument("--write-defaults", action="store_true",
                    help="refresh src/repro/runtime/autotune_defaults.json "
                         "from the sweep results (reference box only)")
    args = ap.parse_args()
    from repro.runtime import platform
    platform.apply_bench_preset()
    table = "autotune_tiny" if args.tiny else "autotune"
    rows = Rows(table)
    run(rows, tiny=args.tiny)
    rows.print_csv()
    rows.save()
    if args.write_defaults:
        write_defaults()


if __name__ == "__main__":
    main()
