"""Quickstart: visualize a clustered dataset in 2D with LargeVis.

    PYTHONPATH=src python examples/quickstart.py

Builds an approximate KNN graph (projection forest + neighbor exploring),
calibrates edge weights at the target perplexity, and lays the graph out
with edge-sampling SGD — the full paper pipeline in ~20 lines of API use.
Writes coords + labels to /tmp/largevis_quickstart.npz.
"""
import jax
import numpy as np

from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import largevis
from repro.core.metrics import graph_recall, knn_classifier_accuracy
from repro.data.synthetic import gaussian_mixture


def main():
    key = jax.random.key(0)
    x, labels = gaussian_mixture(key, 5000, 64, 12)
    print(f"data: {x.shape[0]} points, {x.shape[1]} dims, 12 clusters")

    cfg = LargeVisConfig(
        n_neighbors=20,          # K
        n_trees=4,               # projection forest size
        n_explore_iters=2,       # neighbor exploring rounds
        window=32,
        perplexity=15.0,
        samples_per_node=4000,   # T / N
        batch_size=4096,
    )
    result = largevis(x, key, cfg=cfg)

    recall = graph_recall(x, result.knn_idx)
    acc = knn_classifier_accuracy(result.y, labels, k=5)
    print(f"KNN graph recall vs exact: {recall:.3f}")
    print(f"2D KNN-classifier accuracy: {acc:.3f} (chance = 0.083)")
    print(f"timings: {dict((k, round(v, 2)) for k, v in result.timings.items())}")

    out = "/tmp/largevis_quickstart.npz"
    np.savez(out, coords=np.asarray(result.y), labels=np.asarray(labels))
    print(f"wrote {out} — plot with matplotlib scatter(coords[:,0], coords[:,1], c=labels)")


if __name__ == "__main__":
    main()
