"""Ties the two halves of the framework together: train a small LM, then
visualize its learned token embeddings with LargeVis — the paper's own
recommended usage ('use Skipgram/LINE to learn 100-dim representations,
then LargeVis to visualize them', §4.1).

    PYTHONPATH=src python examples/visualize_embeddings.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.largevis_default import LargeVisConfig
from repro.core.largevis import largevis
from repro.launch.train import train


def main():
    # 1) train a reduced qwen for a few hundred steps on structured data
    print("training reduced qwen1.5 (few hundred steps)...")
    params, _, losses = train("qwen1.5-0.5b", steps=200, batch=8, seq=64,
                              ckpt_dir="/tmp/emb_ckpt", resume=False,
                              log_every=50)
    first, last = losses[0][1], losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f}")

    # 2) extract the token embedding table (vocab x d)
    table = np.asarray(params["embed"]["table"], np.float32)
    print(f"embedding table: {table.shape}")

    # 3) LargeVis the embeddings into 2D
    cfg = LargeVisConfig(n_neighbors=15, n_trees=4, n_explore_iters=2,
                         window=32, perplexity=10.0, samples_per_node=2000,
                         batch_size=4096)
    result = largevis(jnp.asarray(table), jax.random.key(1), cfg=cfg)
    y = np.asarray(result.y)
    print(f"layout: {y.shape}, spread {y.std():.2f}")
    np.savez("/tmp/largevis_token_embeddings.npz", coords=y)
    print("wrote /tmp/largevis_token_embeddings.npz")


if __name__ == "__main__":
    main()
