"""Serve a small model with batched requests (continuous batching engine).

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]

Uses reduced configs on CPU; the same ServeEngine runs full configs on a
pod via make_production_mesh() + the decode-cell shardings proven by the
dry-run.
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    eng = ServeEngine(cfg, slots=4, max_len=96, temperature=0.8)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(4, 16))).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    steps = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"{args.arch}: served {len(reqs)} requests / {total} tokens in "
          f"{steps} engine steps, {dt:.1f}s ({total/dt:.1f} tok/s; "
          f"4-slot continuous batching)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> "
              f"{len(r.out)} generated")


if __name__ == "__main__":
    main()
