"""Train a ~100M-parameter LM for a few hundred steps (end-to-end driver).

    PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m] [--steps 300]

xlstm-125m is the one assigned architecture whose FULL config fits this
CPU container (~125M params); every other arch runs with --reduced.  The
driver exercises the production path: sharded step, checkpointing +
auto-resume, preemption guard, watchdog.
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    full_ok = args.arch in ("xlstm-125m",)
    reduced = args.reduced or not full_ok
    print(f"training {args.arch} ({'reduced' if reduced else 'FULL'} config) "
          f"for {args.steps} steps")
    _, _, losses = train(args.arch, steps=args.steps, batch=args.batch,
                         seq=args.seq, reduced=reduced,
                         ckpt_dir=f"/tmp/train_{args.arch}", resume=True,
                         save_every=100, log_every=25)
    first, last = losses[0][1], losses[-1][1]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'check hyper-params'})")


if __name__ == "__main__":
    main()
