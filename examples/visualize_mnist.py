"""End-to-end driver (the paper's kind of workload): visualize an
MNIST-shaped dataset — 784-dim images, 10 classes — at the largest size
this container handles comfortably, with the full production feature set:
checkpointed layout state, straggler watchdog, quality metrics.

    PYTHONPATH=src python examples/visualize_mnist.py [--n 20000]

This is the 'train ~100M-model-equivalent' driver for a layout system: the
trainable object is the (N x 2) coordinate table optimized for
samples_per_node * N edge samples.
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.largevis_default import LargeVisConfig
from repro.core import sampler as S
from repro.core.largevis import build_graph
from repro.core.layout import run_layout
from repro.core.metrics import graph_recall, knn_classifier_accuracy
from repro.data.synthetic import mnist_like
from repro.runtime.fault_tolerance import Watchdog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--samples-per-node", type=int, default=3000)
    ap.add_argument("--ckpt", default="/tmp/largevis_mnist_ckpt")
    args = ap.parse_args()

    key = jax.random.key(0)
    x, labels = mnist_like(key, args.n, 784, 10)
    print(f"dataset: {x.shape} (MNIST-shaped), 10 classes")

    cfg = LargeVisConfig(n_neighbors=50, n_trees=8, n_explore_iters=2,
                         window=64, perplexity=30.0,
                         samples_per_node=args.samples_per_node,
                         batch_size=8192)
    t0 = time.time()
    idx, dist, w, timings = build_graph(x, key, cfg)
    print(f"graph built in {time.time()-t0:.1f}s "
          f"(recall {graph_recall(x, idx):.3f})")

    es = S.build_edge_sampler(idx, w)
    ns = S.build_negative_sampler(idx, w)
    mgr = CheckpointManager(args.ckpt, save_every=200)
    dog = Watchdog()

    state, start = mgr.resume()
    y0 = state["y"] if state else None

    # run_layout's scan-fused path: cfg.steps_per_dispatch steps per device
    # dispatch (donated y buffer); on_chunk fires at every chunk boundary —
    # the checkpoint / watchdog / progress tick.  Saves use a distance
    # check, not step % save_every, so any steps_per_dispatch cadence works.
    t0 = time.time()
    prog = {"last": t0, "saved": start}
    res_batch = min(cfg.batch_size, args.n // 2)    # the collision cap

    def on_chunk(t, steps, y):
        now = time.time()
        dog.observe(t, now - prog["last"])
        prog["last"] = now
        if t - prog["saved"] >= mgr.save_every or t >= steps:
            mgr.save_now(t, {"y": y})
            prog["saved"] = t
        if t % max(1, (steps // 10)) < cfg.steps_per_dispatch:
            rate = (t - start) * res_batch / max(now - t0, 1e-9)
            print(f"  step {t}/{steps} ({rate:,.0f} edge samples/s)")

    res = run_layout(key, es, ns, args.n, cfg, y0=y0, start_step=start,
                     on_chunk=on_chunk)
    y = res.y
    acc = knn_classifier_accuracy(y, labels, k=5)
    print(f"layout done: {res.steps} steps, {res.edge_samples:,} edge "
          f"samples, 2D KNN accuracy {acc:.3f} (chance 0.1)")
    if dog.stragglers:
        print(f"straggler steps flagged: {len(dog.stragglers)}")
    np.savez("/tmp/largevis_mnist.npz", coords=np.asarray(y),
             labels=np.asarray(labels))
    print("wrote /tmp/largevis_mnist.npz")


if __name__ == "__main__":
    main()
