"""End-to-end driver (the paper's kind of workload): visualize an
MNIST-shaped dataset — 784-dim images, 10 classes — through the
``repro.LargeVis`` estimator, then project held-out points into the
frozen layout with ``transform`` and grow the model with ``insert``.

    PYTHONPATH=src python examples/visualize_mnist.py [--n 20000]

The out-of-sample path is the online-serving story: ``transform`` places
new points without moving a single fitted coordinate (the corpus stays
bit-identical), and ``insert`` adopts them — KNN graph, edge weights and
samplers updated incrementally — so the next fit-quality question can be
asked of the grown model.  For the checkpointed / watchdogged production
fit loop see ``launch/train.py``.
"""
import argparse
import time

import jax
import numpy as np

from repro import LargeVis, LargeVisConfig
from repro.core.metrics import graph_recall, knn_classifier_accuracy
from repro.data.synthetic import mnist_like


def _held_out_accuracy(y_corpus, labels_corpus, y_query, labels_query, k=5):
    """5-NN majority vote of projected queries against the corpus layout."""
    d2 = ((np.asarray(y_query)[:, None, :]
           - np.asarray(y_corpus)[None, :, :]) ** 2).sum(-1)
    nn = np.argsort(d2, axis=1)[:, :k]
    votes = np.asarray(labels_corpus)[nn]
    pred = np.array([np.bincount(v).argmax() for v in votes])
    return float((pred == np.asarray(labels_query)).mean())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--n-held-out", type=int, default=1_000)
    ap.add_argument("--samples-per-node", type=int, default=3000)
    args = ap.parse_args()

    key = jax.random.key(0)
    x, labels = mnist_like(key, args.n + args.n_held_out, 784, 10)
    x_fit, labels_fit = x[:args.n], labels[:args.n]
    x_new, labels_new = x[args.n:], labels[args.n:]
    print(f"dataset: {x.shape} (MNIST-shaped), 10 classes "
          f"({args.n} fit + {args.n_held_out} held out)")

    model = LargeVis(n_neighbors=50, n_trees=8, n_explore_iters=2,
                     window=64, perplexity=30.0,
                     samples_per_node=args.samples_per_node,
                     batch_size=8192)

    t0 = time.time()
    model.fit(x_fit, key)
    r = model.result_
    acc = knn_classifier_accuracy(r.y, labels_fit, k=5)
    print(f"fit in {time.time()-t0:.1f}s "
          f"(graph recall {graph_recall(x_fit, r.knn_idx):.3f}, "
          f"2D KNN accuracy {acc:.3f}, chance 0.1)")
    for stage, secs in r.timings.items():
        print(f"  {stage}: {secs:.2f}s")

    # -- out-of-sample projection: corpus coordinates stay bit-identical
    y_before = np.asarray(r.y).copy()
    t0 = time.time()
    y_new = model.transform(x_new)
    acc_new = _held_out_accuracy(r.y, labels_fit, y_new, labels_new)
    assert np.array_equal(np.asarray(r.y).view(np.uint32),
                          y_before.view(np.uint32)), "corpus moved!"
    print(f"transform: {len(x_new)} held-out points in {time.time()-t0:.1f}s "
          f"(held-out 2D KNN accuracy {acc_new:.3f}; corpus frozen: bitwise)")

    # -- incremental adoption: the model grows, nothing refits
    t0 = time.time()
    model.insert(x_new)
    r = model.result_
    print(f"insert: model grown to N={r.y.shape[0]} in {time.time()-t0:.1f}s "
          f"(graph rows repaired incrementally, samplers rebuilt)")

    y_all = np.asarray(r.y)
    labels_all = np.concatenate([np.asarray(labels_fit),
                                 np.asarray(labels_new)])
    np.savez("/tmp/largevis_mnist.npz", coords=y_all, labels=labels_all)
    print("wrote /tmp/largevis_mnist.npz")


if __name__ == "__main__":
    main()
