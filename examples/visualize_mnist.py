"""End-to-end driver (the paper's kind of workload): visualize an
MNIST-shaped dataset — 784-dim images, 10 classes — at the largest size
this container handles comfortably, with the full production feature set:
checkpointed layout state, straggler watchdog, quality metrics.

    PYTHONPATH=src python examples/visualize_mnist.py [--n 20000]

This is the 'train ~100M-model-equivalent' driver for a layout system: the
trainable object is the (N x 2) coordinate table optimized for
samples_per_node * N edge samples.
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.largevis_default import LargeVisConfig
from repro.core import sampler as S
from repro.core.largevis import build_graph
from repro.core.layout import LayoutResult, layout_step
from repro.core.metrics import graph_recall, knn_classifier_accuracy
from repro.data.synthetic import mnist_like
from repro.runtime.fault_tolerance import Watchdog

import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--samples-per-node", type=int, default=3000)
    ap.add_argument("--ckpt", default="/tmp/largevis_mnist_ckpt")
    args = ap.parse_args()

    key = jax.random.key(0)
    x, labels = mnist_like(key, args.n, 784, 10)
    print(f"dataset: {x.shape} (MNIST-shaped), 10 classes")

    cfg = LargeVisConfig(n_neighbors=50, n_trees=8, n_explore_iters=2,
                         window=64, perplexity=30.0,
                         samples_per_node=args.samples_per_node,
                         batch_size=8192)
    t0 = time.time()
    idx, dist, w, timings = build_graph(x, key, cfg)
    print(f"graph built in {time.time()-t0:.1f}s "
          f"(recall {graph_recall(x, idx):.3f})")

    es = S.build_edge_sampler(idx, w)
    ns = S.build_negative_sampler(idx, w)
    mgr = CheckpointManager(args.ckpt, save_every=200)
    dog = Watchdog()

    total = cfg.samples_per_node * args.n
    steps = max(1, total // cfg.batch_size)
    state, start = mgr.resume()
    y = state["y"] if state else (
        jax.random.normal(key, (args.n, cfg.out_dim)) * cfg.init_scale)

    kwargs = dict(edge_src=es.src, edge_dst=es.dst, edge_thr=es.threshold,
                  edge_alias=es.alias, neg_thr=ns.threshold,
                  neg_alias=ns.alias, n_negatives=cfg.n_negatives,
                  n_nodes=args.n, prob_fn=cfg.prob_fn, a=cfg.prob_a,
                  gamma=cfg.gamma, clip=cfg.grad_clip, rho0=cfg.rho0,
                  batch=cfg.batch_size)
    t0 = time.time()
    for t in range(start, steps):
        ts = time.time()
        y = layout_step(y, jax.random.fold_in(key, t),
                        jnp.float32(t / steps), **kwargs)
        dog.observe(t, time.time() - ts)
        mgr.maybe_save(t + 1, {"y": y})
        if t % max(1, steps // 10) == 0:
            print(f"  step {t}/{steps} "
                  f"({cfg.batch_size*(t+1-start)/(time.time()-t0):,.0f} "
                  f"edge samples/s)")
    acc = knn_classifier_accuracy(y, labels, k=5)
    print(f"layout done: {steps} steps, {steps*cfg.batch_size:,} edge "
          f"samples, 2D KNN accuracy {acc:.3f} (chance 0.1)")
    if dog.stragglers:
        print(f"straggler steps flagged: {len(dog.stragglers)}")
    np.savez("/tmp/largevis_mnist.npz", coords=np.asarray(y),
             labels=np.asarray(labels))
    print("wrote /tmp/largevis_mnist.npz")


if __name__ == "__main__":
    main()
